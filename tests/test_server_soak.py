"""DataflowServer soak: randomized admission/harvest schedule
(ISSUE 4).

A seeded random workload churns one server for >= 100 blocks — random
request sizes (stream lengths 1..6, so the packed feed buffer grows
and slots are refilled mid-flight), submissions interleaved with
blocks at random, slots turning over continuously — and every
per-request invariant is checked:

* tokens out are exact (one per stream element on a DAG fabric);
* block accounting is consistent: queued <= admitted <= finished and
  queue-wait + residency never exceeds the request's wall-clock blocks;
* results are bit-identical to solo ``DataflowEngine.run`` runs in
  every field (the server's continuous batching is a pure scheduling
  change);
* after drain no slot leaks: every slot free, no resident bookkeeping,
  empty queue, and the server accepts a fresh workload.
"""
import numpy as np
import pytest

from repro.core import library
from repro.core.engine import DataflowEngine
from repro.serve.dataflow_server import DataflowServer
from repro.serve.faults import FaultPlan
from repro.serve.types import Request


@pytest.mark.parametrize("backend,min_blocks",
                         [("xla", 120), ("pallas", 60)])
def test_server_soak_random_schedule(backend, min_blocks):
    bench = library.vector_sum_graph(8)
    srv = DataflowServer(bench.graph, slots=4, block_cycles=4,
                         backend=backend)
    rng = np.random.default_rng(42)
    submitted: dict[int, dict] = {}
    results = {}
    safety = 0
    while srv.block < min_blocks:
        safety += 1
        assert safety < 50 * min_blocks, "soak schedule stalled"
        in_flight = len(submitted) - len(results)
        if rng.random() < 0.6 and in_flight < 12:
            k = int(rng.integers(1, 7))
            feeds = library.random_feeds("vector_sum", bench, k, rng)
            uid = srv.submit(feeds)
            submitted[uid] = feeds
        for r in srv.step():
            results[r.uid] = r
    for r in srv.drain():
        results[r.uid] = r

    # -- no slot leak, nothing resident, queue empty --------------------
    assert set(results) == set(submitted) and len(submitted) > 20
    assert srv.pending == 0 and not srv.queue
    assert not srv.state.active.any() and not srv.state.quiesced.any()
    assert srv._resident == {} and srv._queued_at == {}
    assert srv.block >= min_blocks

    # -- per-request invariants -----------------------------------------
    eng = srv.engine
    for uid, feeds in submitted.items():
        r = results[uid]
        m = r.metrics
        k = max(len(v) for v in feeds.values())
        assert m.tokens_out == k, (uid, "tokens out must be exact")
        assert 0 <= m.slot < 4
        assert m.queued_block <= m.admitted_block <= m.finished_block
        assert m.queue_wait_blocks == m.admitted_block - m.queued_block
        assert m.residency_blocks >= 1
        wall = m.finished_block - m.queued_block
        assert m.queue_wait_blocks + m.residency_blocks <= wall, uid
        assert m.residency_cycles == r.engine.cycles
        # bit-identical to a solo run in every field
        solo = eng.run(feeds)
        assert r.engine.counts == solo.counts, uid
        assert r.engine.cycles == solo.cycles, uid
        assert r.engine.fired == solo.fired, uid
        for a, c in solo.counts.items():
            if c:
                assert int(np.asarray(r.engine.outputs[a])) == \
                    int(np.asarray(solo.outputs[a])), (uid, a)

    # -- the drained server is reusable ----------------------------------
    feeds = library.random_feeds("vector_sum", bench, 2, rng)
    uid = srv.submit(feeds)
    again = {r.uid: r for r in srv.drain()}
    assert uid in again and again[uid].metrics.tokens_out == 2


def test_server_chaos_soak_under_seeded_fault_plan():
    """Chaos soak (DESIGN.md §11): >= 200 blocks of mixed traffic —
    tenants, deadlines, per-request budgets — through a seeded
    FaultPlan injecting transient dispatch failures, wedged slots, and
    poisoned feeds.  ``REPRO_FAULTS=full`` (the CI chaos job) doubles
    the fault rates; ``REPRO_FAULTS=off`` skips injection entirely.

    Invariants: the server never raises, every submitted uid receives
    exactly one Result with a known disposition, no slot leaks after
    drain, and every *unfaulted* request (no poison, no deadline, no
    budget) finishes ok or wedged with results bit-identical to a solo
    ``DataflowEngine.run`` — wedges suppress the quiescence signal,
    never the computation, so even wedged values must match.
    """
    plan = FaultPlan.scaled(seed=7,
                            dispatch_fail_rate=0.04, transient_attempts=1,
                            wedge_rate=0.10, poison_rate=0.12)
    if plan is None:
        pytest.skip("REPRO_FAULTS=off")
    bench = library.vector_sum_graph(8)
    srv = DataflowServer(bench.graph, slots=4, block_cycles=2,
                         backend="xla", max_retries=3,
                         wedge_timeout_blocks=4, faults=plan)
    rng = np.random.default_rng(1234)
    submitted: dict[int, Request] = {}
    results = {}
    uid = 0
    safety = 0
    while srv.block < 200:
        safety += 1
        assert safety < 20_000, "chaos soak stalled"
        in_flight = len(submitted) - len(results)
        if rng.random() < 0.5 and in_flight < 14:
            uid += 1
            k = int(rng.integers(1, 7))
            roll = rng.random()
            req = Request(
                uid=uid,
                feeds=library.random_feeds("vector_sum", bench, k, rng),
                tenant=("a", "b", None)[uid % 3],
                deadline_blocks=int(rng.integers(1, 40))
                if roll < 0.15 else None,
                max_cycles=int(rng.integers(1, 6)) if roll > 0.9 else None)
            srv.submit(req)
            submitted[uid] = req
        for r in srv.step():            # must never raise
            assert r.uid not in results, "duplicate result"
            results[r.uid] = r
    for r in srv.drain():
        assert r.uid not in results, "duplicate result"
        results[r.uid] = r

    # -- conservation: one result per submission, no leaks ---------------
    assert set(results) == set(submitted) and len(submitted) > 30
    assert srv.pending == 0 and not srv.queue
    assert not srv.state.active.any()
    assert srv._resident == {} and srv._queued_at == {}
    known = {"ok", "truncated", "expired", "wedged", "error"}
    assert {r.status for r in results.values()} <= known

    # -- fault schedule actually fired (seeded, so deterministic) --------
    kinds = {k for k, *_ in plan.log}
    assert "poison" in kinds and "dispatch-transient" in kinds

    # -- unfaulted requests: bit-identical to solo runs ------------------
    eng = DataflowEngine(bench.graph, backend="xla", block_cycles=2)
    checked = 0
    for u, req in submitted.items():
        if req.deadline_blocks is not None or req.max_cycles is not None \
                or plan.poisoned(u):
            continue
        r = results[u]
        assert r.status in ("ok", "wedged"), (u, r.status)
        solo = eng.run(req.feeds)
        assert r.engine.counts == solo.counts, u
        assert r.engine.cycles == solo.cycles, u
        assert r.engine.fired == solo.fired, u
        for a, c in solo.counts.items():
            if c:
                assert int(np.asarray(r.engine.outputs[a])) == \
                    int(np.asarray(solo.outputs[a])), (u, a)
        checked += 1
    assert checked > 10, "soak must exercise enough unfaulted requests"
