"""Trip-count-aware HLO analyzer: validated against unrolled compiles."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze


def _cost(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    return analyze(c.as_text())


def test_scan_flops_match_unrolled():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)

    def scanned(x, w):
        def body(x, wi):
            return jnp.tanh(x @ wi), None
        return jax.lax.scan(body, x, w)[0]

    def unrolled(x, w):
        for i in range(7):
            x = jnp.tanh(x @ w[i])
        return x

    a = _cost(scanned, x, w)
    b = _cost(unrolled, x, w)
    assert a["flops"] == b["flops"] == 7 * 2 * 64 ** 3


def test_nested_scan_multiplies():
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def fn(x):
        def outer(x, _):
            def inner(x, _):
                return x @ x, None
            x, _ = jax.lax.scan(inner, x, None, length=3)
            return x, None
        return jax.lax.scan(outer, x, None, length=5)[0]

    c = _cost(fn, x)
    assert c["flops"] == 5 * 3 * 2 * 32 ** 3


def test_dus_in_scan_is_aliased_not_restacked():
    """A scan writing one row per step must NOT count the whole output
    stack per iteration (buffer aliasing)."""
    n, d = 64, 256
    x = jax.ShapeDtypeStruct((n, d), jnp.float32)

    def fn(x):
        out = jnp.zeros((n, d), jnp.float32)

        def body(out, i):
            return jax.lax.dynamic_update_index_in_dim(
                out, x[i] * 2.0, i, 0), None

        out, _ = jax.lax.scan(body, out, jnp.arange(n))
        return out

    c = _cost(fn, x)
    stack_bytes = n * d * 4
    # v1 would count ~n * stack_bytes (~67MB); aliased should be O(few
    # stacks) total
    assert c["traffic_bytes"] < 8 * stack_bytes, c["traffic_bytes"]


def test_collectives_counted_with_trip_multiplier():
    devs = jax.devices()
    if len(devs) < 2:
        # single-device session: collective path covered by dryrun sweep
        return
    from jax.sharding import PartitionSpec as P, NamedSharding
    mesh = jax.make_mesh((2,), ("m",))
    x = jax.ShapeDtypeStruct((4, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)

    def fn(x, w):
        def body(x, wi):
            return x @ wi, None
        return jax.lax.scan(body, x, w)[0]

    c = jax.jit(fn, in_shardings=(
        NamedSharding(mesh, P(None, None)),
        NamedSharding(mesh, P(None, None, "m"))),
        out_shardings=NamedSharding(mesh, P())).lower(x, w).compile()
    s = analyze(c.as_text())
    assert s["collective_bytes"] > 0
