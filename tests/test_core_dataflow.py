"""Core static-dataflow tests: operators, benchmarks, engine vs compiled."""
import numpy as np
import pytest

from repro.core import asm, library
from repro.core.compile import compile_dag_stream, compile_cyclic
from repro.core.engine import DataflowEngine, run_reference
from repro.core.graph import Graph, Op


# ---------------------------------------------------------------------------
# single-operator firing semantics
# ---------------------------------------------------------------------------
def _single(op, feeds, n_out=1):
    g = Graph(name=f"single_{op.name}")
    n_in, n_out_op = (len(feeds),
                      2 if op in (Op.COPY, Op.BRANCH) else 1)
    ins = list(feeds)
    outs = [f"z{i}" for i in range(n_out_op)]
    g.add(op, ins, outs)
    eng = DataflowEngine(g)
    return eng.run(feeds), outs


@pytest.mark.parametrize("op,a,b,expect", [
    (Op.ADD, 3, 4, 7), (Op.SUB, 9, 4, 5), (Op.MUL, 3, 4, 12),
    (Op.DIV, 9, 4, 2), (Op.AND, 6, 3, 2), (Op.OR, 6, 3, 7),
    (Op.XOR, 6, 3, 5), (Op.MAX, 6, 3, 6), (Op.MIN, 6, 3, 3),
    (Op.SHL, 3, 2, 12), (Op.SHR, 12, 2, 3),
    (Op.IFGT, 5, 3, 1), (Op.IFGE, 3, 3, 1), (Op.IFLT, 5, 3, 0),
    (Op.IFLE, 3, 3, 1), (Op.IFEQ, 3, 3, 1), (Op.IFDF, 3, 3, 0),
])
def test_primitive_ops(op, a, b, expect):
    res, outs = _single(op, {"a": [a], "b": [b]})
    assert int(res.outputs[outs[0]]) == expect
    assert res.counts[outs[0]] == 1


def test_copy_duplicates():
    res, outs = _single(Op.COPY, {"a": [42]})
    assert int(res.outputs["z0"]) == 42
    assert int(res.outputs["z1"]) == 42


def test_branch_routes_true_false():
    g = Graph()
    g.add(Op.BRANCH, ["a", "c"], ["t", "f"])
    eng = DataflowEngine(g)
    res = eng.run({"a": [10], "c": [1]})
    assert res.counts["t"] == 1 and res.counts["f"] == 0
    assert int(res.outputs["t"]) == 10
    res = eng.run({"a": [11], "c": [0]})
    assert res.counts["f"] == 1 and res.counts["t"] == 0
    assert int(res.outputs["f"]) == 11


def test_dmerge_selects_by_control():
    g = Graph()
    g.add(Op.DMERGE, ["a", "b", "c"], ["z"])
    eng = DataflowEngine(g)
    res = eng.run({"a": [10], "b": [20], "c": [1]})
    assert int(res.outputs["z"]) == 10
    # ctrl False selects b; a's token must remain unconsumed (static
    # semantics: the non-selected input is untouched)
    res = eng.run({"a": [10], "b": [20], "c": [0]})
    assert int(res.outputs["z"]) == 20
    assert res.counts["z"] == 1


def test_ndmerge_first_arrival_priority_a():
    g = Graph()
    g.add(Op.NDMERGE, ["a", "b"], ["z"])
    eng = DataflowEngine(g)
    res = eng.run({"a": [1, 2], "b": [50]})
    # stream: a wins ties; all three tokens eventually pass
    assert res.counts["z"] == 3


def test_one_token_per_arc_backpressure():
    # producer cannot overwrite a full arc: a slow consumer stalls the
    # pipeline but never loses/duplicates tokens.
    g = Graph()
    g.add(Op.ADD, ["a", "b"], ["s"])
    g.add(Op.ADD, ["s", "c"], ["z"])
    eng = DataflowEngine(g)
    k = 5
    res = eng.run({"a": np.arange(k), "b": np.ones(k, int),
                   "c": np.zeros(k, int)})
    assert res.counts["z"] == k
    assert int(res.outputs["z"]) == k  # last token: (k-1)+1+0


# ---------------------------------------------------------------------------
# assembler round-trip
# ---------------------------------------------------------------------------
def test_asm_parse_emit_roundtrip():
    g = asm.parse(library.FIBONACCI_ASM, name="fib")
    g2 = asm.parse(asm.emit(g), name="fib2")
    assert [(n.op, n.inputs, n.outputs) for n in g.nodes] == \
           [(n.op, n.inputs, n.outputs) for n in g2.nodes]
    assert g.consts == g2.consts


def test_asm_listing1_conventions():
    # paper Listing-1 style: inputs first then outputs, numbered lines
    g = asm.parse("""
        1. ndmerge s7, dadob, s1;
        2. add s1, dadoe, s11;
        3. gtdecider dadoa, s11, s5;
    """)
    assert g.nodes[0].op == Op.NDMERGE
    assert g.nodes[0].inputs == ("s7", "dadob")
    assert g.nodes[0].outputs == ("s1",)
    assert g.nodes[2].op == Op.IFGT


def test_asm_bad_arity_raises():
    with pytest.raises(SyntaxError):
        asm.parse("add s1, s2;")


# ---------------------------------------------------------------------------
# paper benchmarks: engine vs python reference vs compiled backends
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [0, 1, 2, 3, 7, 15])
def test_fibonacci(n):
    bench = library.fibonacci_graph()
    eng = DataflowEngine(bench.graph, dtype=np.int32)
    res = eng.run(bench.make_feeds(n))
    assert int(res.outputs["fibo"]) == int(bench.reference(n))
    assert int(res.outputs["pf"]) == n  # exit value of loop counter


@pytest.mark.parametrize("n", [0, 3, 10])
def test_fibonacci_compiled_matches_engine(n):
    bench = library.fibonacci_graph()
    eng = DataflowEngine(bench.graph, dtype=np.int32)
    run = compile_cyclic(bench.graph, dtype=np.int32)
    feeds = bench.make_feeds(n)
    r1, r2 = eng.run(feeds), run(feeds)
    assert int(r1.outputs["fibo"]) == int(r2.outputs["fibo"])
    assert r1.cycles == r2.cycles  # bit-identical cycle semantics
    assert r1.fired == r2.fired


def test_fibonacci_from_asm():
    g = asm.parse(library.FIBONACCI_ASM, name="fib_asm")
    bench = library.fibonacci_graph()
    eng = DataflowEngine(g, dtype=np.int32)
    res = eng.run(bench.make_feeds(10))
    assert int(res.outputs["fibo"]) == int(bench.reference(10))


@pytest.mark.parametrize("name", ["vector_sum", "max_vector", "dot_prod",
                                  "pop_count", "bubble_sort"])
def test_vector_benchmarks_engine(name):
    rng = np.random.default_rng(0)
    bench = library.BENCHES[name]() if name != "bubble_sort" \
        else library.bubble_sort_graph(6)
    n = sum(1 for a in bench.graph.input_arcs())
    if name == "dot_prod":
        a = rng.integers(0, 50, (1, n // 2))
        b = rng.integers(0, 50, (1, n // 2))
        feeds, ref = bench.make_feeds(a, b), bench.reference(a, b)
    elif name == "pop_count":
        x = rng.integers(0, 2**16, (4,))
        feeds, ref = bench.make_feeds(x), bench.reference(x)
    else:
        v = rng.integers(0, 100, (1, n))
        feeds, ref = bench.make_feeds(v), bench.reference(v)
    eng = DataflowEngine(bench.graph, dtype=np.int32)
    res = eng.run(feeds)
    if bench.out_arcs:
        got = np.array([int(res.outputs[a]) for a in bench.out_arcs])
        np.testing.assert_array_equal(got, np.asarray(ref).ravel())
    else:
        assert int(res.outputs[bench.out_arc]) == int(np.asarray(ref).ravel()[-1])


@pytest.mark.parametrize("name", ["vector_sum", "max_vector", "dot_prod",
                                  "pop_count"])
def test_vector_benchmarks_compiled_stream(name):
    rng = np.random.default_rng(1)
    bench = library.BENCHES[name]()
    k = 8
    if name == "dot_prod":
        n = len(bench.graph.input_arcs()) // 2
        a, b = rng.integers(0, 50, (k, n)), rng.integers(0, 50, (k, n))
        feeds, ref = bench.make_feeds(a, b), bench.reference(a, b)
    elif name == "pop_count":
        x = rng.integers(0, 2**16, (k,))
        feeds, ref = bench.make_feeds(x), bench.reference(x)
    else:
        n = len(bench.graph.input_arcs())
        v = rng.integers(0, 100, (k, n))
        feeds, ref = bench.make_feeds(v), bench.reference(v)
    fn = compile_dag_stream(bench.graph, dtype=np.int32)
    out = fn({k_: np.asarray(v_, np.int32) for k_, v_ in feeds.items()})
    np.testing.assert_array_equal(np.asarray(out[bench.out_arc]),
                                  np.asarray(ref))


def test_engine_streaming_pipelines_tokens():
    """Throughput: a deep fabric sustains ~1 token per 2 cycles (str/ack
    cadence), so streaming k tokens is far cheaper than k×latency."""
    bench = library.vector_sum_graph(16)
    eng = DataflowEngine(bench.graph, dtype=np.int32)
    one = eng.run(bench.make_feeds(np.ones((1, 16), int)))
    many = eng.run(bench.make_feeds(np.ones((32, 16), int)))
    assert many.counts["vsum"] == 32
    assert many.cycles < one.cycles + 2 * 32 + 4  # pipelined, not serial


# ---------------------------------------------------------------------------
# vectorized engine vs numpy reference engine (same cycle semantics)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("maker,args", [
    (library.fibonacci_graph, (9,)),
    (library.vector_sum_graph, None),
    (library.pop_count_graph
     if hasattr(library, "pop_count_graph") else library.popcount_graph,
     None),
])
def test_engine_matches_reference(maker, args):
    bench = maker() if maker is library.fibonacci_graph else maker(8)
    if args is not None:
        feeds = bench.make_feeds(*args)
    elif bench.graph.name.startswith("pop"):
        feeds = bench.make_feeds(np.array([1234, 65535, 0]))
    else:
        feeds = bench.make_feeds(np.arange(16).reshape(2, 8))
    r_jax = DataflowEngine(bench.graph, dtype=np.int32).run(feeds)
    r_np = run_reference(bench.graph, feeds, dtype=np.int32)
    assert r_jax.cycles == r_np.cycles
    assert r_jax.fired == r_np.fired
    for a in bench.graph.output_arcs():
        assert r_jax.counts[a] == r_np.counts[a]
        if r_np.counts[a]:
            np.testing.assert_array_equal(np.asarray(r_jax.outputs[a]),
                                          np.asarray(r_np.outputs[a]))


def test_tensor_tokens():
    """Arcs carry tensors (the 16-bit bus generalized); fabric semantics
    are unchanged."""
    g = Graph()
    g.add(Op.ADD, ["a", "b"], ["s"])
    g.add(Op.MUL, ["s", "c"], ["z"])
    eng = DataflowEngine(g, token_shape=(4,), dtype=np.float32)
    a = np.ones((1, 4), np.float32) * 3
    b = np.ones((1, 4), np.float32) * 4
    c = np.ones((1, 4), np.float32) * 2
    res = eng.run({"a": a, "b": b, "c": c})
    np.testing.assert_allclose(np.asarray(res.outputs["z"]), 14.0)


def test_resources_table():
    for name, mk in library.BENCHES.items():
        r = mk().graph.resources()
        assert r["nodes"] > 0 and r["arcs"] > 0 and r["lut_weight"] > 0
