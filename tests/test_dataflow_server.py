"""Continuous-batching server vs solo runs: the bit-identical property.

Acceptance property (ISSUE 2): for any mix of requests and admission
order, each request's EngineResult (out values, token counts, fired
count, cycles) from the continuous-batching server equals running that
request alone via DataflowEngine.run — across benches x K in {1, 4, 16}
x slots in {2, 8}, including mid-flight admissions and unequal stream
lengths.  Admissions happen only at block boundaries and every slot
carries its own cycle clock, so nothing a neighbouring slot does can
leak in (DESIGN.md §7).
"""
import functools

import numpy as np
import pytest

from repro.core import library
from repro.core.engine import DataflowEngine
from repro.serve.dataflow_server import (CACHE_STATS, DataflowServer,
                                         cached_engine, clear_engine_cache,
                                         graph_signature)
from repro.serve.types import Request

KS = [1, 4, 16]
SLOTS = [2, 8]


def _bench(name):
    # full-size graphs except bubble_sort (8 -> 6 keeps wall-time sane)
    return library.bubble_sort_graph(6) if name == "bubble_sort" \
        else library.BENCHES[name]()


def _mixed_feeds(name, bench, n, base_seed=0):
    """n requests with unequal stream lengths 1..8 (fibonacci: loop
    iteration counts), deterministic per index."""
    return [library.random_feeds(name, bench, 1 + (3 * i + base_seed) % 8,
                                 np.random.default_rng(base_seed + i))
            for i in range(n)]


@functools.lru_cache(maxsize=None)
def _eng_and_solos(name, K):
    """One engine + solo-run oracle per (bench, K), shared across the
    slots parametrization (jit compilations dominate the wall time)."""
    bench = _bench(name)
    eng = DataflowEngine(bench.graph, backend="xla", block_cycles=K)
    feeds = _mixed_feeds(name, bench, 6)
    solos = [eng.run(f) for f in feeds]
    return bench, eng, feeds, solos


def _check(got, want, tag):
    assert got.cycles == want.cycles, (tag, got.cycles, want.cycles)
    assert got.fired == want.fired, (tag, got.fired, want.fired)
    for a, c in want.counts.items():
        assert got.counts[a] == c, (tag, a)
        if c:
            assert int(np.asarray(got.outputs[a])) == \
                int(np.asarray(want.outputs[a])), (tag, a)


@functools.lru_cache(maxsize=None)
def _bench_dtype(name):
    return np.dtype(_bench(name).dtype)


@pytest.mark.parametrize("name", sorted(library.BENCHES))
@pytest.mark.parametrize("K", KS)
@pytest.mark.parametrize("slots", SLOTS)
def test_continuous_matches_solo_runs(name, K, slots):
    if _bench_dtype(name) != np.int32:
        pytest.skip(f"{name}: the resumable slot API is int32-only")
    bench, eng, feeds, solos = _eng_and_solos(name, K)
    srv = DataflowServer(bench.graph, slots=slots, engine=eng)
    # mid-flight admission: 3 requests up front, the rest arrive while
    # the fabric is running
    for f in feeds[:3]:
        srv.submit(f)
    got = srv.step() + srv.step()
    for f in feeds[3:]:
        srv.submit(f)
    got += srv.drain()
    got.sort(key=lambda r: r.uid)
    assert len(got) == len(feeds)
    for r, want in zip(got, solos):
        _check(r.engine, want, (name, K, slots, r.uid))


@pytest.mark.parametrize("name", ["fibonacci", "dot_prod", "pop_count"])
def test_continuous_matches_solo_runs_pallas(name):
    """Same property through the masked Pallas kernel (reduced matrix —
    interpret mode is slow on CPU)."""
    bench = _bench(name)
    eng = DataflowEngine(bench.graph, backend="pallas", block_cycles=4)
    feeds = _mixed_feeds(name, bench, 5, base_seed=3)
    solos = [eng.run(f) for f in feeds]
    srv = DataflowServer(bench.graph, slots=2, engine=eng)
    for f in feeds[:2]:
        srv.submit(f)
    got = srv.step()
    for f in feeds[2:]:
        srv.submit(f)
    got += srv.drain()
    got.sort(key=lambda r: r.uid)
    for r, want in zip(got, solos):
        _check(r.engine, want, (name, "pallas", r.uid))


def test_admission_order_does_not_change_results():
    """Permuting what rides alongside never changes a request's result."""
    bench = library.vector_sum_graph(8)
    eng = DataflowEngine(bench.graph, backend="xla", block_cycles=4)
    feeds = _mixed_feeds("vector_sum", bench, 6, base_seed=5)
    solos = [eng.run(f) for f in feeds]
    for order in ([0, 1, 2, 3, 4, 5], [5, 3, 1, 0, 2, 4]):
        srv = DataflowServer(bench.graph, slots=2, engine=eng)
        uids = {srv.submit(feeds[i]): i for i in order}
        for r in srv.drain():
            _check(r.engine, solos[uids[r.uid]], ("order", order, r.uid))


def test_active_mask_freezes_parked_slots():
    """A quiesced/free slot's registers stay bit-frozen while neighbours
    run (the per-slot clock gate of fire_block_batched_pallas)."""
    bench = library.popcount_graph(8)
    eng = DataflowEngine(bench.graph, backend="pallas", block_cycles=4)
    st = eng.init_state(2)
    st = eng.reset_slots(st, [0], [bench.make_feeds([3])])
    while not st.quiesced_slots():
        st = eng.step_block(st)
    frozen = [np.asarray(x)[0].copy()
              for x in (st.full, st.val, st.ptr, st.out_last, st.out_count)]
    st, [res0] = eng.harvest(st, [0])
    fired0, base0 = int(st.fired[0]), int(st.base[0])
    st = eng.reset_slots(st, [1], [bench.make_feeds([255, 16, 7])])
    for _ in range(5):
        st = eng.step_block(st)
    for name_, x, w in zip(("full", "val", "ptr", "out_last", "out_count"),
                           (st.full, st.val, st.ptr, st.out_last,
                            st.out_count), frozen):
        np.testing.assert_array_equal(np.asarray(x)[0], w, err_msg=name_)
    # the parked slot's clock did not advance while slot 1 ran 5 blocks
    assert int(st.fired[0]) == fired0 and int(st.base[0]) == base0
    assert int(st.fired[1]) > 0 and int(st.base[1]) == 5 * 4


def test_slot_lifecycle_errors():
    bench = library.vector_sum_graph(8)
    eng = DataflowEngine(bench.graph, backend="xla", block_cycles=4)
    st = eng.init_state(2)
    st = eng.reset_slots(st, [0], [_mixed_feeds("vector_sum", bench, 1)[0]])
    with pytest.raises(ValueError, match="unharvested"):
        eng.reset_slots(st, [0], [{}])
    with pytest.raises(ValueError, match="free"):
        eng.harvest(st, [1])
    ref_eng = DataflowEngine(bench.graph, backend="reference")
    with pytest.raises(ValueError, match="reference"):
        ref_eng.init_state(2)


def test_cap_truncated_requests_match_solo_runs():
    """A request that exhausts max_cycles un-quiesced is force-harvested
    with outputs/counts/fired bit-identical to a solo run under the same
    cap: heartbeat blocks shrink near the cap so the slot simulates
    EXACTLY max_cycles cycles (never a partial block past it)."""
    bench = library.BENCHES["fibonacci"]()
    eng = DataflowEngine(bench.graph, backend="xla", block_cycles=16,
                         max_cycles=10)
    feeds = [bench.make_feeds(1000), bench.make_feeds(2)]
    solos = [eng.run(f) for f in feeds]
    srv = DataflowServer(bench.graph, slots=2, engine=eng)
    uids = [srv.submit(f) for f in feeds]
    got = {r.uid: r for r in srv.drain()}
    assert sorted(got) == sorted(uids)
    for uid, want in zip(uids, solos):
        _check(got[uid].engine, want, ("cap", uid))


def test_step_block_rejects_zero_cycles():
    bench = library.vector_sum_graph(8)
    eng = DataflowEngine(bench.graph, backend="xla", block_cycles=4)
    st = eng.reset_slots(eng.init_state(1), [0],
                         [_mixed_feeds("vector_sum", bench, 1)[0]])
    with pytest.raises(ValueError, match="n_cycles"):
        eng.step_block(st, n_cycles=0)


def test_engine_validation_errors():
    from repro.core.compile import compile_graph
    bench = library.vector_sum_graph(8)
    with pytest.raises(ValueError, match="block_cycles"):
        compile_graph(bench.graph, backend="xla", block_cycles=0)
    with pytest.raises(ValueError, match="block_cycles"):
        DataflowEngine(bench.graph, block_cycles=0)
    eng = DataflowEngine(bench.graph, backend="xla")
    with pytest.raises(ValueError, match="feeds_batch is empty"):
        eng.run_batch([])


def _engine_stats():
    # CACHE_STATS also carries the live "plan" memo counters (PR 9);
    # these tests pin only the engine-cache event counts
    return {k: CACHE_STATS[k] for k in ("hits", "misses", "evictions")}


def test_plan_cache_shares_engines_across_requests():
    clear_engine_cache()
    g1 = library.vector_sum_graph(8).graph
    g2 = library.vector_sum_graph(8).graph      # same signature, new obj
    assert graph_signature(g1) == graph_signature(g2)
    e1 = cached_engine(g1, backend="xla", block_cycles=4)
    e2 = cached_engine(g2, backend="xla", block_cycles=4)
    assert e1 is e2
    assert _engine_stats() == {"hits": 1, "misses": 1, "evictions": 0}
    e3 = cached_engine(g1, backend="xla", block_cycles=8)  # new K -> miss
    assert e3 is not e1
    assert CACHE_STATS["misses"] == 2


def test_plan_cache_key_includes_shape_dtype_and_opt():
    """Regression: the cache key once omitted token_shape and dtype, so
    two servers over one fabric signature with different token shapes
    collided on a single compiled engine.  token_shape, dtype and the
    optimize flag all split the key now."""
    clear_engine_cache()
    g = library.vector_sum_graph(8).graph
    base = cached_engine(g, backend="xla", block_cycles=4)
    shaped = cached_engine(g, backend="xla", block_cycles=4,
                           token_shape=(4,))
    floated = cached_engine(g, backend="xla", block_cycles=4,
                            dtype=np.float32)
    opt = cached_engine(g, backend="xla", block_cycles=4, optimize=True)
    assert len({id(base), id(shaped), id(floated), id(opt)}) == 4
    assert CACHE_STATS["misses"] == 4 and CACHE_STATS["hits"] == 0
    assert shaped.token_shape == (4,)
    assert floated.dtype == np.float32
    assert opt.optimize and opt.p["class_slices"] is not None
    # and each variant is a hit the second time around
    assert cached_engine(g, backend="xla", block_cycles=4,
                         token_shape=(4,)) is shaped
    assert cached_engine(g, backend="xla", block_cycles=4,
                         optimize=True) is opt
    assert CACHE_STATS["hits"] == 2


def test_plan_cache_lru_eviction_order(monkeypatch):
    """LRU semantics under interleaved hits/misses/evictions: a hit
    refreshes recency, the oldest-unused entry is the eviction victim,
    and CACHE_STATS tracks all three event kinds exactly."""
    import repro.serve.dataflow_server as ds
    clear_engine_cache()
    monkeypatch.setattr(ds, "_ENGINE_CACHE_MAX", 2)
    g = library.vector_sum_graph(8).graph
    e1 = cached_engine(g, backend="xla", block_cycles=1)
    e2 = cached_engine(g, backend="xla", block_cycles=2)
    assert _engine_stats() == {"hits": 0, "misses": 2, "evictions": 0}
    # a hit refreshes e1's recency, making e2 the LRU victim
    assert cached_engine(g, backend="xla", block_cycles=1) is e1
    e3 = cached_engine(g, backend="xla", block_cycles=3)
    assert _engine_stats() == {"hits": 1, "misses": 3, "evictions": 1}
    # e1 survived the eviction (it was refreshed)...
    assert cached_engine(g, backend="xla", block_cycles=1) is e1
    assert CACHE_STATS["hits"] == 2
    # ...e2 did not: asking again recompiles (a miss), evicting e3
    assert cached_engine(g, backend="xla", block_cycles=2) is not e2
    assert _engine_stats() == {"hits": 2, "misses": 4, "evictions": 2}
    assert cached_engine(g, backend="xla", block_cycles=3) is not e3
    assert len(ds._ENGINE_CACHE) == 2


def test_server_optimized_matches_solo_dense_runs():
    """optimize=True on the server specializes the shared plan; every
    request's result stays bit-identical to a dense solo run."""
    bench = library.vector_sum_graph(8)
    dense = DataflowEngine(bench.graph, backend="xla", block_cycles=4)
    feeds = _mixed_feeds("vector_sum", bench, 5, base_seed=21)
    solos = [dense.run(f) for f in feeds]
    srv = DataflowServer(bench.graph, slots=2, block_cycles=4,
                         backend="xla", optimize=True)
    assert srv.engine.optimize
    uids = [srv.submit(f) for f in feeds]
    got = {r.uid: r for r in srv.drain()}
    for uid, want in zip(uids, solos):
        _check(got[uid].engine, want, ("opt-server", uid))


def test_metrics_account_for_queueing_and_residency():
    bench = library.vector_sum_graph(8)
    eng = DataflowEngine(bench.graph, backend="xla", block_cycles=4)
    srv = DataflowServer(bench.graph, slots=2, engine=eng)
    feeds = _mixed_feeds("vector_sum", bench, 5, base_seed=9)
    for f in feeds:
        srv.submit(f)
    results = sorted(srv.drain(), key=lambda r: r.uid)
    for r in results:
        m = r.metrics
        assert m.queue_wait_blocks == m.admitted_block - m.queued_block >= 0
        assert m.residency_blocks == r.engine.dispatches > 0
        assert m.residency_cycles == r.engine.cycles
        assert m.tokens_out == sum(r.engine.counts.values()) > 0
        assert m.finished_block > m.admitted_block >= 0
    # the first two admissions happen before any block ran
    assert sorted(m.queue_wait_blocks
                  for m in (r.metrics for r in results))[:2] == [0, 0]


def test_submit_accepts_request_objects_and_dicts():
    bench = library.vector_sum_graph(8)
    eng = DataflowEngine(bench.graph, backend="xla", block_cycles=4)
    srv = DataflowServer(bench.graph, slots=2, engine=eng)
    feeds = _mixed_feeds("vector_sum", bench, 2)
    uid_a = srv.submit(feeds[0])                         # bare dict
    uid_b = srv.submit(Request(uid=77, feeds=feeds[1]))  # dataclass
    assert uid_b == 77 and uid_a != uid_b
    with pytest.raises(ValueError, match="no feeds"):
        srv.submit(Request(uid=78, prompt=np.array([1, 2])))
    with pytest.raises(ValueError, match="in flight"):
        srv.submit(Request(uid=77, feeds=feeds[0]))      # duplicate uid
    # auto uids skip caller-claimed ones instead of colliding
    srv2 = DataflowServer(bench.graph, slots=2, engine=eng)
    srv2.submit(Request(uid=1, feeds=feeds[0]))
    assert srv2.submit(feeds[1]) == 2
    results = srv.drain()
    assert sorted(r.uid for r in results) == sorted([uid_a, uid_b])


def test_submit_rejects_unknown_feed_arcs_before_queueing():
    """A bad request is rejected at submit() and cannot poison the
    fused admission round of its co-batched neighbours."""
    bench = library.vector_sum_graph(8)
    eng = DataflowEngine(bench.graph, backend="xla", block_cycles=4)
    srv = DataflowServer(bench.graph, slots=2, engine=eng)
    good = srv.submit(_mixed_feeds("vector_sum", bench, 1)[0])
    with pytest.raises(ValueError, match="non-input arcs"):
        srv.submit({"typo_arc": [1]})
    results = srv.drain()          # the good request still completes
    assert [r.uid for r in results] == [good]


def test_server_rejects_engine_for_other_fabric():
    eng = DataflowEngine(library.vector_sum_graph(8).graph,
                         backend="xla", block_cycles=4)
    with pytest.raises(ValueError, match="different fabric"):
        DataflowServer(library.popcount_graph(8).graph, slots=2,
                       engine=eng)
