"""Fault-tolerance layer of the serving stack (DESIGN.md §11, PR 6).

The acceptance property: under a seeded
:class:`~repro.serve.faults.FaultPlan` — dispatch failures, wedged
slots, poisoned feeds — every submitted request receives exactly one
:class:`~repro.serve.types.Result` (value / truncated / expired /
wedged / typed error), ``step()``/``drain()`` never raise a
workload-induced error, and unfaulted co-resident requests stay
bit-identical to solo ``DataflowEngine.run`` results.
"""
import numpy as np
import pytest

from repro.core import library
from repro.core.engine import DataflowEngine, run_reference
from repro.serve.admission import DroppedError, FairQueue, Rejected
from repro.serve.dataflow_server import DataflowServer
from repro.serve.faults import FaultPlan, InjectedFault
from repro.serve.types import Request


@pytest.fixture()
def bench():
    return library.vector_sum_graph(8)


def _feeds(bench, k, seed=0):
    return library.random_feeds("vector_sum", bench,
                                k, np.random.default_rng(seed))


def _same(got, want, tag=""):
    assert got.cycles == want.cycles, tag
    assert got.fired == want.fired, tag
    assert got.counts == want.counts, tag
    for a, c in want.counts.items():
        if c:
            assert int(np.asarray(got.outputs[a])) == \
                int(np.asarray(want.outputs[a])), (tag, a)


# ---------------------------------------------------------------------------
# bounded admission
# ---------------------------------------------------------------------------
def test_reject_policy_returns_typed_rejection(bench):
    srv = DataflowServer(bench.graph, slots=1, block_cycles=4,
                         max_queue=2, policy="reject")
    assert srv.submit(_feeds(bench, 2, 0)) == 1
    assert srv.submit(_feeds(bench, 2, 1)) == 2
    rej = srv.submit(Request(uid=99, feeds=_feeds(bench, 2, 2),
                             tenant="t9"))
    assert isinstance(rej, Rejected) and not rej     # falsy by design
    assert rej.uid == 99 and rej.queue_depth == 2 and rej.tenant == "t9"
    # the rejected request was never enqueued: exactly 2 results
    results = srv.drain()
    assert sorted(r.uid for r in results) == [1, 2]
    assert all(r.status == "ok" for r in results)
    # after the drain there is room again
    assert srv.submit(Request(uid=99, feeds=_feeds(bench, 2, 2))) == 99


def test_block_policy_applies_backpressure(bench):
    srv = DataflowServer(bench.graph, slots=2, block_cycles=4,
                         max_queue=1, policy="block")
    uids = [srv.submit(_feeds(bench, 1 + i % 3, i)) for i in range(6)]
    # blocking submits pumped heartbeats: some requests already finished
    # out-of-band and surface through step()/drain()
    results = {r.uid: r for r in srv.drain()}
    assert sorted(results) == sorted(uids)
    assert all(r.status == "ok" for r in results.values())
    assert srv.pending == 0


def test_drop_oldest_policy_answers_the_victim(bench):
    srv = DataflowServer(bench.graph, slots=1, block_cycles=4,
                         max_queue=2, policy="drop-oldest")
    u1 = srv.submit(Request(uid=1, feeds=_feeds(bench, 2, 0), tenant="a"))
    u2 = srv.submit(Request(uid=2, feeds=_feeds(bench, 2, 1), tenant="a"))
    u3 = srv.submit(Request(uid=3, feeds=_feeds(bench, 2, 2), tenant="b"))
    assert (u1, u2, u3) == (1, 2, 3)
    # tenant "a" is the most backlogged -> its oldest (uid 1) is evicted
    results = {r.uid: r for r in srv.drain()}
    assert sorted(results) == [1, 2, 3]
    assert isinstance(results[1].error, DroppedError)
    assert results[1].status == "error"
    assert results[1].metrics.slot == -1          # never reached a slot
    assert results[2].status == "ok" and results[3].status == "ok"
    assert any(e["kind"] == "drop-oldest" and e["uid"] == 1
               for e in srv.events)


def test_fair_queue_round_robins_across_tenants():
    q = FairQueue()
    for uid, t in [(1, "a"), (2, "a"), (3, "a"), (4, "b"), (5, None)]:
        q.push(Request(uid=uid, feeds={}, tenant=t))
    assert len(q) == 5
    assert [q.pop().uid for _ in range(5)] == [1, 4, 5, 2, 3]
    with pytest.raises(IndexError):
        q.pop()


def test_fairness_one_tenant_cannot_starve_another(bench):
    srv = DataflowServer(bench.graph, slots=1, block_cycles=4)
    for i in range(5):                       # tenant "flood" queues 5
        srv.submit(Request(uid=10 + i, feeds=_feeds(bench, 2, i),
                           tenant="flood"))
    srv.submit(Request(uid=1, feeds=_feeds(bench, 2, 9), tenant="solo"))
    order = [r.uid for r in srv.drain()]
    # round-robin: solo's single request rides the second admission,
    # not behind all five of flood's
    assert order.index(1) <= 1


# ---------------------------------------------------------------------------
# deadlines and budgets
# ---------------------------------------------------------------------------
def test_deadline_expires_queued_request_without_a_slot(bench):
    srv = DataflowServer(bench.graph, slots=1, block_cycles=1)
    srv.submit(Request(uid=1, feeds=_feeds(bench, 8, 0)))    # hogs the slot
    srv.submit(Request(uid=2, feeds=_feeds(bench, 2, 1),
                       deadline_blocks=2))
    results = {r.uid: r for r in srv.drain()}
    assert results[2].status == "expired"
    assert results[2].metrics.slot == -1
    assert results[2].metrics.admitted_block == -1
    assert results[2].engine is None
    assert results[1].status == "ok"


def test_deadline_expires_resident_request_with_partial_results(bench):
    srv = DataflowServer(bench.graph, slots=2, block_cycles=1)
    srv.submit(Request(uid=1, feeds=_feeds(bench, 8, 0),
                       deadline_blocks=3))
    srv.submit(Request(uid=2, feeds=_feeds(bench, 2, 1)))
    results = {r.uid: r for r in srv.drain()}
    assert results[1].status == "expired" and results[1].metrics.expired
    assert results[1].metrics.slot >= 0          # it was resident
    assert results[1].engine is not None         # partial results delivered
    assert results[1].engine.cycles < 20
    # the co-resident request is untouched
    _same(results[2].engine,
          DataflowEngine(bench.graph, backend="xla",
                         block_cycles=1).run(_feeds(bench, 2, 1)))


def test_per_request_max_cycles_matches_solo_capped_run(bench):
    feeds = _feeds(bench, 8, 0)
    solo = DataflowEngine(bench.graph, backend="xla", block_cycles=4,
                          max_cycles=6).run(feeds)
    srv = DataflowServer(bench.graph, slots=2, block_cycles=4)
    srv.submit(Request(uid=1, feeds=feeds, max_cycles=6))
    srv.submit(Request(uid=2, feeds=_feeds(bench, 2, 1)))    # co-resident
    results = {r.uid: r for r in srv.drain()}
    assert results[1].status == "truncated"
    _same(results[1].engine, solo, "per-request cap")
    assert results[2].status == "ok"


# ---------------------------------------------------------------------------
# wedged-slot watchdog
# ---------------------------------------------------------------------------
def test_watchdog_harvests_wedged_slot(bench):
    plan = FaultPlan(wedge_uids={1})
    srv = DataflowServer(bench.graph, slots=2, block_cycles=4,
                         wedge_timeout_blocks=3, faults=plan)
    srv.submit(_feeds(bench, 2, 0))              # uid 1: wedged
    srv.submit(_feeds(bench, 3, 1))              # uid 2: clean
    results = {r.uid: r for r in srv.drain()}
    assert results[1].status == "wedged" and results[1].metrics.wedged
    assert results[2].status == "ok"
    # the wedge suppressed the *signal*, not the computation: the
    # harvested values still equal a solo run, and the slot was freed
    eng = DataflowEngine(bench.graph, backend="xla", block_cycles=4)
    _same(results[1].engine, eng.run(_feeds(bench, 2, 0)), "wedged")
    _same(results[2].engine, eng.run(_feeds(bench, 3, 1)), "clean")
    assert not srv.state.active.any() and srv.pending == 0


# ---------------------------------------------------------------------------
# retry / degradation chain
# ---------------------------------------------------------------------------
def test_transient_dispatch_fault_is_retried(bench):
    plan = FaultPlan(dispatch_fail_blocks={0, 1}, transient_attempts=2)
    srv = DataflowServer(bench.graph, slots=2, block_cycles=4,
                         max_retries=3, faults=plan)
    srv.submit(_feeds(bench, 2, 0))
    results = {r.uid: r for r in srv.drain()}
    assert results[1].status == "ok"
    assert results[1].metrics.retries >= 2
    assert not results[1].metrics.degraded       # retries never degrade
    assert any(e["kind"] == "dispatch-retry" for e in srv.events)
    _same(results[1].engine,
          DataflowEngine(bench.graph, backend="xla",
                         block_cycles=4).run(_feeds(bench, 2, 0)))


def test_persistent_fault_degrades_pallas_to_xla(bench):
    plan = FaultPlan(persistent_backends={"pallas"})
    srv = DataflowServer(bench.graph, slots=2, block_cycles=4,
                         backend="pallas", max_retries=1, faults=plan)
    feeds = [_feeds(bench, 2, 0), _feeds(bench, 3, 1)]
    for f in feeds:
        srv.submit(f)
    results = {r.uid: r for r in srv.drain()}
    assert srv.backend == "xla" and srv.degraded
    eng = DataflowEngine(bench.graph, backend="xla", block_cycles=4)
    for uid, f in zip((1, 2), feeds):
        r = results[uid]
        assert r.status == "ok"
        assert r.metrics.degraded and r.metrics.backend == "xla"
        _same(r.engine, eng.run(f), ("degraded", uid))
    kinds = [e["kind"] for e in srv.events]
    assert "degrade" in kinds and "degrade-to" in kinds


def test_persistent_fault_degrades_xla_to_reference(bench):
    plan = FaultPlan(persistent_backends={"xla"},
                     persistent_from_block=1)
    srv = DataflowServer(bench.graph, slots=2, block_cycles=4,
                         backend="xla", max_retries=1, faults=plan)
    feeds = [_feeds(bench, 2, 0), _feeds(bench, 3, 1), _feeds(bench, 4, 2)]
    for f in feeds:
        srv.submit(f)
    results = {r.uid: r for r in srv.drain()}
    assert srv.backend == "reference"
    assert sorted(results) == [1, 2, 3]
    for uid, f in zip((1, 2, 3), feeds):
        r = results[uid]
        assert r.status == "ok" and r.metrics.backend == "reference"
        _same(r.engine, run_reference(bench.graph, f), ("reference", uid))
    # the degraded server still accepts and answers new work
    uid = srv.submit(_feeds(bench, 2, 7))
    again = {r.uid: r for r in srv.drain()}
    assert again[uid].status == "ok"


def test_compile_fault_falls_back_at_construction(bench):
    plan = FaultPlan(compile_fail={"pallas"})
    srv = DataflowServer(bench.graph, slots=2, block_cycles=4,
                         backend="pallas", faults=plan)
    assert srv.backend == "xla" and srv.degraded
    srv.submit(_feeds(bench, 2, 0))
    results = srv.drain()
    assert results[0].status == "ok" and results[0].metrics.degraded
    assert any(e["kind"] == "compile-degrade" and e["backend"] == "pallas"
               for e in srv.events)


def test_reference_mode_server_and_per_request_errors(bench):
    plan = FaultPlan(reference_fail_uids={2})
    srv = DataflowServer(bench.graph, slots=2, backend="reference",
                         faults=plan)
    assert srv.backend == "reference"
    feeds = [_feeds(bench, 2, 0), _feeds(bench, 3, 1), _feeds(bench, 4, 2)]
    for f in feeds:
        srv.submit(f)
    results = {r.uid: r for r in srv.drain()}
    assert sorted(results) == [1, 2, 3]
    # the faulted request is *answered* with a typed error; its
    # neighbours compute normally
    assert results[2].status == "error"
    assert isinstance(results[2].error, InjectedFault)
    _same(results[1].engine, run_reference(bench.graph, feeds[0]))
    _same(results[3].engine, run_reference(bench.graph, feeds[2]))


# ---------------------------------------------------------------------------
# poisoned feeds
# ---------------------------------------------------------------------------
def test_poisoned_feeds_do_not_perturb_neighbours(bench):
    plan = FaultPlan(poison_uids={2})
    srv = DataflowServer(bench.graph, slots=3, block_cycles=4,
                         faults=plan)
    feeds = [_feeds(bench, 3, i) for i in range(3)]
    for f in feeds:
        srv.submit({a: np.array(v) for a, v in f.items()})
    results = {r.uid: r for r in srv.drain()}
    eng = DataflowEngine(bench.graph, backend="xla", block_cycles=4)
    # clean neighbours: bit-identical to solo runs on the clean feeds
    _same(results[1].engine, eng.run(feeds[0]), "clean 1")
    _same(results[3].engine, eng.run(feeds[2]), "clean 3")
    # the poisoned request computes deterministically over the poisoned
    # feeds (wraparound is the ALU contract) — compare against a solo
    # run over the same poison (poison() is idempotent)
    _same(results[2].engine, eng.run(plan.poison(feeds[1], 2)), "poisoned")
    assert ("poison", 2) in plan.log


# ---------------------------------------------------------------------------
# satellite bugfixes
# ---------------------------------------------------------------------------
def test_submit_rejects_missing_input_arcs(bench):
    srv = DataflowServer(bench.graph, slots=1)
    feeds = _feeds(bench, 2, 0)
    missing_arc = sorted(feeds)[0]
    bad = {a: v for a, v in feeds.items() if a != missing_arc}
    with pytest.raises(ValueError, match=missing_arc):
        srv.submit(bad)
    assert srv.pending == 0 and not srv._queued_at   # nothing half-queued
    srv.submit(feeds)                                # full feeds still fine
    assert srv.drain()[0].status == "ok"


def test_harvest_accounting_is_strict(bench):
    """The submit-time accounting for a resident uid must exist at
    harvest: a silent default would mask bookkeeping corruption, so the
    pop is strict (regression for the `.pop(uid, admitted)` fallback)."""
    srv = DataflowServer(bench.graph, slots=1, block_cycles=4)
    uid = srv.submit(_feeds(bench, 2, 0))
    srv.step()                              # admit + first block
    del srv._queued_at[uid]                 # corrupt the books
    with pytest.raises(KeyError):
        srv.drain()
