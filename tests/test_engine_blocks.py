"""Block-fused + batched engine executors vs the numpy reference oracle.

Acceptance property (ISSUE 1): for every graph in library.BENCHES, the
block-fused engine (K cycles per dispatch) and the batched stream
executor (B independent streams through one fabric) produce outputs,
drain counts, firing totals AND cycle counts bit-identical to
run_reference — including streams of unequal length within a batch.
"""
import numpy as np
import pytest

from repro.core import library
from repro.core.compile import compile_graph
from repro.core.engine import DataflowEngine, run_reference

KS = [1, 4, 16]
BACKENDS = ["xla", "pallas"]


def _bench(name):
    # full-size graphs except bubble_sort (8 -> 6 keeps the 112-node
    # fabric's test wall-time sane; the schema is identical)
    return library.bubble_sort_graph(6) if name == "bubble_sort" \
        else library.BENCHES[name]()


def _bench_for(name, backend):
    """Bench + execution dtype; skip executor/dtype combos that cannot
    exist (the pallas kernels are scalar-int32-only)."""
    bench = _bench(name)
    dt = np.dtype(bench.dtype)
    if backend == "pallas" and dt != np.int32:
        pytest.skip(f"{name} runs at {dt}; pallas is int32-only")
    return bench, dt


def _feeds(name, bench, k, seed):
    return library.random_feeds(name, bench, k,
                                np.random.default_rng(seed))


def _check(got, want, tag):
    assert got.cycles == want.cycles, (tag, got.cycles, want.cycles)
    assert got.fired == want.fired, (tag, got.fired, want.fired)
    for a, c in want.counts.items():
        assert got.counts[a] == c, (tag, a)
        if c:
            assert np.asarray(got.outputs[a]).item() == \
                np.asarray(want.outputs[a]).item(), (tag, a)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name", sorted(library.BENCHES))
def test_block_fused_matches_reference(name, backend):
    bench, dt = _bench_for(name, backend)
    feeds = _feeds(name, bench, 5, seed=0)
    want = run_reference(bench.graph, feeds, dtype=dt)
    for K in KS:
        eng = DataflowEngine(bench.graph, dtype=dt, backend=backend,
                             block_cycles=K)
        _check(eng.run(feeds), want, (name, backend, K))


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name", sorted(library.BENCHES))
def test_batched_streams_match_reference(name, backend):
    bench, dt = _bench_for(name, backend)
    for B in (1, 8):
        # unequal stream lengths: stream b carries 1 + (b % 4) tokens
        # (loop fabrics: the trip count varies per stream instead)
        lens = [1 + (b % 4) for b in range(B)]
        fb = [_feeds(name, bench, k, seed=10 + b)
              for b, k in enumerate(lens)]
        wants = [run_reference(bench.graph, f, dtype=dt) for f in fb]
        eng = DataflowEngine(bench.graph, dtype=dt, backend=backend,
                             block_cycles=8)
        got = eng.run_batch(fb)
        assert len(got) == B
        for b in range(B):
            _check(got[b], wants[b], (name, backend, B, b))


def test_batched_pallas_kernel_matches_vmap():
    """The explicit batch grid in the Pallas kernel == vmap over the
    fused block step (the two batching implementations agree)."""
    import jax
    import jax.numpy as jnp
    from repro.core.engine import pack_feeds
    from repro.kernels import ops, ref

    bench = library.popcount_graph(8)
    tables, bstep = ops.make_block_step(bench.graph, 8, batched=True)
    p = tables["plan"]
    B, L = 4, 4   # L = longest stream (stream b carries 1+b tokens)
    packed = [pack_feeds(p["input_arcs"],
                         _feeds("pop_count", bench, 1 + b, seed=b),
                         pad_rows=1, min_len=L) for b in range(B)]
    fv = jnp.asarray(np.stack([x for x, _ in packed]))
    fl = jnp.asarray(np.stack([x for _, x in packed]))
    A2 = p["A"] + 2
    n_in = max(len(p["input_arcs"]), 1)
    n_out = max(len(p["output_arcs"]), 1)
    full = np.zeros((B, A2), np.int32)
    val = np.zeros((B, A2), np.int32)
    full[:, p["FULL_PAD"]] = 1
    for a, v in bench.graph.consts.items():
        full[:, p["aidx"][a]] = 1
        val[:, p["aidx"][a]] = int(v)
    state = (jnp.asarray(full), jnp.asarray(val),
             jnp.zeros((B, n_in), jnp.int32),
             jnp.zeros((B, n_out), jnp.int32),
             jnp.zeros((B, n_out), jnp.int32))
    active = jnp.ones((B,), jnp.int32)
    got = bstep(fv, fl, *state, active)
    want = jax.vmap(
        lambda fv1, fl1, *s: ref.fire_block_ref(
            tables, fv1, fl1, *s, n_cycles=8))(fv, fl, *state)
    for g, w in zip(got[:5], want[:5]):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    np.testing.assert_array_equal(np.asarray(got[5]).ravel(),
                                  np.asarray(want[5]).ravel())
    np.testing.assert_array_equal(np.asarray(got[6]).ravel(),
                                  np.asarray(want[6]).ravel())


def test_block_fusion_cuts_dispatches_10x():
    """K=16 fused blocks need >= 10x fewer device dispatches than the
    seed per-cycle kernel driver (one dispatch per cycle)."""
    bench = library.fibonacci_graph()
    feeds = bench.make_feeds(30)
    per_cycle_dispatches = run_reference(bench.graph, feeds).cycles
    eng = DataflowEngine(bench.graph, backend="pallas", block_cycles=16)
    res = eng.run(feeds)
    assert res.dispatches * 10 <= per_cycle_dispatches, \
        (res.dispatches, per_cycle_dispatches)


def test_max_cycles_cutoff_is_exact():
    """Truncating a still-active fabric mid-block simulates EXACTLY
    max_cycles cycles: fired/counts bit-identical to the per-cycle
    reference, for caps both off and on block boundaries."""
    bench = library.fibonacci_graph()
    feeds = bench.make_feeds(1000)   # still running at every cap below
    for cap in (50, 48, 7):
        want = run_reference(bench.graph, feeds, max_cycles=cap)
        for backend in BACKENDS:
            eng = DataflowEngine(bench.graph, backend=backend,
                                 block_cycles=16)
            _check(eng.run(feeds, max_cycles=cap), want,
                   ("cutoff", backend, cap))


def test_compile_graph_backend_dispatch():
    bench = library.fibonacci_graph()
    feeds = bench.make_feeds(7)
    want = run_reference(bench.graph, feeds)
    for backend in ("xla", "pallas", "reference"):
        run = compile_graph(bench.graph, backend=backend, block_cycles=4)
        _check(run(feeds), want, backend)
        assert hasattr(run.engine, "run_batch")


def test_run_batch_matches_solo_runs():
    """A stream's result is independent of what rides alongside it."""
    bench = library.vector_sum_graph(8)
    rng = np.random.default_rng(3)
    fb = [bench.make_feeds(rng.integers(0, 99, (k, 8)))
          for k in (4, 1, 7)]
    eng = DataflowEngine(bench.graph, backend="xla", block_cycles=4)
    batched = eng.run_batch(fb)
    for f, got in zip(fb, batched):
        _check(got, eng.run(f), "solo-vs-batch")
