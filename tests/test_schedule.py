"""Static firing schedules (DESIGN.md §13): scheduled execution must be
bit-identical to the dynamic engine and the run_reference oracle in
every observable — values, token counts, cycles, node_fires, §12
profiles, and per-arc registers at block boundaries."""
import dataclasses

import numpy as np
import pytest

from repro.core import library, passes
from repro.core.compile import OPTIMIZE_LEVELS, compile
from repro.core.engine import DataflowEngine, run_reference
from repro.core.graph import Graph, Op
from repro.core.schedule import schedulable, schedule_blockers

CAP = 4096
SCHED_BENCHES = ("fir", "dot_prod", "horner", "bubble_sort")


def _feeds(name, bench, k, seed=0):
    return library.random_feeds(name, bench, k,
                                np.random.default_rng(seed))


def _check(tag, ref, got, profile=False):
    assert got.cycles == ref.cycles, (tag, got.cycles, ref.cycles)
    assert got.fired == ref.fired, (tag, got.fired, ref.fired)
    assert got.counts == ref.counts, tag
    for a, c in ref.counts.items():
        if c:
            assert np.asarray(got.outputs[a]).tobytes() == \
                np.asarray(ref.outputs[a]).tobytes(), (tag, a)
    if profile:
        assert np.array_equal(got.node_fires, ref.node_fires), tag
        _check_profile(tag, ref.profile, got.profile)


def _check_profile(tag, ref, got, with_dispatches=False):
    for f in dataclasses.fields(ref):
        if f.name == "dispatches" and not with_dispatches:
            continue    # run(): oracle profiles carry 0, engines 1
        x, y = getattr(ref, f.name), getattr(got, f.name)
        if isinstance(x, np.ndarray):
            assert np.array_equal(x, y), (tag, f.name)
        else:
            assert x == y, (tag, f.name, x, y)


# ---------------------------------------------------------------------------
# the property matrix: benches x backends x K, bit-identity vs oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", SCHED_BENCHES)
@pytest.mark.parametrize("backend", ("reference", "xla", "pallas"))
def test_scheduled_matches_oracle(name, backend):
    bench = library.BENCHES[name]()
    g, _ = passes.optimize_graph(bench.graph)
    assert schedulable(g), f"{name} should be statically schedulable"
    feeds = _feeds(name, bench, 12)
    ref = run_reference(g, feeds, max_cycles=CAP, profile=True)
    for K in (1, 4, 16):
        eng = DataflowEngine(g, backend=backend, block_cycles=K,
                             max_cycles=CAP, schedule=True, profile=True)
        _check((name, backend, K), ref, eng.run(feeds), profile=True)


@pytest.mark.parametrize("dtype", (np.uint32, np.float32))
def test_scheduled_dtypes(dtype):
    bench = library.BENCHES["fir"]()
    g, _ = passes.optimize_graph(bench.graph, dtype=np.dtype(dtype))
    feeds = _feeds("fir", bench, 12, seed=3)
    ref = run_reference(g, feeds, dtype=dtype, max_cycles=CAP)
    eng = DataflowEngine(g, dtype=dtype, backend="xla", block_cycles=4,
                         max_cycles=CAP, schedule=True)
    _check(("fir", dtype), ref, eng.run(feeds))


def test_scheduled_max_cycles_truncation():
    bench = library.BENCHES["fir"]()
    g, _ = passes.optimize_graph(bench.graph)
    feeds = _feeds("fir", bench, 32, seed=5)
    for mc in (3, 17, 40):
        ref = run_reference(g, feeds, max_cycles=mc)
        for backend in ("reference", "xla", "pallas"):
            eng = DataflowEngine(g, backend=backend, block_cycles=4,
                                 max_cycles=mc, schedule=True)
            _check(("trunc", backend, mc), ref, eng.run(feeds))


def test_scheduled_run_batch():
    bench = library.BENCHES["dot_prod"]()
    g, _ = passes.optimize_graph(bench.graph)
    same = [_feeds("dot_prod", bench, 8, seed=s) for s in range(3)]
    mixed = [_feeds("dot_prod", bench, k, seed=k) for k in (4, 8, 2)]
    for lbl, fb in (("same", same), ("mixed", mixed)):
        refs = [run_reference(g, f, max_cycles=CAP) for f in fb]
        for backend in ("xla", "pallas"):
            eng = DataflowEngine(g, backend=backend, block_cycles=4,
                                 max_cycles=CAP, schedule=True)
            for i, got in enumerate(eng.run_batch(fb)):
                _check((lbl, backend, i), refs[i], got)


def test_free_running_fabric_schedules():
    """A const-fed fabric never quiesces: the plan locks onto a
    free-running period and the scheduled run truncates at max_cycles
    exactly like the dynamic engine."""
    g = Graph(name="free_run")
    g.add(Op.ADD, ["c1", "c2"], ["z"])
    g.const("c1", 3)
    g.const("c2", 4)
    assert schedulable(g)
    ref = run_reference(g, {}, max_cycles=41)
    assert ref.cycles == 41     # never quiesces
    for backend in ("reference", "xla", "pallas"):
        eng = DataflowEngine(g, backend=backend, block_cycles=4,
                             max_cycles=41, schedule=True)
        _check(("free", backend), ref, eng.run({}))


# ---------------------------------------------------------------------------
# schedulability gate
# ---------------------------------------------------------------------------
def test_schedule_true_raises_on_control_graph():
    bench = library.BENCHES["fibonacci"]()
    blockers = schedule_blockers(bench.graph)
    assert blockers
    with pytest.raises(ValueError) as ei:
        DataflowEngine(bench.graph, schedule=True)
    for b in blockers:      # the error must name every blocker
        assert b in str(ei.value)
    # "auto" on the same fabric silently runs dynamic, bit-identically
    feeds = _feeds("fibonacci", bench, 8)
    eng = DataflowEngine(bench.graph, schedule="auto", max_cycles=CAP)
    assert not eng._sched_on
    _check(("fib", "auto"),
           run_reference(bench.graph, feeds, max_cycles=CAP),
           eng.run(feeds))


def test_schedule_arg_validated():
    bench = library.BENCHES["fir"]()
    with pytest.raises(ValueError):
        DataflowEngine(bench.graph, schedule="yes")


# ---------------------------------------------------------------------------
# the slot API: block-boundary state + clock parity vs the dynamic engine
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ("xla", "pallas"))
def test_slot_parity_with_dynamic(backend):
    import jax
    bench = library.BENCHES["fir"]()
    g, _ = passes.optimize_graph(bench.graph)
    feeds = [_feeds("fir", bench, k, seed=k) for k in (8, 16, 4)]
    for K in (1, 4, 16):
        dyn = DataflowEngine(g, backend=backend, block_cycles=K,
                             max_cycles=CAP, profile=True)
        sch = DataflowEngine(g, backend=backend, block_cycles=K,
                             max_cycles=CAP, profile=True, schedule=True)
        sd = dyn.reset_slots(dyn.init_state(3), [0, 1, 2], feeds)
        ss = sch.reset_slots(sch.init_state(3), [0, 1, 2], feeds)
        for blk in range(12):
            sd = dyn.step_block(sd)
            ss = sch.step_block(ss)
            # per-arc registers at block boundaries — bit-identical
            for fld in ("full", "val", "ptr", "out_last", "out_count"):
                a, b = jax.device_get((getattr(sd, fld),
                                       getattr(ss, fld)))
                assert np.array_equal(a, b), (backend, K, blk, fld)
            # per-slot clocks advance by schedule position
            for fld in ("base", "last", "fired", "quiesced",
                        "dispatches", "stalled"):
                assert np.array_equal(getattr(sd, fld),
                                      getattr(ss, fld)), \
                    (backend, K, blk, fld)
            if sd.quiesced.all():
                break
        sd, rd = dyn.harvest(sd, [0, 1, 2])
        ss, rs = sch.harvest(ss, [0, 1, 2])
        for i, (r, s) in enumerate(zip(rd, rs)):
            assert r.cycles == s.cycles and r.fired == s.fired
            assert r.counts == s.counts
            assert np.array_equal(r.node_fires, s.node_fires)
            _check_profile((backend, K, i), r.profile, s.profile,
                           with_dispatches=True)
        # slot reuse: readmit on a harvested slot rebinds its plan
        f2 = [_feeds("fir", bench, 6, seed=99)]
        sd = dyn.reset_slots(sd, [1], f2)
        ss = sch.reset_slots(ss, [1], f2)
        while not sd.quiesced[sd.active > 0].all():
            sd = dyn.step_block(sd)
            ss = sch.step_block(ss)
        sd, rd = dyn.harvest(sd, [1])
        ss, rs = sch.harvest(ss, [1])
        assert rd[0].cycles == rs[0].cycles
        assert rd[0].counts == rs[0].counts
        _check_profile((backend, K, "readmit"), rd[0].profile,
                       rs[0].profile, with_dispatches=True)


# ---------------------------------------------------------------------------
# compile() + serve-layer integration
# ---------------------------------------------------------------------------
def test_compile_sched_level():
    assert "sched" in OPTIMIZE_LEVELS
    bench = library.BENCHES["fir"]()
    feeds = _feeds("fir", bench, 8, seed=2)
    run = compile(bench.graph, backend="xla", optimize="sched",
                  max_cycles=CAP)
    assert run.engine._sched_on
    ref = run_reference(passes.optimize_graph(bench.graph)[0], feeds,
                        max_cycles=CAP)
    _check(("compile", "sched"), ref, run(feeds))
    # cyclic/control-bearing fabrics fall back to the dynamic engine
    gcd = library.BENCHES["gcd"]()
    run2 = compile(gcd.graph, backend="xla", optimize="sched",
                   max_cycles=CAP)
    assert not run2.engine._sched_on
    f2 = _feeds("gcd", gcd, 8, seed=2)
    _check(("compile", "fallback"),
           compile(gcd.graph, backend="xla", optimize="full",
                   max_cycles=CAP)(f2), run2(f2))
    # SSA executors have no plan to schedule
    with pytest.raises(ValueError, match="engine backend"):
        compile(bench.graph, backend="dag", optimize="sched")


def test_cached_engine_schedule_no_alias():
    from repro.serve.dataflow_server import cached_engine, \
        clear_engine_cache
    bench = library.BENCHES["fir"]()
    clear_engine_cache()
    dyn = cached_engine(bench.graph, backend="xla", optimize=True)
    sch = cached_engine(bench.graph, backend="xla", optimize=True,
                        schedule="auto")
    assert dyn is not sch, "scheduled and dynamic engines must not alias"
    assert sch._sched_on and not dyn._sched_on
    assert cached_engine(bench.graph, backend="xla",
                         optimize=True) is dyn
    assert cached_engine(bench.graph, backend="xla", optimize=True,
                         schedule="auto") is sch
    clear_engine_cache()


def test_server_serves_scheduled_fabric():
    from repro.serve.dataflow_server import DataflowServer
    bench = library.BENCHES["fir"]()
    reqs = [_feeds("fir", bench, 8, seed=s) for s in range(5)]
    srv_d = DataflowServer(bench.graph, slots=2, backend="xla",
                           optimize=True, max_cycles=CAP)
    srv_s = DataflowServer(bench.graph, slots=2, backend="xla",
                           optimize=True, schedule="auto",
                           max_cycles=CAP)
    assert srv_s.engine._sched_on
    uids = {srv_d.submit(f): i for i, f in enumerate(reqs)}
    uids_s = {srv_s.submit(f): i for i, f in enumerate(reqs)}
    got_d, got_s = {}, {}
    for _ in range(300):
        for r in srv_d.step():
            got_d[uids[r.uid]] = r
        for r in srv_s.step():
            got_s[uids_s[r.uid]] = r
        if len(got_d) == 5 and len(got_s) == 5:
            break
    assert len(got_d) == len(got_s) == 5
    for i in range(5):
        d, s = got_d[i], got_s[i]
        assert d.status == s.status == "ok"
        assert d.engine.cycles == s.engine.cycles
        assert d.engine.counts == s.engine.counts
        for a, c in d.engine.counts.items():
            if c:
                assert np.asarray(d.engine.outputs[a]).tobytes() == \
                    np.asarray(s.engine.outputs[a]).tobytes(), (i, a)
