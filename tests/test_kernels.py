"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Per assignment: shape/dtype sweeps with hypothesis, assert_allclose
against ref.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import library
from repro.core.engine import run_reference
from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(
    B=st.integers(1, 2),
    Sq=st.sampled_from([8, 33, 128]),
    Skv=st.sampled_from([16, 64, 130]),
    Hkv=st.sampled_from([1, 2]),
    G=st.sampled_from([1, 4]),
    hd=st.sampled_from([16, 64]),
    causal=st.booleans(),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_flash_attention_kernel_sweep(B, Sq, Skv, Hkv, G, hd, causal,
                                      dtype):
    if causal and Skv != Sq:
        Skv = Sq  # causal self-attention case
    key = jax.random.key(Sq * 131 + Skv)
    k1, k2, k3 = jax.random.split(key, 3)
    H = Hkv * G
    q = jax.random.normal(k1, (B, Sq, H, hd), dtype)
    k = jax.random.normal(k2, (B, Skv, Hkv, hd), dtype)
    v = jax.random.normal(k3, (B, Skv, Hkv, hd), dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, bq=32, bk=32)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 3e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("bq,bk", [(16, 64), (64, 16), (128, 128)])
def test_flash_attention_block_shape_invariance(bq, bk):
    key = jax.random.key(0)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (1, 96, 4, 32))
    k = jax.random.normal(k2, (1, 96, 2, 32))
    v = jax.random.normal(k3, (1, 96, 2, 32))
    out = flash_attention_pallas(q, k, v, causal=True, bq=bq, bk=bk)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(
    rows=st.sampled_from([1, 7, 64, 300]),
    d=st.sampled_from([32, 128, 512]),
    rows_blk=st.sampled_from([8, 256]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_rmsnorm_kernel_sweep(rows, d, rows_blk, dtype):
    key = jax.random.key(rows * 7 + d)
    x = jax.random.normal(key, (rows, d), dtype) * 3
    w = jax.random.normal(jax.random.key(d), (d,), dtype)
    out = rmsnorm_pallas(x, w, rows_blk=rows_blk)
    want = ref.rmsnorm_ref(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_rmsnorm_3d_batch():
    x = jax.random.normal(jax.random.key(1), (2, 17, 64))
    w = jnp.ones((64,))
    np.testing.assert_allclose(np.asarray(rmsnorm_pallas(x, w)),
                               np.asarray(ref.rmsnorm_ref(x, w)),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# dataflow fire step: full benchmarks driven by the kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name,args", [
    ("fibonacci", (11,)),
    ("pop_count", (np.array([12345, 65535, 7]),)),
    ("vector_sum", (np.arange(64).reshape(2, 32),)),
    ("bubble_sort", (np.array([[5, 3, 8, 1, 9, 2, 7, 4]]),)),
])
def test_fire_kernel_runs_benchmarks(name, args):
    bench = library.BENCHES[name]()
    feeds = bench.make_feeds(*args)
    got = ops.run_fabric(bench.graph, feeds)
    want = run_reference(bench.graph, feeds)
    assert got.cycles == want.cycles
    assert got.fired == want.fired
    for a in bench.graph.output_arcs():
        assert got.counts[a] == want.counts[a], a
        if want.counts[a]:
            assert int(got.outputs[a]) == int(np.asarray(want.outputs[a]))


def test_fire_body_matches_ref_random_states():
    """Property: kernel fire == jnp ref on random arc states."""
    bench = library.popcount_graph(8)
    tables, step = ops.make_fire_step(bench.graph)
    p = tables["plan"]
    A2 = p["A"] + 2
    rng = np.random.default_rng(0)
    for trial in range(10):
        full = rng.integers(0, 2, (A2,)).astype(np.int32)
        full[p["FULL_PAD"]] = 1
        full[p["EMPTY_PAD"]] = 0
        full[tables["const_mask"] > 0] = 1
        val = rng.integers(0, 1000, (A2,)).astype(np.int32)
        nf1, nv1, f1 = step(full, val)
        nf2, nv2, f2 = ref.fire_step_ref(tables, jnp.asarray(full),
                                         jnp.asarray(val))
        np.testing.assert_array_equal(np.asarray(nf1),
                                      np.asarray(nf2).astype(np.int32))
        np.testing.assert_array_equal(np.asarray(nv1), np.asarray(nv2))
        assert int(f1[0]) == int(f2)
