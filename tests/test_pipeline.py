"""Dataflow-scheduled pipeline parallelism.

Schedule-generation tests run in-process; the executor test (needs >1
device) runs in a subprocess with XLA_FLAGS host-device override so the
rest of the suite keeps a single device.
"""
import os
import subprocess
import sys

from repro.core.pipeline import dataflow_schedule, dense_schedule


def test_dataflow_schedule_matches_handshake_cadence():
    S, M = 4, 6
    t = dataflow_schedule(S, M)
    # paper-faithful: one token per two cycles per arc -> 2M+S-2 steps
    # (stage s fires microbatch m at cycle s+2m+1)
    assert t.shape[0] == 2 * M + S - 2
    # stage s fires microbatch m at cycle s + 2m (0-based rows: s+2m)
    for s in range(S):
        fired = [(r, int(t[r, s])) for r in range(t.shape[0])
                 if t[r, s] >= 0]
        assert [m for _, m in fired] == list(range(M))  # in order, all M
        assert [r for r, _ in fired] == [s + 2 * m for m in range(M)]


def test_dense_schedule_wavefront():
    S, M = 4, 6
    t = dense_schedule(S, M)
    assert t.shape[0] == M + S - 1
    for r in range(t.shape[0]):
        for s in range(S):
            m = r - s
            assert t[r, s] == (m if 0 <= m < M else -1)


def test_every_stage_processes_every_microbatch_once():
    for S, M in [(2, 2), (3, 5), (8, 3)]:
        t = dataflow_schedule(S, M)
        for s in range(S):
            col = t[:, s]
            assert sorted(col[col >= 0].tolist()) == list(range(M))


_EXEC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.pipeline import (dataflow_schedule, dense_schedule,
                                 pipeline_apply, make_stage_fn)
from repro.configs.base import get_arch
from repro.models import transformer as tfm

cfg = get_arch("internlm2-1.8b").reduced()
L, S, M, mb, seq = 8, 4, 6, 2, 16
import dataclasses
cfg = dataclasses.replace(cfg, n_layers=L, remat=False)
params = tfm.init_params(cfg, jax.random.key(0))
layers = params["layers"]

mesh = jax.make_mesh((4,), ("pp",))
x = jax.random.normal(jax.random.key(1), (M, mb, seq, cfg.d_model),
                      jnp.float32) * 0.1
stage_fn = make_stage_fn(cfg, L // S)

for sched_name, sched in [("dataflow", dataflow_schedule(S, M)),
                          ("dense", dense_schedule(S, M))]:
    y = pipeline_apply(mesh, stage_fn, layers, x, sched)
    # reference: plain scan over all layers, per microbatch
    def ref_fn(x):
        pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32),
                               (mb, seq))
        def body(x, lp):
            from repro.models.transformer import _dense_body
            x, _ = _dense_body(cfg, lp, x, pos)
            return x, None
        y, _ = jax.lax.scan(body, x, layers)
        return y
    ref = jax.vmap(ref_fn)(x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    print(f"OK {sched_name} fwd")

# gradient flows through the pipeline (reverse schedule via autodiff)
sched = dense_schedule(S, M)
def loss_pipe(layers):
    return jnp.sum(pipeline_apply(mesh, stage_fn, layers, x, sched) ** 2)
def loss_ref(layers):
    pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (mb, seq))
    def ref_fn(x):
        def body(x, lp):
            from repro.models.transformer import _dense_body
            x, _ = _dense_body(cfg, lp, x, pos)
            return x, None
        y, _ = jax.lax.scan(body, x, layers)
        return y
    return jnp.sum(jax.vmap(ref_fn)(x) ** 2)
g1 = jax.grad(loss_pipe)(layers)
g2 = jax.grad(loss_ref)(layers)
for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=5e-3, atol=5e-3)
print("OK grads")
"""


def test_pipeline_executor_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    r = subprocess.run([sys.executable, "-c", _EXEC_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "OK dataflow fwd" in r.stdout
    assert "OK dense fwd" in r.stdout
    assert "OK grads" in r.stdout
