"""Pass-pipeline correctness (ISSUE 3).

Two layers, two contracts (DESIGN.md §8):

* **opcode-class specialization** (plan-level, ``optimize="spec"`` /
  ``DataflowEngine(optimize=True)``) is a pure layout permutation: EVERY
  EngineResult field — outputs, counts, cycles, fired — and every
  per-arc register must be bit-identical to the unoptimized engine,
  across every library bench x backend {reference, xla, pallas} x
  K in {1, 4, 16}.
* **graph rewrites** (constant folding / identity elimination / dead
  code elimination, ``optimize="full"``) shrink the fabric: for fabrics
  that quiesce, every surviving output arc must drain bit-identical
  last values and token counts, including graphs where folding
  eliminates the nodes feeding output arcs.  ``cycles``/``fired`` may
  shrink — the optimized fabric does less work.
"""
import functools

import numpy as np
import pytest

from repro.core import library, passes
from repro.core.compile import compile_graph
from repro.core.engine import DataflowEngine, run_reference
from repro.core.graph import Graph, Op

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # CI installs hypothesis; local runs may not
    HAVE_HYPOTHESIS = False

KS = [1, 4, 16]
BACKENDS = ["reference", "xla", "pallas"]


def _bench(name):
    # full-size graphs except bubble_sort (8 -> 6 keeps wall-time sane)
    return library.bubble_sort_graph(6) if name == "bubble_sort" \
        else library.BENCHES[name]()


def _feeds(name, bench, k, seed=0):
    return library.random_feeds(name, bench, k,
                                np.random.default_rng(seed))


def _check_full(got, want, tag):
    """All EngineResult fields bit-identical (the spec contract)."""
    assert got.cycles == want.cycles, (tag, got.cycles, want.cycles)
    assert got.fired == want.fired, (tag, got.fired, want.fired)
    _check_observables(got, want, tag)


def _check_observables(got, want, tag):
    """Last values + token counts on every output arc of `want` (the
    rewrite contract)."""
    for a, c in want.counts.items():
        assert got.counts[a] == c, (tag, a, got.counts[a], c)
        if c:
            assert np.asarray(got.outputs[a]).item() == \
                np.asarray(want.outputs[a]).item(), (tag, a)


# ---------------------------------------------------------------------------
# specialization: full-field bit-identity across the whole matrix
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name", sorted(library.BENCHES))
def test_specialized_plan_bit_identical(name, backend):
    bench = _bench(name)
    dt = np.dtype(bench.dtype)
    if backend == "pallas" and dt != np.int32:
        pytest.skip(f"{name} runs at {dt}; pallas is int32-only")
    k = 10 if name == "fibonacci" else 3
    feeds = _feeds(name, bench, k)
    want = run_reference(bench.graph, feeds, dtype=dt)
    for K in KS:
        eng = DataflowEngine(bench.graph, dtype=dt, backend=backend,
                             block_cycles=K, optimize=True)
        _check_full(eng.run(feeds), want, (name, backend, K))


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_specialized_per_arc_state_identical(backend):
    """Mid-flight arc registers (not just drained results) match the
    dense engine's, mapped through the plan's arc permutation."""
    bench = library.fibonacci_graph()
    feeds = bench.make_feeds(40)          # still running after 3 blocks

    def arc_state(eng, st):
        full = np.asarray(st.full)[0]
        val = np.asarray(st.val)[0]
        return {a: (int(full[eng.p["aidx"][a]]),
                    int(val[eng.p["aidx"][a]]))
                for a in eng.p["arcs"]}

    states = []
    for optimize in (False, True):
        eng = DataflowEngine(bench.graph, backend=backend,
                             block_cycles=4, optimize=optimize)
        st_ = eng.reset_slots(eng.init_state(1), [0], [feeds])
        for _ in range(3):
            st_ = eng.step_block(st_)
        states.append(arc_state(eng, st_))
    assert states[0] == states[1]


def test_specialized_tensor_tokens_bit_identical():
    """The xla spec path generalizes to tensor tokens and float dtypes
    like the dense one."""
    g = Graph(name="tensor")
    g.add(Op.ADD, ["a", "b"], ["s"])
    g.add(Op.MUL, ["s", "c"], ["z"])
    feeds = {"a": np.full((2, 4), 3.0), "b": np.full((2, 4), 4.0),
             "c": np.full((2, 4), 2.0)}
    runs = []
    for opt in (False, True):
        eng = DataflowEngine(g, token_shape=(4,), dtype=np.float32,
                             backend="xla", block_cycles=4, optimize=opt)
        runs.append(eng.run(feeds))
    dense, spec = runs
    assert spec.cycles == dense.cycles and spec.fired == dense.fired
    assert spec.counts == dense.counts
    np.testing.assert_array_equal(np.asarray(spec.outputs["z"]),
                                  np.asarray(dense.outputs["z"]))


def test_plan_permutations_are_inverses():
    for name in sorted(library.BENCHES):
        p = DataflowEngine(_bench(name).graph, optimize=True).p
        assert (p["node_perm"][p["node_inv"]]
                == np.arange(len(p["node_perm"]))).all()
        assert (p["arc_perm"][p["arc_inv"]]
                == np.arange(len(p["arc_perm"]))).all()
        # class slices tile [0, N) and each bucket is opcode-pure
        edges = [0]
        for op, lo, hi in p["class_slices"]:
            assert lo == edges[-1] and hi > lo
            assert (p["opcode"][lo:hi] == op).all()
            edges.append(hi)
        assert edges[-1] == len(p["opcode"])


def test_specialized_batched_and_server_paths():
    """run_batch and the continuous-batching server ride the same
    specialized plan and stay bit-identical to solo dense runs."""
    from repro.serve.dataflow_server import DataflowServer
    bench = _bench("fir")
    fb = [_feeds("fir", bench, 1 + i % 3, seed=i) for i in range(5)]
    dense = DataflowEngine(bench.graph, backend="xla", block_cycles=4)
    solos = [dense.run(f) for f in fb]
    eng = DataflowEngine(bench.graph, backend="xla", block_cycles=4,
                         optimize=True)
    for got, want in zip(eng.run_batch(fb), solos):
        _check_full(got, want, "run_batch")
    srv = DataflowServer(bench.graph, slots=2, block_cycles=4,
                         backend="xla", optimize=True)
    uids = [srv.submit(f) for f in fb]
    got = {r.uid: r.engine for r in srv.drain()}
    for uid, want in zip(uids, solos):
        _check_full(got[uid], want, ("server", uid))


# ---------------------------------------------------------------------------
# rewrite passes: observable identity on quiescing fabrics
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", sorted(library.BENCHES))
def test_rewrites_preserve_observables(name):
    bench = _bench(name)
    dt = np.dtype(bench.dtype)
    opt, report = passes.optimize_graph(bench.graph, dtype=dt)
    assert report.nodes_after <= report.nodes_before
    k = 10 if name == "fibonacci" else 4
    feeds = _feeds(name, bench, k, seed=7)
    want = run_reference(bench.graph, feeds, dtype=dt)
    got = run_reference(opt, feeds, dtype=dt)
    _check_observables(got, want, (name, "reference"))
    eng = DataflowEngine(opt, dtype=dt, backend="xla", block_cycles=4,
                         optimize=True)
    _check_observables(eng.run(feeds), want, (name, "xla"))


def test_constant_folding_collapses_chains():
    g = Graph(name="foldme")
    g.const("c2", 2)
    g.const("c3", 3)
    g.const("c5", 5)
    g.add(Op.ADD, ["c2", "c3"], ["t"])       # t = 5
    g.add(Op.MUL, ["t", "c5"], ["u"])        # u = 25
    g.add(Op.ADD, ["u", "x"], ["out"])
    opt, report = passes.optimize_graph(g)
    assert report.folded == 2
    assert len(opt.nodes) == 1 and opt.consts["u"] == 25
    feeds = {"x": np.arange(4)}
    _check_observables(run_reference(opt, feeds),
                       run_reference(g, feeds), "fold-chain")


def test_folding_eliminates_nodes_feeding_outputs():
    """The folded node fed an output arc directly: the arc survives as
    a const bus and drains the same value at the same cadence (both
    fabrics free-run on it, so counts and cycles agree even at a cap)."""
    g = Graph(name="foldout")
    g.const("a", 7)
    g.const("b", 6)
    g.add(Op.MUL, ["a", "b"], ["y"])         # y: output arc, = 42
    g.add(Op.ADD, ["x", "a"], ["z"])         # stream-gated second output
    opt, report = passes.optimize_graph(g)
    assert report.folded == 1
    assert "y" in opt.output_arcs() and opt.consts["y"] == 42
    feeds = {"x": [1, 2]}
    want = run_reference(g, feeds, max_cycles=60)
    got = run_reference(opt, feeds, max_cycles=60)
    assert got.cycles == want.cycles
    _check_observables(got, want, "fold-to-output")


def test_copy_of_const_is_never_folded():
    """COPY's two outputs share one firing rule (both must be empty), so
    folding it to two independent always-full const buses would remove
    that backpressure coupling — here it would flip a quiescing fabric
    into a free-running one.  The folder must leave it alone."""
    g = Graph(name="foldcopy")
    g.const("c", 9)
    g.add(Op.COPY, ["c"], ["y1", "y2"])      # y2: env-drained output
    g.add(Op.ADD, ["y1", "x"], ["z"])        # y1: gated by the stream
    opt, report = passes.optimize_graph(g)
    assert report.folded == 0 and len(opt.nodes) == 2
    feeds = {"x": [3, 4]}
    want = run_reference(g, feeds)
    got = run_reference(opt, feeds)
    assert got.cycles == want.cycles < 100_000   # still quiesces
    _check_observables(got, want, "copy-kept")


def test_folding_uses_execution_dtype():
    """Folded constants wrap exactly like fired int32 results."""
    g = Graph(name="wrap")
    g.const("big", 70_000)
    g.add(Op.MUL, ["big", "big"], ["y"])
    g.add(Op.ADD, ["y", "x"], ["out"])
    with np.errstate(over="ignore"):
        opt, _ = passes.optimize_graph(g, dtype=np.int32)
        assert opt.consts["y"] == int(np.int32(70_000) * np.int32(70_000))


def test_identity_elimination_is_dtype_aware():
    g = Graph(name="ident")
    g.const("z0", 0)
    g.const("k", 5)
    g.add(Op.XOR, ["x", "z0"], ["m"])        # x ^ 0 == x only for ints
    g.add(Op.ADD, ["m", "k"], ["out"])
    opt_i, rep_i = passes.optimize_graph(g, dtype=np.int32)
    assert rep_i.identities == 1 and len(opt_i.nodes) == 1
    opt_f, rep_f = passes.optimize_graph(g, dtype=np.float32)
    assert rep_f.identities == 0 and len(opt_f.nodes) == 2
    # the guard case: an identity between an environment input and an
    # environment output is kept (both interface arcs must survive)
    g3 = Graph(name="ident3")
    g3.const("z0", 0)
    g3.add(Op.ADD, ["x", "z0"], ["out"])
    opt3, rep3 = passes.optimize_graph(g3)
    assert rep3.identities == 0 and len(opt3.nodes) == 1
    # and the splice preserves the stream (internal-wire case)
    g2 = Graph(name="ident2")
    g2.const("one", 1)
    g2.const("z0", 0)
    g2.add(Op.MUL, ["x", "one"], ["m"])
    g2.add(Op.ADD, ["m", "z0"], ["n"])
    g2.add(Op.SUB, ["n", "one"], ["out"])
    opt2, rep2 = passes.optimize_graph(g2)
    assert rep2.identities == 2 and len(opt2.nodes) == 1
    feeds = {"x": [5, 6, 7]}
    _check_observables(run_reference(opt2, feeds),
                       run_reference(g2, feeds), "identity-splice")


def test_const_fed_ndmerge_race_is_not_folded():
    """NDMERGE arbitration depends on token *arrival timing*, so folding
    must bail on merge-bearing graphs.  Regression for the review case:
    with feeds w=[7], s=[100] the authored fabric drains 107 (the merge
    takes ``w`` during ``m``'s one-cycle refill gap) but a folded fabric
    would drain 110 (``m`` always full as a const bus; tie picks a, so
    ``w`` is never consumed) — bit-identity would be violated."""
    g = Graph(name="merge_race")
    g.const("c", 5)
    g.add(Op.ADD, ["c", "c"], ["m"])         # all-const, but feeds a race
    g.add(Op.NDMERGE, ["m", "w"], ["y"])
    g.add(Op.ADD, ["y", "s"], ["out"])
    opt, report = passes.optimize_graph(g)
    assert not report.changed and len(opt.nodes) == 3
    feeds = {"w": [7], "s": [100]}
    want = run_reference(g, feeds, max_cycles=500)
    got = run_reference(opt, feeds, max_cycles=500)
    assert want.cycles < 500                 # both fabrics quiesce
    _check_observables(got, want, "merge-race")
    # the stream token must have won its race in both fabrics
    assert int(np.asarray(want.outputs["out"])) == 107


def test_identity_feeding_ndmerge_cone_is_kept():
    """An identity node is a one-token pipeline register; splicing it
    out shifts downstream arrivals a cycle earlier, which can flip an
    NDMERGE race — the pass bails on merge-bearing graphs."""
    g = Graph(name="merge_ident")
    g.const("z0", 0)
    g.add(Op.ADD, ["x", "z0"], ["m"])        # no-op, but a register
    g.add(Op.NDMERGE, ["m", "w"], ["out"])
    opt, report = passes.optimize_graph(g)
    assert report.identities == 0 and len(opt.nodes) == 2


def test_identity_on_cyclic_fabric_is_kept():
    """On a cyclic path the spliced register's lost capacity can change
    blocking behavior, so the identity pass is restricted to DAGs."""
    g = Graph(name="cyc_ident")
    g.const("z0", 0)
    g.add(Op.ADD, ["x", "fb"], ["m"])
    g.add(Op.COPY, ["m"], ["t", "out"])
    g.add(Op.ADD, ["t", "z0"], ["fb"])       # identity on the loop
    assert g.is_cyclic()
    opt, report = passes.optimize_graph(g)
    assert report.identities == 0 and len(opt.nodes) == 3


def test_region_scoped_fold_runs_beside_loop_entry_merges():
    """ISSUE 5: with only loop-entry NDMERGEs (on a cycle through
    exactly one input), the fold/splice passes run region-scoped
    instead of bailing out — const cones outside the loop fold, and
    the loop's outputs/token counts are untouched."""
    g = Graph(name="loop_fold")
    g.const("one", 1)
    g.const("c2", 2)
    g.const("c3", 3)
    # foldable cone OUTSIDE the loop feeds the environment
    g.add(Op.ADD, ["c2", "c3"], ["t"])          # -> const 5
    g.add(Op.MUL, ["t", "x"], ["pre"])
    # counter loop: NDMERGE entry, IFGT decider, BRANCH back edge
    g.add(Op.NDMERGE, ["i_fb", "i0"], ["i"])
    g.add(Op.COPY, ["i"], ["i_c", "i_d"])
    g.add(Op.IFGT, ["pre", "i_c"], ["cond"])
    g.add(Op.BRANCH, ["i_d", "cond"], ["i_live", "out"])
    g.add(Op.ADD, ["i_live", "one"], ["i_fb"])
    g.init("i0", 0)
    g.validate()
    opt, report = passes.optimize_graph(g)
    assert report.folded == 1, report.summary()     # the c2+c3 cone
    assert opt.consts["t"] == 5 and opt.inits == {"i0": 0}
    # the decider consumes one `pre` token per iteration, so the
    # environment presents x persistently (one per firing, like the
    # fibonacci bench's n_in bus): trip count = 5*2 = 10
    feeds = {"x": [2] * 12}
    want = run_reference(g, feeds, max_cycles=400)
    got = run_reference(opt, feeds, max_cycles=400)
    assert want.cycles < 400                        # both quiesce
    _check_observables(got, want, "loop-fold")
    assert want.counts["out"] == 1
    assert np.asarray(got.outputs["out"]).item() == 10  # trip count


def test_fold_never_turns_an_ndmerge_input_into_a_const_bus():
    """Folding a node whose output feeds an NDMERGE would replace a
    one-shot/periodic arc with an always-full bus and re-fire the
    merge every refill window — the folder must keep it even when the
    graph's merges are all loop entries."""
    g = Graph(name="merge_feed")
    g.const("one", 1)
    g.const("c2", 2)
    g.const("c3", 3)
    g.add(Op.ADD, ["c2", "c3"], ["seed"])       # all-const, feeds merge
    g.add(Op.NDMERGE, ["i_fb", "seed"], ["i"])
    g.add(Op.COPY, ["i"], ["i_c", "i_d"])
    g.add(Op.IFGT, ["n", "i_c"], ["cond"])
    g.add(Op.BRANCH, ["i_d", "cond"], ["i_live", "out"])
    g.add(Op.ADD, ["i_live", "one"], ["i_fb"])
    g.validate()
    opt, report = passes.optimize_graph(g)
    assert report.folded == 0
    assert "seed" not in opt.consts
    # the const-fed seed producer free-runs (re-initiating the merge),
    # so the fabric never quiesces — compare capped runs, which would
    # diverge if the fold had made seed an always-full const bus
    feeds = {"n": [8] * 6}      # one decider token per iteration
    want = run_reference(g, feeds, max_cycles=400)
    got = run_reference(opt, feeds, max_cycles=400)
    _check_observables(got, want, "merge-feed")


def test_off_cycle_identity_splices_in_cyclic_graphs():
    """The blanket acyclic restriction is gone: an identity on a wire
    OUTSIDE every cycle splices even when the graph has loops, while
    on-cycle identities stay (loop token capacity)."""
    g = Graph(name="cyc_mixed")
    g.const("z0", 0)
    g.const("one", 1)
    # off-cycle identity feeding the loop's decider input
    g.add(Op.ADD, ["x", "z0"], ["n"])           # spliceable no-op
    g.add(Op.NDMERGE, ["i_fb", "i0"], ["i"])
    g.add(Op.COPY, ["i"], ["i_c", "i_d"])
    g.add(Op.IFGT, ["n", "i_c"], ["cond"])
    g.add(Op.BRANCH, ["i_d", "cond"], ["i_live", "out"])
    # on-cycle identity: the back-edge register must survive
    g.add(Op.ADD, ["i_live", "one"], ["i_pre"])
    g.add(Op.XOR, ["i_pre", "z0"], ["i_fb"])    # no-op, but on the loop
    g.init("i0", 0)
    g.validate()
    opt, report = passes.optimize_graph(g)
    assert report.identities == 1, report.summary()
    assert any(n.op == Op.XOR for n in opt.nodes)      # on-cycle kept
    assert not any(n.op == Op.ADD and "z0" in n.inputs
                   for n in opt.nodes)                 # off-cycle gone
    feeds = {"x": [5] * 8}      # one decider token per iteration
    want = run_reference(g, feeds, max_cycles=400)
    got = run_reference(opt, feeds, max_cycles=400)
    assert want.cycles < 400
    _check_observables(got, want, "cyc-mixed")


def test_racy_ndmerge_still_bails_out_everything():
    """Two back edges into one NDMERGE (or an acyclic merge — covered
    by the PR 3 regression above) is racy: fold and splice both bail."""
    g = Graph(name="two_backs")
    g.const("one", 1)
    g.const("z0", 0)
    g.const("c_extra", 4)
    g.add(Op.ADD, ["c_extra", "z0"], ["w"])     # would-be fold target
    g.add(Op.NDMERGE, ["fb_a", "fb_b"], ["m"])  # merged by TWO cycles
    g.add(Op.COPY, ["m"], ["m1", "m2"])
    g.add(Op.COPY, ["m1"], ["out", "m3"])       # live: env-drained out
    g.add(Op.ADD, ["m3", "one"], ["fb_a"])
    g.add(Op.SUB, ["m2", "w"], ["fb_b"])
    g.validate()
    opt, report = passes.optimize_graph(g)
    assert report.folded == 0 and report.identities == 0
    assert len(opt.nodes) == len(g.nodes)


def test_dce_removes_closed_dead_region_only():
    g = Graph(name="dce")
    g.const("c1", 3)
    g.const("c2", 4)
    g.add(Op.ADD, ["x", "c1"], ["out"])      # live
    g.add(Op.NDMERGE, ["c1", "c2"], ["m"])   # dead, const-fed (unfoldable)
    g.add(Op.SINK, ["m"], [])                # dead drain
    opt, report = passes.optimize_graph(g)
    assert report.dead == 2 and len(opt.nodes) == 1
    assert "c2" not in opt.consts            # dead const arc dropped
    # the dead NDMERGE free-runs in the original (it never quiesces, so
    # cap both runs); the optimized fabric quiesces on its own
    want = run_reference(g, {"x": [1, 2, 3]}, max_cycles=300)
    got = run_reference(opt, {"x": [1, 2, 3]}, max_cycles=300)
    _check_observables(got, want, "dce")
    assert got.cycles < want.cycles == 300   # dead region free-ran
    # a SINK fed by a LIVE producer is kept: removing it would strand
    # the producer's arc as a new environment-drained output
    fib = library.fibonacci_graph().graph
    opt_fib, rep_fib = passes.optimize_graph(fib)
    assert not rep_fib.changed
    assert len(opt_fib.nodes) == len(fib.nodes)


def test_dce_keeps_env_fed_dead_regions_for_feed_compat():
    """A dead region fed by an environment input arc is kept: deleting
    the arc would make feeds that were valid for the authored graph
    start raising in pack_feeds."""
    g = Graph(name="dce_env")
    g.const("k", 3)
    g.add(Op.ADD, ["x", "k"], ["out"])       # live
    g.add(Op.MUL, ["d", "k"], ["dd"])        # dead, fed by env input d
    g.add(Op.SINK, ["dd"], [])
    opt, report = passes.optimize_graph(g)
    assert report.dead == 0 and sorted(opt.input_arcs()) == ["d", "x"]
    feeds = {"x": [1, 2, 3], "d": [9]}       # authored-interface feeds
    run = compile_graph(g, backend="xla", block_cycles=4, optimize=True)
    _check_full(run(feeds), run_reference(g, feeds), "env-fed-dce")


def test_float_constant_folding_is_exact():
    """Folded float constants must not be truncated through int()."""
    g = Graph(name="ffold")
    g.const("h", 0.5)
    g.const("q", 0.25)
    g.add(Op.ADD, ["h", "q"], ["s"])         # s = 0.75
    g.add(Op.ADD, ["s", "x"], ["out"])
    opt, report = passes.optimize_graph(g, dtype=np.float32)
    assert report.folded == 1 and opt.consts["s"] == 0.75
    feeds = {"x": np.asarray([1.0, 2.0], np.float32)}
    want = run_reference(g, feeds, dtype=np.float32)
    got = run_reference(opt, feeds, dtype=np.float32)
    for a, c in want.counts.items():
        assert got.counts[a] == c
        np.testing.assert_array_equal(np.asarray(got.outputs[a]),
                                      np.asarray(want.outputs[a]))
    # ...and a float const that truncates to an identity value is NOT
    # treated as one: x + 0.5 stays
    g2 = Graph(name="fident")
    g2.const("h", 0.5)
    g2.const("k", 2.0)
    g2.add(Op.ADD, ["x", "h"], ["m"])
    g2.add(Op.MUL, ["m", "k"], ["out"])
    _, rep2 = passes.optimize_graph(g2, dtype=np.float32)
    assert rep2.identities == 0


def test_float_add_zero_is_not_spliced_signed_zero():
    """x + 0.0 is not a BIT-exact identity: -0.0 + 0.0 == +0.0 per IEEE
    754, so splicing the ADD would propagate -0.0 where the authored
    fabric drains +0.0.  Float identities are restricted to *1 /1."""
    g = Graph(name="szero")
    g.const("z0", 0.0)
    g.const("k", 2.0)
    g.add(Op.ADD, ["x", "z0"], ["t"])
    g.add(Op.MUL, ["t", "k"], ["out"])
    opt, report = passes.optimize_graph(g, dtype=np.float32)
    assert report.identities == 0 and len(opt.nodes) == 2
    feeds = {"x": np.asarray([-0.0], np.float32)}
    want = run_reference(g, feeds, dtype=np.float32)
    got = run_reference(opt, feeds, dtype=np.float32)
    assert (np.signbit(np.asarray(got.outputs["out"]))
            == np.signbit(np.asarray(want.outputs["out"])))
    # MUL/DIV by one stay bit-exact spliceable for floats
    g2 = Graph(name="fmul1")
    g2.const("one", 1.0)
    g2.const("k", 2.0)
    g2.add(Op.MUL, ["x", "one"], ["t"])
    g2.add(Op.ADD, ["t", "k"], ["out"])
    opt2, rep2 = passes.optimize_graph(g2, dtype=np.float32)
    assert rep2.identities == 1 and len(opt2.nodes) == 1
    got2 = run_reference(opt2, feeds, dtype=np.float32)
    want2 = run_reference(g2, feeds, dtype=np.float32)
    np.testing.assert_array_equal(np.asarray(got2.outputs["out"]),
                                  np.asarray(want2.outputs["out"]))
    assert (np.signbit(np.asarray(got2.outputs["out"]))
            == np.signbit(np.asarray(want2.outputs["out"])))


def test_float_shr_underflow_guard_matches_jax_alus():
    """alu_numpy (the reference engine's fire math AND the folder's
    compile-time evaluator) guards float SHR's exp2 underflow exactly
    like the jax `_alu`/`_alu_op` paths: exp2(-200) underflows float32
    to 0, and a/0 would fold to inf where the live engines produce a."""
    from repro.core.engine import alu_numpy
    a = np.float32(3.0)
    assert alu_numpy(Op.SHR, a, np.float32(-200.0), np.float32) == a
    g = Graph(name="shr_fold")
    g.const("a", 3.0)
    g.const("b", -200.0)
    g.add(Op.SHR, ["a", "b"], ["s"])
    g.add(Op.ADD, ["s", "x"], ["out"])
    opt, report = passes.optimize_graph(g, dtype=np.float32)
    assert report.folded == 1 and opt.consts["s"] == 3.0
    feeds = {"x": np.asarray([1.0, 2.0], np.float32)}
    want = DataflowEngine(g, dtype=np.float32, backend="xla",
                          block_cycles=4).run(feeds)
    for run in (run_reference(opt, feeds, dtype=np.float32),
                DataflowEngine(opt, dtype=np.float32, backend="xla",
                               block_cycles=4, optimize=True).run(feeds)):
        for arc, c in want.counts.items():
            assert run.counts[arc] == c
            np.testing.assert_array_equal(np.asarray(run.outputs[arc]),
                                          np.asarray(want.outputs[arc]))


def test_alu_numpy_matches_jax_alu_on_edge_operands():
    """alu_numpy (fold / reference fire math) and the jax `_alu_op`
    (specialized fire) are hand-synced copies of one formula table, and
    the float-SHR underflow drift shipped because no test compared them
    on edge operands.  Pin bit-for-bit parity across every value op x
    dtype on the historical drift points: zero divisors, signed zeros,
    shift over/underflow, extreme magnitudes — plus the integer edges
    (ISSUE 4): INT_MIN negation/abs wrap, DIV by -1 at INT_MIN,
    shift-by->=-width (clipped, both directions), and unsigned
    wraparound on a uint32 fabric."""
    import jax.numpy as jnp
    from repro.core.engine import _alu_op, alu_numpy
    cases = {
        np.int32: [-(2 ** 31), -(2 ** 31) + 1, -40, -2, -1, 0, 1, 5,
                   31, 32, 33, 40, 2 ** 31 - 1],
        np.uint32: [0, 1, 2, 5, 7, 31, 32, 40, 2 ** 31, 2 ** 32 - 1],
        np.float32: [-np.inf, -200.0, -1.5, -0.0, 0.0, 0.5, 1.0,
                     200.0, np.inf],
    }
    ops = [op for op in Op if op not in (Op.DMERGE, Op.NDMERGE)]
    for dt, vals in cases.items():
        A, B = np.meshgrid(np.asarray(vals, dt), np.asarray(vals, dt))
        a, b = A.ravel(), B.ravel()
        is_f = np.issubdtype(dt, np.floating)
        uview = np.dtype(f"u{np.dtype(dt).itemsize}")
        for op in ops:
            with np.errstate(all="ignore"):
                want = np.asarray(alu_numpy(op, a, b, dt), dt)
            got = np.asarray(
                _alu_op(op, jnp.asarray(a), jnp.asarray(b), dt)
            ).astype(dt, copy=False)
            nan = np.isnan(want) if is_f else np.zeros(want.shape, bool)
            assert (got.view(uview)[~nan]
                    == want.view(uview)[~nan]).all(), (op, dt)
            assert np.isnan(got[nan]).all(), (op, dt)


def test_alu_integer_edge_regressions_pin_exact_values():
    """The specific integer edges, asserted against their expected
    two's-complement results so a 'both drifted the same way' bug in
    the parity test above cannot hide them: INT_MIN // -1 wraps to
    INT_MIN (and never traps), shifts by >= width clip to 31, negative
    shift counts clip to 0, and uint32 SUB wraps."""
    import jax.numpy as jnp
    from repro.core.engine import _alu_op, alu_numpy
    INT_MIN = np.int32(-(2 ** 31))
    checks = [
        (Op.DIV, np.int32, INT_MIN, np.int32(-1), INT_MIN),
        (Op.DIV, np.int32, INT_MIN, np.int32(0), np.int32(0)),
        (Op.SUB, np.int32, np.int32(0), INT_MIN, INT_MIN),
        (Op.SHL, np.int32, np.int32(1), np.int32(40), INT_MIN),
        (Op.SHR, np.int32, INT_MIN, np.int32(40), np.int32(-1)),
        (Op.SHL, np.int32, np.int32(1), np.int32(-5), np.int32(1)),
        (Op.SUB, np.uint32, np.uint32(0), np.uint32(1),
         np.uint32(2 ** 32 - 1)),
        (Op.ADD, np.uint32, np.uint32(2 ** 32 - 1), np.uint32(2),
         np.uint32(1)),
        (Op.SHR, np.uint32, np.uint32(2 ** 32 - 1), np.uint32(31),
         np.uint32(1)),
    ]
    for op, dt, a, b, want in checks:
        with np.errstate(all="ignore"):
            got_np = np.asarray(alu_numpy(op, a, b, dt), dt).reshape(())
        got_jx = np.asarray(
            _alu_op(op, jnp.asarray(a), jnp.asarray(b), dt)
        ).astype(dt).reshape(())
        assert got_np == want, (op, dt, "alu_numpy")
        assert got_jx == want, (op, dt, "_alu_op")


def test_uint32_fabric_runs_bit_identical_across_engines():
    """Unsigned execution end to end, not just ALU formulas: a
    wraparound-heavy uint32 fabric drains identical results from the
    reference oracle and the xla engine (dense and specialized)."""
    g = Graph(name="u32")
    g.const("m1", 2 ** 32 - 1)               # UINT_MAX
    g.add(Op.ADD, ["x", "m1"], ["t"])        # x - 1 mod 2^32
    g.add(Op.SHR, ["t", "s"], ["z"])
    feeds = {"x": np.asarray([0, 1, 2 ** 31], np.uint32),
             "s": np.asarray([1, 31, 40], np.uint32)}
    want = run_reference(g, feeds, dtype=np.uint32)
    for opt in (False, True):
        eng = DataflowEngine(g, dtype=np.uint32, backend="xla",
                             block_cycles=4, optimize=opt)
        got = eng.run(feeds)
        assert got.counts == want.counts and got.cycles == want.cycles
        np.testing.assert_array_equal(
            np.asarray(got.outputs["z"], np.uint32),
            np.asarray(want.outputs["z"], np.uint32))


def test_optimize_graph_rejects_unknown_pass():
    with pytest.raises(ValueError, match="unknown passes"):
        passes.optimize_graph(Graph(), passes=("fold", "bogus"))
    with pytest.raises(ValueError, match="optimize"):
        compile_graph(library.vector_sum_graph(8).graph,
                      backend="xla", optimize="bogus")
    # plan-level specialization needs a plan: auto backends have none,
    # and silently measuring an unoptimized runner would be worse
    with pytest.raises(ValueError, match="engine backend"):
        compile_graph(library.vector_sum_graph(8).graph, optimize="spec")


def test_compile_graph_full_pipeline_reports():
    bench = _bench("fir")
    run = compile_graph(bench.graph, backend="xla", block_cycles=4,
                        optimize=True)
    assert run.report is not None and run.report.identities >= 1
    assert len(run.graph.nodes) < len(bench.graph.nodes)
    feeds = _feeds("fir", bench, 3, seed=2)
    _check_observables(run(feeds),
                       run_reference(bench.graph, feeds), "full")


# ---------------------------------------------------------------------------
# hypothesis property layer (CI; local runs without hypothesis skip it)
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:
    @functools.lru_cache(maxsize=None)
    def _engines(name):
        bench = _bench(name)
        dt = np.dtype(bench.dtype)
        dense = DataflowEngine(bench.graph, dtype=dt, backend="xla",
                               block_cycles=4)
        spec = DataflowEngine(bench.graph, dtype=dt, backend="xla",
                              block_cycles=4, optimize=True)
        rewritten, _ = passes.optimize_graph(bench.graph, dtype=dt)
        full = DataflowEngine(rewritten, dtype=dt, backend="xla",
                              block_cycles=4, optimize=True)
        return bench, dense, spec, full

    @settings(max_examples=15, deadline=None)
    @given(name=st.sampled_from(sorted(library.BENCHES)),
           k=st.integers(min_value=1, max_value=6),
           seed=st.integers(min_value=0, max_value=2 ** 16))
    def test_property_optimized_runs_bit_identical(name, k, seed):
        bench, dense, spec, full = _engines(name)
        feeds = _feeds(name, bench, k, seed=seed)
        want = dense.run(feeds)
        _check_full(spec.run(feeds), want, (name, k, seed, "spec"))
        _check_observables(full.run(feeds), want,
                           (name, k, seed, "full"))

    @settings(max_examples=10, deadline=None)
    @given(c1=st.integers(min_value=-50, max_value=50),
           c2=st.integers(min_value=-50, max_value=50),
           xs=st.lists(st.integers(min_value=-99, max_value=99),
                       min_size=1, max_size=6))
    def test_property_folding_output_feeds(c1, c2, xs):
        """Folding nodes that feed outputs keeps observables for any
        constants and any gating stream."""
        g = Graph(name="prop_fold")
        g.const("c1", c1)
        g.const("c2", c2)
        g.add(Op.ADD, ["c1", "c2"], ["s"])
        g.add(Op.MUL, ["s", "x"], ["out"])
        opt, report = passes.optimize_graph(g)
        assert report.folded == 1 and opt.consts["s"] == c1 + c2
        feeds = {"x": np.asarray(xs, np.int32)}
        _check_observables(run_reference(opt, feeds),
                           run_reference(g, feeds), (c1, c2))
