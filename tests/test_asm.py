"""Assembler round-trip property over the full benchmark library.

Property: for every graph in core.library.BENCHES,
``asm.parse(asm.emit(g))`` reproduces an isomorphic Graph — same node
table (opcodes + arc wiring), same consts, same derived arc classes —
and the reproduced fabric behaves identically on the reference engine.
``emit`` is also a fixed point after one round trip.
"""
import numpy as np
import pytest

from repro.core import asm, library
from repro.core.engine import run_reference


def _graphs():
    for name, mk in library.BENCHES.items():
        bench = library.bubble_sort_graph(4) if name == "bubble_sort" \
            else mk()   # 4-wide sort keeps the reference run cheap
        yield name, bench


@pytest.mark.parametrize("name,bench", list(_graphs()),
                         ids=[n for n, _ in _graphs()])
def test_roundtrip_is_isomorphic(name, bench):
    g = bench.graph
    g2 = asm.parse(asm.emit(g), name=g.name)
    assert [(n.op, n.inputs, n.outputs) for n in g.nodes] == \
           [(n.op, n.inputs, n.outputs) for n in g2.nodes]
    assert {a: int(v) for a, v in g.consts.items()} == \
           {a: int(v) for a, v in g2.consts.items()}
    assert g.input_arcs() == g2.input_arcs()
    assert g.output_arcs() == g2.output_arcs()
    assert g.is_cyclic() == g2.is_cyclic()
    assert g.resources() == g2.resources()


@pytest.mark.parametrize("name,bench", list(_graphs()),
                         ids=[n for n, _ in _graphs()])
def test_roundtrip_emit_is_fixed_point(name, bench):
    text = asm.emit(bench.graph)
    assert asm.emit(asm.parse(text)) == text


@pytest.mark.parametrize("name", ["fibonacci", "vector_sum", "pop_count"])
def test_roundtrip_behaves_identically(name):
    bench = library.BENCHES[name]() if name != "vector_sum" \
        else library.vector_sum_graph(8)
    g2 = asm.parse(asm.emit(bench.graph))
    feeds = library.random_feeds(name, bench, 4, np.random.default_rng(0))
    want = run_reference(bench.graph, feeds)
    got = run_reference(g2, feeds)
    assert got.cycles == want.cycles
    assert got.fired == want.fired
    assert got.counts == want.counts
    for a, c in want.counts.items():
        if c:
            np.testing.assert_array_equal(np.asarray(got.outputs[a]),
                                          np.asarray(want.outputs[a]))
