"""Assembler round-trip property over the full benchmark library, and
the parse error paths (ISSUE 4).

Round-trip property: for every graph in core.library.BENCHES,
``asm.parse(asm.emit(g))`` reproduces an isomorphic Graph — same node
table (opcodes + arc wiring), same consts, same derived arc classes —
and the reproduced fabric behaves identically on the reference engine.
``emit`` is also a fixed point after one round trip.

Error paths: malformed statements, unknown opcodes, wrong argument
counts, and bad/duplicate const declarations raise SyntaxError naming
the statement; structural violations (duplicate producers/receivers,
produced const arcs) surface as Graph.validate's ValueError.
"""
import numpy as np
import pytest

from repro.core import asm, library
from repro.core.engine import run_reference
from repro.core.graph import Graph, Op


def _graphs():
    for name, mk in library.BENCHES.items():
        bench = library.bubble_sort_graph(4) if name == "bubble_sort" \
            else mk()   # 4-wide sort keeps the reference run cheap
        yield name, bench


@pytest.mark.parametrize("name,bench", list(_graphs()),
                         ids=[n for n, _ in _graphs()])
def test_roundtrip_is_isomorphic(name, bench):
    g = bench.graph
    g2 = asm.parse(asm.emit(g), name=g.name)
    assert [(n.op, n.inputs, n.outputs) for n in g.nodes] == \
           [(n.op, n.inputs, n.outputs) for n in g2.nodes]
    assert {a: float(v) for a, v in g.consts.items()} == \
           {a: float(v) for a, v in g2.consts.items()}
    assert {a: float(v) for a, v in g.inits.items()} == \
           {a: float(v) for a, v in g2.inits.items()}
    assert g.input_arcs() == g2.input_arcs()
    assert g.output_arcs() == g2.output_arcs()
    assert g.is_cyclic() == g2.is_cyclic()
    assert g.resources() == g2.resources()


@pytest.mark.parametrize("name,bench", list(_graphs()),
                         ids=[n for n, _ in _graphs()])
def test_roundtrip_emit_is_fixed_point(name, bench):
    text = asm.emit(bench.graph)
    assert asm.emit(asm.parse(text)) == text


def test_init_annotation_round_trip_and_errors():
    """Initial-token annotations (loop back-edge registers, ISSUE 5):
    emit + parse round-trip, value classes preserved, and the parse
    error paths name the offending statement."""
    g = Graph(name="loop")
    g.add(Op.NDMERGE, ["back", "seed"], ["c"])
    g.add(Op.COPY, ["c"], ["tap", "d"])
    g.add(Op.ADD, ["tap", "one"], ["back"])
    g.const("one", 1)
    g.init("seed", 7)
    g.validate()
    text = asm.emit(g)
    assert "init seed = 7;" in text
    g2 = asm.parse(text, name="loop")
    assert g2.inits == {"seed": 7} and g2.consts == {"one": 1}
    assert asm.emit(g2) == text
    assert g2.input_arcs() == []        # init arcs are not env inputs
    # float init values round-trip exactly (like float consts)
    g.inits["seed"] = -0.5
    g3 = asm.parse(asm.emit(g))
    assert g3.inits["seed"] == -0.5
    with pytest.raises(SyntaxError, match="redeclared"):
        asm.parse("init a = 1; init a = 2; sink a;")
    with pytest.raises(SyntaxError, match="both const and init"):
        asm.parse("const a = 1; init a = 2; sink a;")
    with pytest.raises(SyntaxError, match="bad init declaration"):
        asm.parse("init a;")
    with pytest.raises(ValueError, match="no consumer"):
        asm.parse("init a = 1; add x, y, z;")


def test_init_property_random_values_run_identically():
    """Property: any init value on the loop seed register produces the
    same run from the parsed graph as from the authored one."""
    rng = np.random.default_rng(11)
    for _ in range(5):
        seed_v = int(rng.integers(-50, 50))
        g = Graph(name="acc")
        g.add(Op.NDMERGE, ["back", "ini"], ["c"])
        g.add(Op.COPY, ["c"], ["tap", "d"])
        g.add(Op.ADD, ["tap", "x"], ["back"])
        g.init("ini", seed_v)
        g.validate()
        g2 = asm.parse(asm.emit(g))
        feeds = {"x": rng.integers(-9, 9, (3,))}
        want = run_reference(g, feeds, max_cycles=60)
        got = run_reference(g2, feeds, max_cycles=60)
        assert got.cycles == want.cycles and got.fired == want.fired
        assert got.counts == want.counts
        for a, c in want.counts.items():
            if c:
                assert np.asarray(got.outputs[a]).item() == \
                    np.asarray(want.outputs[a]).item(), (seed_v, a)


@pytest.mark.parametrize("name", ["fibonacci", "vector_sum", "pop_count",
                                  "gcd", "horner_loop"])
def test_roundtrip_behaves_identically(name):
    bench = library.BENCHES[name]() if name != "vector_sum" \
        else library.vector_sum_graph(8)
    g2 = asm.parse(asm.emit(bench.graph))
    feeds = library.random_feeds(name, bench, 4, np.random.default_rng(0))
    want = run_reference(bench.graph, feeds)
    got = run_reference(g2, feeds)
    assert got.cycles == want.cycles
    assert got.fired == want.fired
    assert got.counts == want.counts
    for a, c in want.counts.items():
        if c:
            np.testing.assert_array_equal(np.asarray(got.outputs[a]),
                                          np.asarray(want.outputs[a]))


# ---------------------------------------------------------------------------
# parse error paths
# ---------------------------------------------------------------------------
def test_parse_rejects_malformed_statements():
    with pytest.raises(SyntaxError, match="bad statement"):
        asm.parse("42;")
    with pytest.raises(SyntaxError, match="unknown opcode 'frob'"):
        asm.parse("1. frob a, b, z;")
    # bad arity: add wants 2 inputs + 1 output
    with pytest.raises(SyntaxError, match="add wants 2\\+1 args"):
        asm.parse("add a, z;")
    with pytest.raises(SyntaxError, match="branch wants 2\\+2 args"):
        asm.parse("branch a, c, t;")


def test_parse_rejects_bad_const_declarations():
    with pytest.raises(SyntaxError, match="bad const declaration"):
        asm.parse("const a;")
    with pytest.raises(SyntaxError, match="bad const declaration"):
        asm.parse("const a =;")
    with pytest.raises(SyntaxError, match="bad const value 'xyz'"):
        asm.parse("const a = xyz;")
    with pytest.raises(SyntaxError, match="redeclared"):
        asm.parse("const a = 1; const a = 2;")


def test_parse_propagates_structural_validation():
    # duplicate producer: two nodes write arc z
    with pytest.raises(ValueError, match="multiple producers"):
        asm.parse("add x, y, z; sub u, v, z;")
    # duplicate receiver: two nodes read non-const arc z
    with pytest.raises(ValueError, match="multiple consumers"):
        asm.parse("add z, y, w; sub z, v, u;")
    # a const arc with a producer (dangling const bus wiring)
    with pytest.raises(ValueError, match="also has a producer"):
        asm.parse("const z = 1; add x, y, z;")
    # ...but a const arc MAY fan out to several receivers
    g = asm.parse("const z = 1; add z, y, w; sub z, v, u;")
    assert len(g.nodes) == 2


def test_const_values_roundtrip_ints_and_floats():
    g = Graph(name="consts")
    g.const("i", 7)
    g.const("neg", -3)
    g.const("hexy", 255)
    g.const("half", 0.5)
    g.const("mzero", -0.0)
    g.const("intfloat", 3.0)
    g.add(Op.ADD, ["i", "neg"], ["a"])
    g.add(Op.ADD, ["hexy", "half"], ["b"])
    g.add(Op.ADD, ["mzero", "intfloat"], ["c"])
    text = asm.emit(g)
    g2 = asm.parse(text)
    assert g2.consts["i"] == 7 and g2.consts["neg"] == -3
    assert g2.consts["half"] == 0.5
    assert g2.consts["mzero"] == 0.0 and np.signbit(g2.consts["mzero"])
    assert g2.consts["intfloat"] == 3       # integral floats emit as int
    assert asm.emit(g2) == text             # emit is a fixed point
    # hex int literals parse (base-0 int syntax)
    assert asm.parse("const h = 0x10; add h, x, y;").consts["h"] == 16
