"""Expression-to-fabric frontend (ISSUE 4).

Acceptance contract: for every program here, the traced fabric is
bit-identical to a plain-numpy reference of the same expression —
last drained value and token count per output arc — across ALL three
backends (reference, xla, pallas) with ``optimize="full"`` (graph
rewrites + specialized plan).  The matrix includes a ``jnp.where``
select lowering and a const-heavy program whose PassReport shows the
PR 3 folding pass visibly shrinking the synthesized fabric.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import asm, library
from repro.core.compile import compile_fn, compile_graph
from repro.core.engine import DataflowEngine, run_reference
from repro.front import LoweringError, trace

BACKENDS = ["reference", "xla", "pallas"]
I32 = np.int32


# ---------------------------------------------------------------------------
# the acceptance program suite: (name, traced fn, numpy reference, streams)
# every reference computes in int32 so wraparound matches the fabric
# ---------------------------------------------------------------------------
def _i32(*vs):
    return [np.asarray(v, I32) for v in vs]


def _prog_where(x, y):
    return jnp.where(x > y, x - y, y - x)


def _ref_where(x, y):
    return np.where(x > y, x - y, y - x)


def _prog_horner(x):
    return ((2 * x + 3) * x - 7) * x + 5


def _ref_horner(x):
    return ((I32(2) * x + I32(3)) * x - I32(7)) * x + I32(5)


def _prog_saxpy(x, y):
    return 3 * x + y


def _prog_popc8(x):
    acc = (x >> 0) & 1
    for k in range(1, 8):
        acc = acc + ((x >> k) & 1)
    return acc


def _ref_popc8(x):
    acc = (x >> 0) & I32(1)
    for k in range(1, 8):
        acc = acc + ((x >> k) & I32(1))
    return acc


def _prog_clamp_relu(x):
    return jnp.clip(jnp.maximum(x, 0) * 3, 0, 100)


def _ref_clamp_relu(x):
    return np.clip(np.maximum(x, I32(0)) * I32(3), 0, 100)


def _prog_logic(x, y):
    return ((x ^ y) | (x & 3)) + (x > y)


def _ref_logic(x, y):
    return ((x ^ y) | (x & I32(3))) + (x > y).astype(I32)


def _prog_powsum(x):
    return x ** 3 + x ** 2 - x


def _ref_powsum(x):
    return x ** 2 * x + x ** 2 - x


def _prog_negabs(x, y):
    return -x + abs(y) * 2


def _ref_negabs(x, y):
    return -x + np.abs(y) * I32(2)


def _prog_minmax(x, y):
    return jnp.minimum(jnp.maximum(x, y) - jnp.minimum(x, y), 1000)


def _ref_minmax(x, y):
    return np.minimum(np.maximum(x, y) - np.minimum(x, y), I32(1000))


PROGRAMS = {
    # name: (fn, numpy ref, list of argument streams)
    "where_absdiff": (_prog_where, _ref_where,
                      _i32([5, 1, 7, -4, 0], [2, 9, 7, -4, 1])),
    "horner": (_prog_horner, _ref_horner, _i32([0, 1, -3, 12, 99])),
    "saxpy": (_prog_saxpy, lambda x, y: I32(3) * x + y,
              _i32([1, -2, 50, 0, 7], [10, 20, -30, 0, 1])),
    "popc8": (_prog_popc8, _ref_popc8, _i32([0, 1, 255, 170, 99])),
    "clamp_relu": (_prog_clamp_relu, _ref_clamp_relu,
                   _i32([-5, 2, 50, 7, -1])),
    "logic_mix": (_prog_logic, _ref_logic,
                  _i32([5, 0, -7, 31, 12], [3, 0, 7, -31, 12])),
    "powsum": (_prog_powsum, _ref_powsum, _i32([0, 2, -3, 9, 40])),
    "negabs": (_prog_negabs, _ref_negabs,
               _i32([4, -4, 0, 99, -2], [-3, 3, 0, -99, 2])),
    "minmax_span": (_prog_minmax, _ref_minmax,
                    _i32([9, -9, 0, 4, 2], [1, 9, 0, -4, 2])),
}


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name", sorted(PROGRAMS))
def test_traced_program_matches_numpy_reference(name, backend):
    fn, ref, streams = PROGRAMS[name]
    want = np.asarray(ref(*streams), I32)
    run = compile_fn(fn, *([I32] * len(streams)), backend=backend,
                     block_cycles=4, optimize="full")
    res = run(run.make_feeds(*streams))
    out = run.out_arcs[0]
    assert res.counts[out] == len(want), (name, backend)
    assert int(np.asarray(res.outputs[out])) == int(want[-1]), \
        (name, backend)


def test_traced_program_full_stream_bit_identical():
    """The auto backend (vmapped SSA) exposes every stream element, so
    the whole stream — not just the last drained token — is checked
    bit-for-bit against numpy for the select-free programs."""
    for name in ("horner", "saxpy", "popc8", "clamp_relu", "logic_mix",
                 "powsum", "negabs", "minmax_span"):
        fn, ref, streams = PROGRAMS[name]
        want = np.asarray(ref(*streams), I32)
        run = compile_fn(fn, *([I32] * len(streams)), backend="auto")
        got = run(run.make_feeds(*streams))
        if hasattr(got, "outputs"):         # select lowering -> cyclic
            continue
        np.testing.assert_array_equal(
            np.asarray(got[run.out_arcs[0]], I32), want, err_msg=name)


def test_where_lowering_consumes_both_sides_per_token():
    """The select schema must consume BOTH operands every firing (the
    untaken side rides a BRANCH into a SINK) — alternating predicates
    over a long stream would otherwise deadlock on stale tokens."""
    prog = trace(_prog_where, I32, I32, name="where")
    ops = [n.op.name for n in prog.nodes]
    assert ops.count("BRANCH") == 2 and ops.count("DMERGE") == 1
    assert ops.count("SINK") == 2
    x = np.asarray([5, 1, 7, -9, 0, 3, 3, 100], I32)
    y = np.asarray([2, 9, 7, 4, -1, 3, 4, -100], I32)
    want = _ref_where(x, y)
    for backend in BACKENDS:
        eng = DataflowEngine(prog, backend=backend, block_cycles=4)
        # per-token: feed one token at a time so every element of the
        # stream is observable, not just the last drained value
        for i in range(len(x)):
            r = eng.run(prog.make_feeds(x[i:i + 1], y[i:i + 1]))
            assert r.counts[prog.out_arc] == 1
            assert int(np.asarray(r.outputs[prog.out_arc])) == \
                int(want[i]), (backend, i)


def test_const_heavy_program_folds_visibly():
    """Const-bound arguments (the paper's sticky input buses) become
    genuine const-fed operators, and the PR 3 folding pass collapses
    them at compile time — asserted through the PassReport."""
    def poly(x, a, b):
        return (a * b + a) * x + (a - b) * x

    run = compile_fn(poly, I32, I32, I32, backend="xla",
                     block_cycles=4, optimize="full",
                     const_args={1: 6, 2: 7})
    rep = run.report
    assert rep is not None and rep.folded >= 2
    assert rep.nodes_after < rep.nodes_before
    assert len(run.graph.nodes) < len(run.traced.nodes)
    x = np.asarray([0, 1, -2, 10], I32)
    want = I32(6 * 7 + 6) * x + I32(6 - 7) * x
    res = run(run.make_feeds(x))
    out = run.out_arcs[0]
    assert res.counts[out] == 4
    assert int(np.asarray(res.outputs[out])) == int(want[-1])
    # the authored (unoptimized) fabric agrees with the folded one
    want_ref = run_reference(run.traced, run.make_feeds(x))
    assert want_ref.counts[out] == 4
    assert int(np.asarray(want_ref.outputs[out])) == int(want[-1])


def test_float_programs_reference_and_xla():
    """Float fabrics (pallas is int32-only) stay bit-identical to the
    engines' float ALU semantics, including -0.0 through neg."""
    def f(x, y):
        return 2.5 * x + y / 2.0 - jnp.maximum(-x, y)

    prog = trace(f, np.float32, np.float32)
    x = np.asarray([1.5, -2.0, 0.0, -0.0], np.float32)
    y = np.asarray([0.5, 0.25, -1.0, 4.0], np.float32)
    want = (np.float32(2.5) * x + y / np.float32(2.0)
            - np.maximum(-x, y)).astype(np.float32)
    feeds = prog.make_feeds(x, y)
    ref = run_reference(prog, feeds, dtype=np.float32)
    eng = DataflowEngine(prog, dtype=np.float32, backend="xla",
                         block_cycles=4, optimize=True)
    for res in (ref, eng.run(feeds)):
        assert res.counts[prog.out_arc] == 4
        got = np.asarray(res.outputs[prog.out_arc], np.float32)
        np.testing.assert_array_equal(got, want[-1])
    # neg of +0.0 must produce -0.0 (MUL by -1, not SUB from 0)
    pneg = trace(lambda x: -x, np.float32)
    rneg = run_reference(pneg, pneg.make_feeds(
        np.asarray([0.0], np.float32)), dtype=np.float32)
    assert np.signbit(np.asarray(rneg.outputs[pneg.out_arc]))


def test_float_consts_roundtrip_through_asm_signature():
    prog = trace(lambda x: 2.5 * x - 0.75, np.float32)
    text = asm.emit(prog)
    g2 = asm.parse(text)
    assert sorted(g2.consts.values()) == sorted(prog.consts.values())
    assert asm.emit(g2) == text         # emit is a fixed point


# ---------------------------------------------------------------------------
# traced regenerations of hand-assembled library benches
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("hand,traced", [
    ("dot_prod", "dot_prod_traced"),
    ("pop_count", "pop_count_traced"),
    ("fir", "fir_traced"),
])
def test_traced_bench_matches_hand_built(hand, traced):
    hb = library.BENCHES[hand]()
    tb = library.BENCHES[traced]()
    rng = np.random.default_rng(11)
    fh = library.random_feeds(hand, hb, 4, rng)
    rng = np.random.default_rng(11)     # same arguments for both
    ft = library.random_feeds(traced, tb, 4, rng)
    want = run_reference(hb.graph, fh)
    got = run_reference(tb.graph, ft)
    assert got.counts[tb.out_arc] == want.counts[hb.out_arc] == 4
    assert int(np.asarray(got.outputs[tb.out_arc])) == \
        int(np.asarray(want.outputs[hb.out_arc]))


def test_traced_benches_run_every_backend_optimized():
    for name in ("horner", "saxpy", "relu_chain", "fir_traced"):
        bench = library.BENCHES[name]()
        feeds = library.random_feeds(name, bench, 3,
                                     np.random.default_rng(5))
        want = run_reference(bench.graph, feeds)
        for backend in ("xla", "pallas"):
            run = compile_graph(bench.graph, backend=backend,
                                block_cycles=4, optimize="full")
            got = run(feeds)
            for a, c in want.counts.items():
                assert got.counts[a] == c, (name, backend, a)
                if c:
                    assert int(np.asarray(got.outputs[a])) == \
                        int(np.asarray(want.outputs[a])), (name, backend)


def test_fir_traced_identity_splice_visible():
    """fir_traced's c0 == 1 tap is a MUL-by-one the identity pass
    splices out, mirroring the hand-built fir bench's contract."""
    bench = library.BENCHES["fir_traced"]()
    run = compile_graph(bench.graph, backend="xla", block_cycles=4,
                        optimize="full")
    assert run.report.identities >= 1
    assert len(run.graph.nodes) < len(bench.graph.nodes)


# ---------------------------------------------------------------------------
# serving integration: a traced program is just another asm signature
# ---------------------------------------------------------------------------
def test_traced_program_through_dataflow_server():
    from repro.serve.dataflow_server import (cached_engine,
                                             clear_engine_cache)
    from repro.serve.dataflow_server import DataflowServer
    clear_engine_cache()
    prog = trace(_prog_where, I32, I32, name="where_srv")
    prog2 = trace(_prog_where, I32, I32, name="where_srv")
    # structurally-equal traces share one compiled engine via the
    # signature cache
    e1 = cached_engine(prog, backend="xla", block_cycles=4)
    e2 = cached_engine(prog2, backend="xla", block_cycles=4)
    assert e1 is e2
    srv = DataflowServer(prog, slots=2, block_cycles=4, backend="xla")
    rng = np.random.default_rng(3)
    reqs = [prog.make_feeds(rng.integers(-99, 99, (k,)),
                            rng.integers(-99, 99, (k,)))
            for k in (1, 4, 2, 6, 3)]
    uids = [srv.submit(f) for f in reqs]
    got = {r.uid: r for r in srv.drain()}
    eng = DataflowEngine(prog, backend="xla", block_cycles=4)
    for uid, feeds in zip(uids, reqs):
        solo = eng.run(feeds)
        r = got[uid].engine
        assert r.counts == solo.counts and r.cycles == solo.cycles \
            and r.fired == solo.fired
        assert int(np.asarray(r.outputs[prog.out_arc])) == \
            int(np.asarray(solo.outputs[prog.out_arc]))
        assert got[uid].metrics.tokens_out == sum(solo.counts.values())


def test_dataflow_server_for_fn():
    from repro.serve.dataflow_server import DataflowServer
    srv = DataflowServer.for_fn(_prog_where, I32, I32, slots=2,
                                block_cycles=4, backend="xla")
    x = np.asarray([5, 1, 7], I32)
    y = np.asarray([2, 9, 7], I32)
    srv.submit(srv.make_feeds(x, y))
    (r,) = srv.drain()
    out = srv.traced.out_arc
    assert r.metrics.tokens_out == 3
    assert int(np.asarray(r.engine.outputs[out])) == \
        int(_ref_where(x, y)[-1])


# ---------------------------------------------------------------------------
# precise rejection + feed adapter behavior
# ---------------------------------------------------------------------------
def test_lowering_errors_name_the_primitive():
    with pytest.raises(LoweringError, match="'div'"):
        trace(lambda x, y: x // y, I32, I32)
    with pytest.raises(LoweringError, match="'sin'"):
        trace(lambda x: jnp.sin(x), np.float32)
    with pytest.raises(LoweringError, match="'rem'"):
        trace(lambda x, y: jnp.maximum(x % y, 0), I32, I32)
    with pytest.raises(LoweringError, match="'integer_pow'"):
        trace(lambda x: x ** 3, np.float32)
    with pytest.raises(LoweringError, match="shift_right_logical"):
        trace(lambda x, y: jax.lax.shift_right_logical(x, y), I32, I32)
    with pytest.raises(LoweringError, match="compile-time constant"):
        trace(lambda x: 5, I32)
    with pytest.raises(LoweringError, match="mixed aval dtypes"):
        trace(lambda x, y: x + y, I32, np.float32)
    with pytest.raises(LoweringError, match="shape"):
        trace(lambda x: x, jax.ShapeDtypeStruct((4,), I32))
    with pytest.raises(LoweringError, match="at least one aval"):
        trace(lambda: 1)
    with pytest.raises(LoweringError, match="const-bound"):
        trace(lambda x: x + 1, I32, const_args={0: 3})
    with pytest.raises(LoweringError, match="out of range"):
        trace(lambda x, y: x + y, I32, I32, const_args={7: 3})


def test_feed_adapter_contract():
    prog = trace(lambda x, y: x + y, I32, I32)
    with pytest.raises(ValueError, match="expected 2 argument streams"):
        prog.make_feeds([1, 2])
    with pytest.raises(ValueError, match="tokens"):
        prog.make_feeds([1, 2, 3], [1, 2])
    with pytest.raises(ValueError, match="shape"):
        prog.make_feeds(np.zeros((2, 2)), [1, 2])
    # scalars broadcast to the common stream length
    feeds = prog.make_feeds(7, [1, 2, 3])
    assert feeds["in0"].shape == (3,) and (feeds["in0"] == 7).all()
    # unused arguments take (and ignore) a stream slot
    p2 = trace(lambda x, y: x * 2, I32, I32)
    assert p2.arg_arcs[1] is None
    r = run_reference(p2, p2.make_feeds([1, 2], [9, 9]))
    assert int(np.asarray(r.outputs[p2.out_arc])) == 4


def test_multi_output_and_duplicate_outputs():
    prog = trace(lambda x, y: (x + y, x - y, x + y), I32, I32)
    assert len(prog.out_arcs) == 3
    assert len(set(prog.out_arcs)) == 3     # duplicates get own buses
    feeds = prog.make_feeds([5, 8], [2, 3])
    r = run_reference(prog, feeds)
    vals = [int(np.asarray(r.outputs[a])) for a in prog.out_arcs]
    assert vals == [11, 5, 11]
    assert all(r.counts[a] == 2 for a in prog.out_arcs)


def test_passthrough_output_keeps_arc_classes_disjoint():
    prog = trace(lambda x, y: x, I32, I32)
    prog.validate()
    assert set(prog.input_arcs()).isdisjoint(prog.output_arcs())
    r = run_reference(prog, prog.make_feeds([3, 1, 4], [0, 0, 0]))
    assert r.counts[prog.out_arc] == 3
    assert int(np.asarray(r.outputs[prog.out_arc])) == 4


def test_trace_is_deterministic():
    a = asm.emit(trace(_prog_clamp_relu, I32))
    b = asm.emit(trace(_prog_clamp_relu, I32))
    assert a == b
