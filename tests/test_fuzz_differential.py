"""Differential fuzzing across every executor (ISSUE 4 + ISSUE 5).

Three unbounded case generators feed one oracle:

* random well-formed acyclic GRAPHS over the full opcode vocabulary
  (valid ARITY, one producer/receiver per arc, every opcode class
  reachable across the pool — asserted below);
* random traceable EXPRESSIONS lowered through the ``repro.front``
  frontend, whose plain-numpy evaluation is an independent oracle for
  the synthesized fabric;
* random LOOP PROGRAMS (ISSUE 5): bounded-trip ``lax`` control flow
  (static fori -> carry-only scan, traced-bound fori -> while with a
  synthetic invariant carry) over carries drawn from the int32 /
  uint32 / float32 dtype set, lowered onto the cyclic loop schema and
  pinned bit-identical across reference x xla x pallas x optimize
  levels AND against plain jax execution of the same function.

Contract per case, against the pure-numpy reference engine:

* optimize off and "spec" engines (xla and pallas, at every block size
  K) reproduce EVERY EngineResult field bit-identically — even when
  the run truncates at the cycle cap (free-running const subgraphs are
  legal fuzz output, and block partitioning must not change capped
  semantics);
* optimize "full" engines reproduce the *rewritten* graph's reference
  run bit-identically, and when the authored fabric quiesces under the
  cap, the rewritten one drains identical last values and counts.

Scale: the default is the seeded CI quick subset (every backend and
optimize level; K rotates through {1, 4, 16} across cases).  Set
``REPRO_FUZZ=full`` for the full local matrix — 16 graph structures
and 10 expression structures x 8 feed streams each (208 cases, >= 200)
with the complete K cross product per case.
"""
import os

import numpy as np
import pytest

from repro.core import passes
from repro.core.engine import DataflowEngine, run_reference
from repro.core.graph import ARITY, Graph, Op
from repro.front import trace

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # CI installs hypothesis; local runs may not
    HAVE_HYPOTHESIS = False

FULL = os.environ.get("REPRO_FUZZ", "").lower() == "full"
N_GRAPHS, N_PROGS, N_FEEDS = (16, 10, 8) if FULL else (5, 4, 2)
N_LOOPS = 12 if FULL else 3
KS_ALL = (1, 4, 16)
CAP = 192                    # cycle cap: free-running fabrics are fine

EDGE_VALS = np.asarray(
    [-(2 ** 31), -(2 ** 31) + 1, -40, -2, -1, 0, 1, 2, 3, 5,
     31, 32, 40, 2 ** 31 - 1], np.int64)


def _ks(idx):
    """Full mode: the whole K cross per case; quick: rotate coverage."""
    return KS_ALL if FULL else (KS_ALL[idx % 3],)


# ---------------------------------------------------------------------------
# generator 1: random well-formed acyclic graphs
# ---------------------------------------------------------------------------
ALL_OPS = list(Op)


def random_graph(seed: int) -> Graph:
    """Acyclic by construction: node inputs only consume arcs that
    already exist (open producer outputs, fresh environment streams,
    or const buses)."""
    rng = np.random.default_rng(1000 + seed)
    g = Graph(name=f"fuzz{seed}")
    open_arcs: list[str] = []
    counters = {"a": 0, "x": 0, "c": 0}

    def fresh(tag):
        counters[tag] += 1
        return f"{tag}{counters[tag]}"

    def const_arc():
        arc = fresh("c")
        g.const(arc, int(rng.choice(EDGE_VALS)))
        return arc

    def src(force_env=False):
        r = rng.random()
        if force_env:
            return fresh("x")
        if open_arcs and r < 0.55:
            return open_arcs.pop(int(rng.integers(len(open_arcs))))
        if r < 0.75:
            return const_arc()
        return fresh("x")

    n_nodes = int(rng.integers(4, 11))
    for i in range(n_nodes):
        # coverage bias: node 0's opcode walks the whole vocabulary
        # across the pool, the rest draw uniformly
        op = ALL_OPS[seed % len(ALL_OPS)] if i == 0 \
            else ALL_OPS[int(rng.integers(len(ALL_OPS)))]
        n_in, n_out = ARITY[op]
        ins = [src(force_env=(i == 0 and k == 0)) for k in range(n_in)]
        outs = [fresh("a") for _ in range(n_out)]
        g.add(op, ins, outs)
        open_arcs.extend(outs)
    if not open_arcs:        # keep at least one drained output bus
        g.add(Op.ADD, [fresh("x"), const_arc()], ["z_out"])
    g.validate()
    return g


def random_feeds_for(g: Graph, rng, k: int) -> dict:
    feeds = {}
    for a in g.input_arcs():
        if rng.random() < 0.5:
            feeds[a] = rng.choice(EDGE_VALS, size=k).astype(np.int32)
        else:
            feeds[a] = rng.integers(-100, 100, (k,), dtype=np.int32)
    return feeds


def test_graph_generator_reaches_every_opcode_class():
    seen = set()
    for seed in range(24):
        seen |= {n.op for n in random_graph(seed).nodes}
    assert seen == set(Op)


# ---------------------------------------------------------------------------
# the differential matrix (shared by both generators)
# ---------------------------------------------------------------------------
def _same_bits(a, b) -> bool:
    """Bit-exact scalar comparison (keeps signed zeros and NaNs honest
    for the float-dtype loop cases)."""
    return np.asarray(a).tobytes() == np.asarray(b).tobytes()


def _check_full(got, want, tag):
    assert got.cycles == want.cycles, (tag, got.cycles, want.cycles)
    assert got.fired == want.fired, (tag, got.fired, want.fired)
    assert got.counts == want.counts, (tag, got.counts, want.counts)
    for a, c in want.counts.items():
        if c:
            assert _same_bits(got.outputs[a], want.outputs[a]), (tag, a)


def _check_observables(got, want, tag):
    for a, c in want.counts.items():
        assert got.counts[a] == c, (tag, a)
        if c:
            assert _same_bits(got.outputs[a], want.outputs[a]), (tag, a)


def differential_case(g: Graph, feeds_list, Ks, tag, dtype=np.int32):
    """One graph, many feed streams, the whole backend x optimize x K
    matrix.  Engines compile once per (backend, K, level) and rerun
    across the feed streams.  Non-int32 dtypes skip the pallas engine
    (its kernels are scalar-int32-only)."""
    dtype = np.dtype(dtype)
    g_full, _ = passes.optimize_graph(g, dtype=dtype)
    oracles = [run_reference(g, f, dtype=dtype, max_cycles=CAP)
               for f in feeds_list]
    oracles_full = [run_reference(g_full, f, dtype=dtype, max_cycles=CAP)
                    for f in feeds_list]
    # the reference backend is the oracle itself; pin the plumbing once
    ref_eng = DataflowEngine(g, dtype=dtype, backend="reference",
                             max_cycles=CAP)
    _check_full(ref_eng.run(feeds_list[0]), oracles[0], (tag, "ref"))
    for want, want_full in zip(oracles, oracles_full):
        if want.cycles < CAP:    # authored fabric quiesced: rewrite
            _check_observables(want_full, want, (tag, "rewrite"))
    for backend in ("xla", "pallas"):
        if backend == "pallas" and dtype != np.int32:
            continue
        for K in Ks:
            e_off = DataflowEngine(g, dtype=dtype, backend=backend,
                                   block_cycles=K, max_cycles=CAP)
            e_spec = DataflowEngine(g, dtype=dtype, backend=backend,
                                    block_cycles=K, max_cycles=CAP,
                                    optimize=True)
            e_full = DataflowEngine(g_full, dtype=dtype, backend=backend,
                                    block_cycles=K, max_cycles=CAP,
                                    optimize=True)
            # "sched" joins the optimize matrix (ISSUE 8): static
            # firing schedules on schedulable fabrics, silent dynamic
            # fallback on the rest — bit-identical either way
            e_sched = DataflowEngine(g_full, dtype=dtype, backend=backend,
                                     block_cycles=K, max_cycles=CAP,
                                     optimize=True, schedule="auto")
            for i, f in enumerate(feeds_list):
                t = (tag, backend, K, i)
                _check_full(e_off.run(f), oracles[i], (*t, "off"))
                _check_full(e_spec.run(f), oracles[i], (*t, "spec"))
                _check_full(e_full.run(f), oracles_full[i], (*t, "full"))
                _check_full(e_sched.run(f), oracles_full[i],
                            (*t, "sched"))


@pytest.mark.parametrize("seed", range(N_GRAPHS))
def test_fuzz_random_graphs(seed):
    g = random_graph(seed)
    rng = np.random.default_rng(5000 + seed)
    feeds_list = [random_feeds_for(g, rng, 3) for _ in range(N_FEEDS)]
    differential_case(g, feeds_list, _ks(seed), f"graph{seed}")


# ---------------------------------------------------------------------------
# generator 2: random traceable expressions (numpy is the oracle)
# ---------------------------------------------------------------------------
LIT_VALS = [-5, -3, -1, 0, 1, 2, 3, 7, 31]
_BIN = ["add", "sub", "mul", "and", "or", "xor", "max", "min"]
_CMP = ["gt", "ge", "lt", "le", "eq", "ne"]


def random_expr(seed: int, n_args: int):
    """An expression tree over supported ops; the top level always
    depends on arg 0 so the program is never a compile-time constant."""
    rng = np.random.default_rng(2000 + seed)

    def val(d):
        r = rng.random()
        if d <= 0 or r < 0.25:
            return ("lit", int(rng.choice(LIT_VALS))) if r < 0.1 \
                else ("arg", int(rng.integers(n_args)))
        r = rng.random()
        if r < 0.45:
            return ("bin", _BIN[int(rng.integers(len(_BIN)))],
                    val(d - 1), val(d - 1))
        if r < 0.55:
            return ("shift", "shl" if rng.random() < 0.5 else "shr",
                    val(d - 1), int(rng.integers(0, 9)))
        if r < 0.65:
            return ("neg", val(d - 1))
        if r < 0.72:
            return ("abs", val(d - 1))
        if r < 0.80:
            lo = int(rng.integers(-20, 10))
            return ("clamp", val(d - 1), lo, lo + int(rng.integers(1, 40)))
        if r < 0.87:
            return ("pow", val(d - 1), int(rng.integers(2, 4)))
        return ("where",
                (_CMP[int(rng.integers(len(_CMP)))], val(d - 1),
                 val(d - 1)),
                val(d - 1), val(d - 1))

    return ("bin", "add", ("arg", 0), val(3))


def eval_expr(t, args, m):
    """Evaluate a tree with module `m` (jnp on traced scalars, np on
    int32 arrays) — the same source of truth for both sides."""
    kind = t[0]
    if kind == "arg":
        return args[t[1]]
    if kind == "lit":
        return m.int32(t[1]) if m is np else t[1]
    if kind == "bin":
        a, b = eval_expr(t[2], args, m), eval_expr(t[3], args, m)
        return {"add": lambda: a + b, "sub": lambda: a - b,
                "mul": lambda: a * b, "and": lambda: a & b,
                "or": lambda: a | b, "xor": lambda: a ^ b,
                "max": lambda: m.maximum(a, b),
                "min": lambda: m.minimum(a, b)}[t[1]]()
    if kind == "shift":
        a = eval_expr(t[2], args, m)
        return a << t[3] if t[1] == "shl" else a >> t[3]
    if kind == "neg":
        return -eval_expr(t[1], args, m)
    if kind == "abs":
        return abs(eval_expr(t[1], args, m))
    if kind == "clamp":
        return m.clip(eval_expr(t[1], args, m), t[2], t[3])
    if kind == "pow":
        return eval_expr(t[1], args, m) ** t[2]
    if kind == "where":
        cmp, av, bv = t[1]
        a, b = eval_expr(av, args, m), eval_expr(bv, args, m)
        c = {"gt": a > b, "ge": a >= b, "lt": a < b, "le": a <= b,
             "eq": a == b, "ne": a != b}[cmp]
        return m.where(c, eval_expr(t[2], args, m),
                       eval_expr(t[3], args, m))
    raise AssertionError(t)


@pytest.mark.parametrize("seed", range(N_PROGS))
def test_fuzz_random_expressions(seed):
    import jax.numpy as jnp
    rng = np.random.default_rng(3000 + seed)
    n_args = int(rng.integers(1, 4))
    tree = random_expr(seed, n_args)
    prog = trace(lambda *a: eval_expr(tree, a, jnp),
                 *([np.int32] * n_args), name=f"expr{seed}")
    k = 3
    feeds_list, wants = [], []
    for _ in range(N_FEEDS):
        streams = [rng.integers(-50, 50, (k,), dtype=np.int32)
                   for _ in range(n_args)]
        feeds_list.append(prog.make_feeds(*streams))
        wants.append(np.asarray(eval_expr(tree, streams, np), np.int32))
    # numpy is an independent oracle for the synthesized fabric
    for f, want in zip(feeds_list, wants):
        r = run_reference(prog, f, max_cycles=CAP)
        assert r.counts[prog.out_arc] == k, (seed, "count")
        assert int(np.asarray(r.outputs[prog.out_arc])) == \
            int(want[-1]), (seed, "numpy-differential")
    # and the full executor matrix agrees bit-for-bit
    differential_case(prog, feeds_list, _ks(seed), f"expr{seed}")


# ---------------------------------------------------------------------------
# generator 3: random bounded loop programs (jax itself is the oracle)
# ---------------------------------------------------------------------------
_LOOP_DTYPES = (np.int32, np.uint32, np.float32)
_LOOP_BIN_INT = ["add", "sub", "mul", "and", "or", "xor", "max", "min"]
_LOOP_BIN_FLT = ["add", "sub", "mul", "max", "min"]
_LOOP_CMP = ["gt", "ge", "lt", "le", "eq", "ne"]


def random_loop_case(seed: int):
    """-> (fn, n_args, dtype, static).  ``fn`` is a jax program whose
    whole body is a bounded loop: static trip count (fori -> carry-only
    scan) for every dtype, traced-bound fori (-> while with a synthetic
    invariant carry) additionally for int32.  Carry updates draw from
    the dtype's closed op set (wraparound / IEEE are the contract) with
    optional ``jnp.where`` data-dependence; float operands stay in
    [-2, 2] over <= 5 trips so no value can overflow to inf (bit-exact
    comparison would still hold, but finite values are a sharper
    differential)."""
    import jax.numpy as jnp
    from jax import lax

    rng = np.random.default_rng(7000 + seed)
    dtype = _LOOP_DTYPES[seed % 3]
    is_f = dtype == np.float32
    n_args = int(rng.integers(1, 3))
    n_carry = int(rng.integers(1, 3))
    T = int(rng.integers(0, 6))
    static = bool(dtype != np.int32 or rng.random() < 0.5)
    bins = _LOOP_BIN_FLT if is_f else _LOOP_BIN_INT
    specs = []
    for _ in range(n_carry):
        op = bins[int(rng.integers(len(bins)))]
        a_i, b_i = (int(rng.integers(n_carry)) for _ in range(2))
        wh = (_LOOP_CMP[int(rng.integers(len(_LOOP_CMP)))],
              int(rng.integers(n_carry))) if rng.random() < 0.4 else None
        specs.append((op, a_i, b_i, wh))

    def step(c):
        new = []
        for op, a_i, b_i, wh in specs:
            a, b = c[a_i], c[b_i]
            v = {"add": lambda: a + b, "sub": lambda: a - b,
                 "mul": lambda: a * b, "and": lambda: a & b,
                 "or": lambda: a | b, "xor": lambda: a ^ b,
                 "max": lambda: jnp.maximum(a, b),
                 "min": lambda: jnp.minimum(a, b)}[op]()
            if wh is not None:
                cmp, w_i = wh
                w = c[w_i]
                cond = {"gt": a > w, "ge": a >= w, "lt": a < w,
                        "le": a <= w, "eq": a == w, "ne": a != w}[cmp]
                v = jnp.where(cond, v, b)
            new.append(v)
        return tuple(new)

    def fn(*args):
        init = tuple(args[j % n_args] for j in range(n_carry))
        if static:
            r = lax.fori_loop(0, T, lambda i, c: step(c), init)
        else:       # data-dependent bounded trip count (int32 only)
            n = jnp.clip(args[0], 0, T)
            r = lax.fori_loop(0, n, lambda i, c: step(c), init)
        return r[0]

    return fn, n_args, dtype, static


def _loop_args(rng, dtype, n_args):
    if dtype == np.float32:
        return [np.float32(np.round(rng.uniform(-2, 2), 3))
                for _ in range(n_args)]
    if dtype == np.uint32:
        return [np.uint32(rng.integers(0, 40)) for _ in range(n_args)]
    return [np.int32(rng.integers(-20, 20)) for _ in range(n_args)]


@pytest.mark.parametrize("seed", range(N_LOOPS))
def test_fuzz_random_loop_programs(seed):
    fn, n_args, dtype, static = random_loop_case(seed)
    prog = trace(fn, *([dtype] * n_args), name=f"loop{seed}")
    assert prog.has_loops and prog.is_cyclic()
    rng = np.random.default_rng(8000 + seed)
    feeds_list = []
    with np.errstate(all="ignore"):
        for _ in range(N_FEEDS):
            args = _loop_args(rng, dtype, n_args)
            feeds = prog.make_feeds(*[[a] for a in args])
            want = np.asarray(fn(*args), dtype)   # plain jax execution
            r = run_reference(prog, feeds, dtype=dtype, max_cycles=CAP)
            assert r.cycles < CAP, (seed, "must quiesce under the cap")
            assert r.counts[prog.out_arc] == 1, (seed, "one initiation")
            assert np.asarray(r.outputs[prog.out_arc]).tobytes() == \
                want.tobytes(), (seed, args, r.outputs, want)
            feeds_list.append(feeds)
    # and the full executor matrix agrees bit-for-bit
    differential_case(prog, feeds_list, _ks(seed), f"loop{seed}",
                      dtype=dtype)


# ---------------------------------------------------------------------------
# hypothesis property layer (CI; local runs without hypothesis skip it)
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2 ** 20),
           fseed=st.integers(min_value=0, max_value=2 ** 20))
    def test_property_random_graph_spec_identity(seed, fseed):
        """Any generated graph, any feeds: the specialized plan is a
        pure layout change on the xla engine."""
        g = random_graph(seed)
        feeds = random_feeds_for(g, np.random.default_rng(fseed), 2)
        want = run_reference(g, feeds, max_cycles=CAP)
        eng = DataflowEngine(g, backend="xla", block_cycles=4,
                             max_cycles=CAP, optimize=True)
        _check_full(eng.run(feeds), want, (seed, fseed))

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2 ** 20),
           fseed=st.integers(min_value=0, max_value=2 ** 20))
    def test_property_random_expression_matches_numpy(seed, fseed):
        import jax.numpy as jnp
        rng = np.random.default_rng(seed)
        n_args = int(rng.integers(1, 4))
        tree = random_expr(seed, n_args)
        prog = trace(lambda *a: eval_expr(tree, a, jnp),
                     *([np.int32] * n_args))
        streams = [np.random.default_rng(fseed + i)
                   .integers(-50, 50, (2,), dtype=np.int32)
                   for i in range(n_args)]
        want = np.asarray(eval_expr(tree, streams, np), np.int32)
        r = run_reference(prog, prog.make_feeds(*streams),
                          max_cycles=CAP)
        assert r.counts[prog.out_arc] == 2
        assert int(np.asarray(r.outputs[prog.out_arc])) == int(want[-1])
