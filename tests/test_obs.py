"""Observability layer (DESIGN.md §12, PR 7).

Acceptance properties:

* **Heisenberg-free profiling** — ``DataflowEngine(profile=True)``
  leaves results bit-identical (outputs / counts / cycles / fired)
  across backends x K x optimize, adds zero device dispatches, and its
  per-node fire counts sum exactly to the aggregate ``fired``.
* **Counter semantics** — the §12 partition invariant holds per node
  (fires + stall_in + stall_out == profiled cycles), per-arc occupancy
  respects the depth-1 register bound, and at K=1 every backend's full
  profile equals the reference oracle's.
* **Trace round-trip** — the server's TraceRecorder exports Chrome
  trace JSON that passes the lifecycle validator on both clocks, and
  its block-clock stamps match ``RequestMetrics`` exactly.
* **Status / validation** — ``Result.status`` precedence (error >
  expired > wedged > truncated > ok) and the typed ``submit``
  validation of ``deadline_blocks`` / ``max_cycles``.
"""
import functools
import itertools
import json

import numpy as np
import pytest

from repro.core import library
from repro.core.engine import DataflowEngine, run_reference
from repro.obs import (MetricsRegistry, TraceInvariantError, TraceRecorder,
                       load_chrome, validate_chrome, validate_snapshot)
from repro.serve.admission import FairQueue
from repro.serve.dataflow_server import DataflowServer
from repro.serve.faults import FaultPlan
from repro.serve.types import (InvalidRequestError, Request, RequestMetrics,
                               Result)

BACKENDS = ("reference", "xla", "pallas")
BENCHES = ("vector_sum", "gcd")          # one acyclic + one loop fabric
KS = (1, 4)


@functools.lru_cache(maxsize=None)
def _bench(name):
    return library.BENCHES[name]()


def _feeds(name, k=6, seed=0):
    return library.random_feeds(name, _bench(name), k,
                                np.random.default_rng(seed))


@functools.lru_cache(maxsize=None)
def _run(name, backend, K, profile, optimize=False):
    eng = DataflowEngine(_bench(name).graph, backend=backend,
                         block_cycles=K, optimize=optimize,
                         profile=profile)
    return eng.run(_feeds(name))


def _same_result(got, want, tag):
    assert got.cycles == want.cycles, tag
    assert got.fired == want.fired, tag
    assert got.counts == want.counts, tag
    for a, c in want.counts.items():
        if c:
            np.testing.assert_array_equal(
                np.asarray(got.outputs[a]), np.asarray(want.outputs[a]),
                err_msg=str((tag, a)))


# ---------------------------------------------------------------------------
# fabric counters: bit-identity, partition invariant, cross-backend
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", BENCHES)
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("K", KS)
def test_profiling_does_not_perturb_results(name, backend, K):
    base = _run(name, backend, K, profile=False)
    prof = _run(name, backend, K, profile=True)
    _same_result(prof, base, (name, backend, K))
    # the unprofiled engine carries no counters at all
    assert base.profile is None and base.node_fires is None
    p = prof.profile
    assert p is not None
    p.check()                                 # §12 partition invariant
    assert p.fired == prof.fired == int(p.node_fires.sum())
    np.testing.assert_array_equal(prof.node_fires, p.node_fires)


@pytest.mark.parametrize("name", BENCHES)
@pytest.mark.parametrize("backend", ("xla", "pallas"))
def test_profiling_adds_zero_dispatches(name, backend):
    base = _run(name, backend, 4, profile=False)
    prof = _run(name, backend, 4, profile=True)
    assert prof.dispatches == base.dispatches
    assert prof.profile.dispatches == base.dispatches


@pytest.mark.parametrize("name", BENCHES)
@pytest.mark.parametrize("K", KS)
def test_node_fires_identical_across_backends(name, K):
    ref = _run(name, "reference", K, profile=True)
    for backend in ("xla", "pallas"):
        got = _run(name, backend, K, profile=True)
        np.testing.assert_array_equal(
            got.node_fires, ref.node_fires, err_msg=(name, backend, K))


@pytest.mark.parametrize("name", BENCHES)
def test_k1_profile_equals_reference_oracle(name):
    """At K=1 the device backends see exactly the cycles the oracle
    simulates, so the *entire* profile (stall attribution and arc
    occupancy included) must match bit-for-bit."""
    ref = _run(name, "reference", 1, profile=True).profile
    for backend in ("xla", "pallas"):
        got = _run(name, backend, 1, profile=True).profile
        for field in ("node_fires", "stall_in", "stall_out",
                      "arc_busy", "arc_hw"):
            np.testing.assert_array_equal(
                getattr(got, field), getattr(ref, field),
                err_msg=(name, backend, field))
        assert got.cycles == ref.cycles


def test_profile_with_optimize_stays_bit_identical():
    base = _run("gcd", "xla", 4, profile=False, optimize=True)
    prof = _run("gcd", "xla", 4, profile=True, optimize=True)
    _same_result(prof, base, "gcd/xla/opt")
    prof.profile.check()
    assert prof.profile.fired == prof.fired
    # the optimized graph must report fires for the optimized nodes
    assert len(prof.profile.node_names) == len(prof.node_fires)


def test_profile_export_roundtrip(tmp_path):
    p = _run("vector_sum", "xla", 4, profile=True).profile
    d = p.to_json()
    assert d["fired"] == p.fired
    assert [n["name"] for n in d["nodes"]] == list(p.node_names)
    path = tmp_path / "prof.json"
    p.save(str(path))
    with open(path) as f:
        assert json.load(f) == d
    assert "hot[" in p.summary()


def test_run_reference_profile_is_free():
    res = run_reference(_bench("vector_sum").graph, _feeds("vector_sum"),
                        profile=True)
    res.profile.check()
    assert res.profile.dispatches == 0
    assert res.profile.fired == res.fired


# ---------------------------------------------------------------------------
# server: trace + metrics + per-request profiles
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _served_scenario():
    """One instrumented serve with every undisputed lifecycle path:
    ok harvests, a queued expiry, a drop-oldest eviction."""
    bench = _bench("vector_sum")
    tr, mr = TraceRecorder(), MetricsRegistry()
    srv = DataflowServer(bench.graph, slots=2, block_cycles=4,
                         backend="xla", policy="drop-oldest", max_queue=5,
                         profile=True, trace=tr, metrics=mr)
    feeds = {uid: _feeds("vector_sum", k=4 + (uid % 3), seed=uid)
             for uid in range(1, 7)}
    for uid, f in feeds.items():
        srv.submit(Request(uid=uid, feeds=f, tenant="ab"[uid % 2],
                           deadline_blocks=1 if uid == 5 else None))
    results = {r.uid: r for r in srv.drain()}
    return srv, tr, mr, results, feeds


def test_scenario_covers_the_lifecycle():
    srv, tr, mr, results, feeds = _served_scenario()
    assert sorted(results) == [1, 2, 3, 4, 5, 6]  # every uid answered
    statuses = {r.status for r in results.values()}
    assert "ok" in statuses
    assert "error" in statuses          # uid 1: drop-oldest victim
    assert results[1].status == "error"
    assert results[5].status == "expired"
    kinds = {e.kind for e in tr.events}
    assert {"submit", "admit", "harvest", "drop", "expire"} <= kinds


def test_trace_export_roundtrip_invariants(tmp_path):
    srv, tr, mr, results, feeds = _served_scenario()
    for clock in ("block", "wall"):
        info = validate_chrome(tr.to_chrome(clock))
        assert info["uids"] == 6 and info["events"] > 0
    path = tmp_path / "trace.json"
    tr.save(str(path))
    assert validate_chrome(load_chrome(str(path)))["uids"] == 6


def test_trace_block_stamps_match_request_metrics():
    srv, tr, mr, results, feeds = _served_scenario()
    by_uid = {}
    for ev in tr.events:
        if ev.uid is not None:
            by_uid.setdefault(ev.uid, []).append(ev)
    for uid, res in results.items():
        m = res.metrics
        evs = by_uid[uid]
        submit = [e for e in evs if e.kind == "submit"]
        assert len(submit) == 1 and submit[0].block == m.queued_block
        admits = [e for e in evs if e.kind == "admit"]
        if m.slot >= 0:
            assert m.admitted_block in [e.block for e in admits]
        terminal = [e for e in evs
                    if e.kind in ("harvest", "expire", "drop")]
        assert len(terminal) == 1
        assert terminal[0].block == m.finished_block
        assert terminal[0].status == res.status


def test_metrics_snapshot_matches_results():
    srv, tr, mr, results, feeds = _served_scenario()
    snap = mr.snapshot()
    validate_snapshot(snap)
    c = snap["counters"]

    def total(name):
        return sum(v for k, v in c.items()
                   if k == name or k.startswith(name + "{"))

    assert total("requests_submitted") == 6
    by_status = {}
    for r in results.values():
        by_status[r.status] = by_status.get(r.status, 0) + 1
    for status, n in by_status.items():
        assert c[f"requests_finished{{status={status}}}"] == n
    assert total("requests_dropped") == 1
    assert snap["gauges"]["queue_depth"]["value"] == 0   # drained
    assert any(k.startswith("queue_wait_blocks")
               for k in snap["histograms"])


def test_server_profile_matches_solo_profiled_run():
    srv, tr, mr, results, feeds = _served_scenario()
    eng = DataflowEngine(_bench("vector_sum").graph, backend="xla",
                         block_cycles=4, profile=True)
    checked = 0
    for uid, res in results.items():
        if res.status != "ok":
            continue
        p = res.engine.profile
        p.check()
        solo = eng.run(feeds[uid])
        np.testing.assert_array_equal(p.node_fires, solo.node_fires,
                                      err_msg=f"uid {uid}")
        assert p.fired == res.engine.fired == solo.fired
        checked += 1
    assert checked >= 2


def test_fault_injections_land_in_the_trace():
    bench = _bench("vector_sum")
    tr = TraceRecorder()
    plan = FaultPlan(seed=3, poison_uids=(2,), wedge_uids=(3,),
                     dispatch_fail_blocks=(1,), transient_attempts=1)
    srv = DataflowServer(bench.graph, slots=2, block_cycles=4,
                         backend="xla", wedge_timeout_blocks=3,
                         faults=plan, trace=tr)
    for uid in (1, 2, 3):
        srv.submit(Request(uid=uid, feeds=_feeds("vector_sum", k=4,
                                                 seed=uid), tenant="t"))
    results = {r.uid: r for r in srv.drain()}
    kinds = {e.kind for e in tr.events}
    assert "fault" in kinds                  # FaultPlan.notify is wired
    injected = {e.args["injected"] for e in tr.events if e.kind == "fault"}
    assert {"poison", "dispatch-transient"} <= injected
    assert "retry" in kinds and "wedge" in kinds
    assert results[3].status == "wedged"
    validate_chrome(tr.to_chrome())


# ---------------------------------------------------------------------------
# trace validator: each invariant rejects a violating log
# ---------------------------------------------------------------------------
def test_validator_rejects_missing_terminal():
    # tenant-less so no async span masks the lifecycle check
    tr = TraceRecorder()
    tr.record("submit", block=0, uid=1)
    with pytest.raises(TraceInvariantError, match="terminal"):
        validate_chrome(tr.to_chrome())
    # with a tenant the same omission trips the async-balance check
    tr.record("submit", block=1, uid=2, tenant="t")
    with pytest.raises(TraceInvariantError):
        validate_chrome(tr.to_chrome())


def test_validator_rejects_backwards_clock():
    tr = TraceRecorder()
    tr.record("submit", block=5, uid=1, tenant="t")
    tr.record("harvest", block=3, uid=1, tenant="t", status="ok")
    with pytest.raises(TraceInvariantError, match="backwards"):
        validate_chrome(tr.to_chrome())


def test_validator_rejects_unbalanced_slot_span():
    tr = TraceRecorder()
    tr.record("submit", block=0, uid=1, tenant="t")
    tr.record("admit", block=1, uid=1, slot=0, tenant="t")
    tr.record("expire", block=2, uid=1, tenant="t")   # span never closed
    with pytest.raises(TraceInvariantError):
        validate_chrome(tr.to_chrome())


def test_validator_rejects_double_submit():
    tr = TraceRecorder()   # tenant-less: the uid-count check itself fires
    tr.record("submit", block=0, uid=1)
    tr.record("submit", block=1, uid=1)
    tr.record("harvest", block=2, uid=1, status="ok")
    with pytest.raises(TraceInvariantError, match="submitted"):
        validate_chrome(tr.to_chrome())


def test_validator_rejects_malformed_shape():
    with pytest.raises(TraceInvariantError):
        validate_chrome({"traceEvents": [{"ph": "i"}]})
    with pytest.raises(TraceInvariantError):
        validate_chrome({"nope": []})


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------
def test_metrics_primitives_and_snapshot_validation():
    mr = MetricsRegistry()
    mr.counter("reqs").inc(2)
    mr.counter("reqs", tenant="a").inc(1)
    mr.gauge("depth").set(7)
    h = mr.histogram("wait")
    for v in (0.5, 2.0, 100.0):
        h.observe(v)
    snap = mr.snapshot()
    validate_snapshot(snap)
    assert snap["counters"]["reqs"] == 2
    assert snap["counters"]["reqs{tenant=a}"] == 1
    assert snap["gauges"]["depth"]["value"] == 7
    hist = snap["histograms"]["wait"]
    assert hist["count"] == 3 and hist["sum"] == pytest.approx(102.5)
    with pytest.raises(ValueError):
        validate_snapshot({"counters": 3})


def test_fair_queue_depths():
    q = FairQueue()
    for uid, t in [(1, "a"), (2, "a"), (3, "b"), (4, None)]:
        q.push(Request(uid=uid, feeds={}, tenant=t))
    assert q.depths() == {"a": 2, "b": 1, None: 1}
    q.pop()
    assert q.depths() == {"a": 1, "b": 1, None: 1}
    for _ in range(3):
        q.pop()
    assert q.depths() == {}


# ---------------------------------------------------------------------------
# Result.status precedence + typed submit validation
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("err,exp,wed,tru",
                         list(itertools.product((False, True), repeat=4)))
def test_status_precedence_table(err, exp, wed, tru):
    m = RequestMetrics(slot=0, queued_block=0, admitted_block=0,
                       finished_block=0, queue_wait_blocks=0,
                       residency_blocks=0, residency_cycles=0,
                       tokens_out=0, expired=exp, wedged=wed,
                       truncated=tru)
    r = Result(uid=1, metrics=m,
               error=RuntimeError("boom") if err else None)
    want = ("error" if err else "expired" if exp else
            "wedged" if wed else "truncated" if tru else "ok")
    assert r.status == want


def test_status_without_metrics():
    assert Result(uid=1).status == "ok"
    assert Result(uid=1, error=ValueError("x")).status == "error"


@pytest.mark.parametrize("field,bad", [("deadline_blocks", 0),
                                       ("deadline_blocks", -3),
                                       ("max_cycles", 0),
                                       ("max_cycles", -1)])
def test_submit_validates_request_fields(field, bad):
    srv = DataflowServer(_bench("vector_sum").graph, slots=1,
                         block_cycles=4, backend="xla")
    req = Request(uid=9, feeds=_feeds("vector_sum", k=2), **{field: bad})
    with pytest.raises(InvalidRequestError, match=field):
        srv.submit(req)
    assert issubclass(InvalidRequestError, ValueError)
    # the boundary value 1 is valid, and uid 9 was never double-queued
    assert srv.submit(Request(uid=9, feeds=_feeds("vector_sum", k=2),
                              **{field: 1})) == 9
    assert [r.uid for r in srv.drain()] == [9]
