"""Sharding-rule unit tests (no big meshes: 1-device abstract checks)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_arch
from repro.launch.dryrun import input_specs, model_flops, abstract_params
from repro.configs.base import SHAPES
from repro.parallel import sharding as shd


class FakeMesh:
    """Duck-typed mesh for rule tests (axis sizes only)."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)
        self.devices = np.empty(
            tuple(shape.values()), dtype=object)


@pytest.fixture
def mesh():
    return FakeMesh({"data": 16, "model": 16})


@pytest.fixture
def pod_mesh():
    return FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_param_rules_dense(mesh):
    cfg = get_arch("internlm2-1.8b")
    struct = abstract_params(cfg)
    specs = shd.param_specs(struct, mesh)
    # stacked layers: leading axis unsharded
    wqkv = specs["layers"]["attn"]["wqkv"]
    assert wqkv == P(None, "data", "model")
    wo = specs["layers"]["attn"]["wo"]
    assert wo == P(None, "model", "data")
    # embedding: vocab 92544 % 16 == 0 -> model-sharded
    assert specs["embed"] == P("model", "data")
    # norms replicated
    assert specs["final_norm"]["w"] == P(None)


def test_param_rules_respect_divisibility(mesh):
    cfg = get_arch("whisper-medium")   # vocab 51865: not divisible
    struct = abstract_params(cfg)
    specs = shd.param_specs(struct, mesh)
    assert specs["embed"][0] is None   # vocab axis dropped, not uneven


def test_param_rules_moe(mesh):
    cfg = get_arch("kimi-k2-1t-a32b")
    struct = abstract_params(cfg)
    specs = shd.param_specs(struct, mesh)
    w1 = specs["layers"]["moe"]["w1"]
    assert w1[1] == "model"            # experts -> EP on model axis
    assert specs["layers"]["moe"]["router"][-1] is None


def test_param_rules_multipod(pod_mesh):
    cfg = get_arch("internlm2-1.8b")
    struct = abstract_params(cfg)
    specs = shd.param_specs(struct, pod_mesh)
    wqkv = specs["layers"]["attn"]["wqkv"]
    assert wqkv == P(None, ("pod", "data"), "model")


def test_batch_specs_shard_batch(mesh, pod_mesh):
    cfg = get_arch("internlm2-1.8b")
    batch = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
    assert shd.batch_specs(cfg, mesh, batch, 256)["tokens"] == \
        P("data", None)
    assert shd.batch_specs(cfg, pod_mesh, batch, 256)["tokens"] == \
        P(("pod", "data"), None)
    # unshardable batch (long_500k, B=1) -> replicated
    b1 = {"tokens": jax.ShapeDtypeStruct((1, 1), jnp.int32)}
    assert shd.batch_specs(cfg, mesh, b1, 1)["tokens"] == P(None, None)


def test_cache_specs_kv_heads_vs_hd(mesh):
    cfg = get_arch("stablelm-1.6b")    # kv=32 divisible -> heads sharded
    cache = {"k": jax.ShapeDtypeStruct((24, 128, 1024, 32, 64),
                                       jnp.bfloat16),
             "v": jax.ShapeDtypeStruct((24, 128, 1024, 32, 64),
                                       jnp.bfloat16),
             "len": jax.ShapeDtypeStruct((), jnp.int32)}
    specs = shd.cache_specs(cfg, mesh, cache, 128)
    assert specs["k"] == P(None, "data", None, "model", None)

    cfg2 = get_arch("internlm2-1.8b")  # kv=8 not divisible -> hd sharded
    cache2 = {"k": jax.ShapeDtypeStruct((24, 128, 1024, 8, 128),
                                        jnp.bfloat16),
              "v": jax.ShapeDtypeStruct((24, 128, 1024, 8, 128),
                                        jnp.bfloat16),
              "len": jax.ShapeDtypeStruct((), jnp.int32)}
    specs2 = shd.cache_specs(cfg2, mesh, cache2, 128)
    assert specs2["k"] == P(None, "data", None, None, "model")


def test_cache_specs_seq_shard_for_batch1(mesh):
    cfg = get_arch("zamba2-7b")
    cache = {"k": jax.ShapeDtypeStruct((13, 1, 524288, 32, 112),
                                       jnp.bfloat16),
             "len": jax.ShapeDtypeStruct((), jnp.int32)}
    specs = shd.cache_specs(cfg, mesh, cache, 1)
    # batch=1: sequence dim takes the data axis
    assert specs["k"] == P(None, None, "data", "model", None)


def test_input_specs_cover_all_cells():
    for name in ("starcoder2-7b", "internvl2-76b", "whisper-medium",
                 "rwkv6-1.6b"):
        cfg = get_arch(name)
        for shape in cfg.shapes():
            spec = input_specs(cfg, shape)
            assert "tokens" in spec
            if cfg.frontend == "patches" and shape.kind != "decode":
                assert "patches" in spec
            if cfg.frontend == "frames" and shape.kind != "decode":
                assert "frames" in spec


def test_model_flops_scaling():
    cfg = get_arch("internlm2-1.8b")
    t = model_flops(cfg, SHAPES["train_4k"])
    p = model_flops(cfg, SHAPES["prefill_32k"])
    # train: 6ND over 1M tokens; prefill: 2ND over 1M tokens -> 3x
    assert abs(t / p - 3.0) < 1e-6
    moe = get_arch("kimi-k2-1t-a32b")
    assert model_flops(moe, SHAPES["train_4k"]) < \
        6 * moe.param_count() * 4096 * 256  # active < total
