"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs; plus a prefill+decode step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCHS, get_arch
from repro.models import transformer as tfm


def _batch(cfg, B=2, S=64, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": rng.integers(0, cfg.vocab, (B, S)).astype(np.int32),
        "labels": rng.integers(0, cfg.vocab, (B, S)).astype(np.int32),
    }
    if cfg.frontend == "patches":
        batch["patches"] = rng.normal(
            0, 1, (B, cfg.n_patches, cfg.frontend_dim)).astype(np.float32)
    if cfg.frontend == "frames":
        batch["frames"] = rng.normal(
            0, 1, (B, cfg.enc_seq, cfg.frontend_dim)).astype(np.float32)
    return batch


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_forward_and_loss(name):
    cfg = get_arch(name).reduced()
    params = tfm.init_params(cfg, jax.random.key(0))
    batch = _batch(cfg)
    loss, metrics = jax.jit(
        lambda p, b: tfm.loss_fn(cfg, p, b))(params, batch)
    assert np.isfinite(float(loss)), f"{name}: loss={loss}"
    assert float(metrics["tokens"]) > 0


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_train_step_grads_finite(name):
    cfg = get_arch(name).reduced()
    params = tfm.init_params(cfg, jax.random.key(1))
    batch = _batch(cfg, seed=1)

    def loss_of(p):
        return tfm.loss_fn(cfg, p, batch)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_of))(params)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g))) for g in flat), name
    # at least some gradient signal somewhere
    assert any(float(jnp.abs(g).max()) > 0 for g in flat), name


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_prefill_decode(name):
    cfg = get_arch(name).reduced()
    params = tfm.init_params(cfg, jax.random.key(2))
    B, S = 2, 32
    batch = _batch(cfg, B=B, S=S, seed=2)
    max_len = S + 8
    logits, cache = jax.jit(
        lambda p, b: tfm.prefill(cfg, p, b, max_len=max_len))(params, batch)
    assert logits.shape == (B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    step = jax.jit(lambda p, t, c: tfm.decode_step(cfg, p, t, c))
    for _ in range(3):
        logits, cache = step(params, tok, cache)
        assert logits.shape == (B, cfg.vocab)
        assert np.all(np.isfinite(np.asarray(logits))), name
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]


def test_decode_matches_prefill_dense():
    """Teacher-forced decode must reproduce the full forward logits."""
    cfg = get_arch("internlm2-1.8b").reduced()
    params = tfm.init_params(cfg, jax.random.key(3))
    B, S = 2, 16
    batch = _batch(cfg, B=B, S=S, seed=3)
    h, _ = tfm.forward(cfg, params, batch)
    full_logits = tfm.unembed(cfg, params, h).astype(jnp.float32)

    # prefill first S-4 tokens, then teacher-force the last 4 step by step
    split = S - 4
    pf_batch = {"tokens": batch["tokens"][:, :split]}
    logits, cache = tfm.prefill(cfg, params, pf_batch, max_len=S + 1)
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(full_logits[:, split - 1]),
        rtol=2e-3, atol=2e-3)
    for t in range(split, S):
        tok = batch["tokens"][:, t:t + 1]
        logits, cache = tfm.decode_step(cfg, params, tok, cache)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, t]),
            rtol=2e-3, atol=2e-3)


def test_param_counts_full_configs():
    """Analytic param_count sanity for the FULL configs (no allocation)."""
    n = get_arch("kimi-k2-1t-a32b").param_count()
    assert 0.8e12 < n < 1.4e12, n   # ~1T total
    na = get_arch("kimi-k2-1t-a32b").param_count(active_only=True)
    assert 15e9 < na < 60e9, na     # ~32B active
    n = get_arch("command-r-plus-104b").param_count()
    assert 80e9 < n < 130e9, n
    n = get_arch("internlm2-1.8b").param_count()
    assert 1.2e9 < n < 2.4e9, n
    n = get_arch("rwkv6-1.6b").param_count()
    assert 1.0e9 < n < 2.4e9, n
