"""Layer numerics: flash attention vs naive oracle (hypothesis sweeps),
chunked SSM vs per-token recurrence oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.base import get_arch
from repro.models import ssm
from repro.models.layers import flash_attention, naive_attention


# ---------------------------------------------------------------------------
# flash attention == naive attention
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(
    B=st.integers(1, 3),
    Sq=st.integers(1, 65),
    Hkv=st.sampled_from([1, 2]),
    G=st.sampled_from([1, 3]),
    hd=st.sampled_from([8, 32]),
    causal=st.booleans(),
    qb=st.sampled_from([4, 16, 64]),
    kb=st.sampled_from([8, 32]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_flash_vs_naive(B, Sq, Hkv, G, hd, causal, qb, kb, dtype):
    key = jax.random.key(B * 1000 + Sq)
    k1, k2, k3 = jax.random.split(key, 3)
    H = Hkv * G
    q = jax.random.normal(k1, (B, Sq, H, hd), dtype)
    k = jax.random.normal(k2, (B, Sq, Hkv, hd), dtype)
    v = jax.random.normal(k3, (B, Sq, Hkv, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, q_block=qb, kv_block=kb)
    ref = naive_attention(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_decode_with_cache_offset():
    """q_len=1 decode against a padded cache with kv_len masking."""
    key = jax.random.key(7)
    B, Smax, H, hd = 2, 64, 4, 16
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, 1, H, hd))
    k = jax.random.normal(k2, (B, Smax, H, hd))
    v = jax.random.normal(k3, (B, Smax, H, hd))
    kv_len = 37
    out = flash_attention(q, k, v, causal=True, q_block=1, kv_block=16,
                          q_offset=jnp.int32(kv_len - 1), kv_len=kv_len)
    ref = naive_attention(q, k[:, :kv_len], v[:, :kv_len], causal=True,
                          q_offset=kv_len - 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Mamba2: chunked == per-token recurrence
# ---------------------------------------------------------------------------
def _mamba_cfg():
    return get_arch("zamba2-7b").reduced()


def test_mamba2_chunked_vs_step():
    cfg = _mamba_cfg()
    p = ssm.init_mamba2(cfg, jax.random.key(0))
    B, S = 2, 64
    x = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model),
                          jnp.float32) * 0.5
    y_chunk = ssm.mamba2_block(cfg, p, x, chunk=16)
    # oracle: token-by-token recurrent stepping
    state = ssm.mamba2_init_state(cfg, B)
    ys = []
    for t in range(S):
        y, state = ssm.mamba2_step(cfg, p, x[:, t:t + 1], state)
        ys.append(y)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_step),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_mamba2_chunk_size_invariance(chunk):
    cfg = _mamba_cfg()
    p = ssm.init_mamba2(cfg, jax.random.key(2))
    x = jax.random.normal(jax.random.key(3), (1, 64, cfg.d_model)) * 0.5
    y1 = ssm.mamba2_block(cfg, p, x, chunk=chunk)
    y2 = ssm.mamba2_block(cfg, p, x, chunk=64)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# RWKV6: chunked == per-token recurrence
# ---------------------------------------------------------------------------
def _rwkv_cfg():
    return get_arch("rwkv6-1.6b").reduced()


def _rwkv_step_oracle(cfg, p, x):
    """Naive per-token recurrence for time-mix."""
    from repro.models.ssm import _rwkv_proj, _shift, rwkv6_dims
    d, H, P = rwkv6_dims(cfg)
    B, S, _ = x.shape
    xs = _shift(x)
    r, k, v, g, logw = _rwkv_proj(cfg, p, x, xs)
    r, k, v = (t.astype(jnp.float32) for t in (r, k, v))
    u = p["u"].astype(jnp.float32)
    w = jnp.exp(logw)
    Sst = jnp.zeros((B, H, P, P))
    ys = []
    for t in range(S):
        rt, kt, vt, wt = r[:, t], k[:, t], v[:, t], w[:, t]
        att = Sst + u[None, :, :, None] * (kt[..., None] * vt[:, :, None])
        yt = jnp.einsum("bhp,bhpv->bhv", rt, att)
        Sst = wt[..., None] * Sst + kt[..., None] * vt[:, :, None]
        ys.append(yt)
    y = jnp.stack(ys, axis=1)                       # [B,S,H,P]
    # same output path as rwkv6_timemix
    y = y * jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + 1e-5)
    y = y.reshape(B, S, d) * p["ln_w"].astype(jnp.float32)
    return (y.astype(x.dtype) * g) @ p["Wo"].astype(x.dtype)


def test_rwkv6_chunked_vs_step():
    cfg = _rwkv_cfg()
    p = ssm.init_rwkv6(cfg, jax.random.key(4))
    B, S = 2, 64
    x = jax.random.normal(jax.random.key(5), (B, S, cfg.d_model)) * 0.5
    y_chunk, _ = ssm.rwkv6_timemix(cfg, p, x, chunk=16)
    y_ref = _rwkv_step_oracle(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_ref),
                               rtol=3e-4, atol=3e-4)


def test_rwkv6_state_continuation():
    """Processing [a;b] equals processing a then b with carried state."""
    cfg = _rwkv_cfg()
    p = ssm.init_rwkv6(cfg, jax.random.key(6))
    x = jax.random.normal(jax.random.key(7), (1, 64, cfg.d_model)) * 0.5
    y_full, _ = ssm.rwkv6_timemix(cfg, p, x, chunk=16)
    y1, st = ssm.rwkv6_timemix(cfg, p, x[:, :32], chunk=16)
    y2, _ = ssm.rwkv6_timemix(cfg, p, x[:, 32:], state=st, chunk=16)
    np.testing.assert_allclose(np.asarray(y_full[:, 32:]), np.asarray(y2),
                               rtol=3e-4, atol=3e-4)
