"""Loop frontend (ISSUE 5): lax control flow -> cyclic loop fabrics.

The acceptance property: a ``lax.while_loop``-bearing traced program
with a data-dependent trip count (gcd) compiles through the single
``compile()`` entry point and runs bit-identical across reference x
xla x pallas — and equal to plain jax execution of the same function;
region-scoped passes win >= 1 fold on a loop-bearing graph without
changing outputs or token counts; the DataflowServer serves it end to
end with exact per-request token metrics.

Plus the schema's edge cases: fori_loop with traced bounds (streamy
loop invariant -> synthetic pass-through carry), static fori_loop
(carry-only scan), zero-trip loops, nested loops, literal next-state
materialization, const_args invariants as sticky buses, and the
single-initiation feed contract.
"""
import math

import numpy as np
import pytest

import jax.numpy as jnp
from jax import lax

from repro.core import asm, library, passes
from repro.core.compile import GraphTraits, compile, compile_fn
from repro.core.engine import DataflowEngine, run_reference
from repro.front import LoweringError, trace

I32 = np.int32


def _gcd_fn():
    def gcd(a, b):
        def body(c):
            x, y = c
            return (jnp.where(x > y, x - y, x),
                    jnp.where(x > y, y, y - x))
        return lax.while_loop(lambda c: c[0] != c[1], body, (a, b))[0]
    return gcd


def _check_full(got, want, tag):
    assert got.cycles == want.cycles, (tag, got.cycles, want.cycles)
    assert got.fired == want.fired, (tag, got.fired, want.fired)
    assert got.counts == want.counts, (tag, got.counts, want.counts)
    for a, c in want.counts.items():
        if c:
            assert np.asarray(got.outputs[a]).item() == \
                np.asarray(want.outputs[a]).item(), (tag, a)


# ---------------------------------------------------------------------------
# acceptance: gcd through the single compile() entry point
# ---------------------------------------------------------------------------
def test_gcd_bit_identical_across_executors_and_jax():
    gcd = _gcd_fn()
    prog = trace(gcd, I32, I32, name="gcd")
    assert prog.has_loops and prog.is_cyclic()
    cases = [(12, 18), (7, 7), (100, 64), (81, 27), (1, 99), (360, 84)]
    for a, b in cases:
        feeds = prog.make_feeds([a], [b])
        want = run_reference(prog, feeds)
        # one initiation -> exactly one result token, equal to python
        # AND plain jax execution of the same function
        assert want.counts[prog.out_arc] == 1
        got = np.asarray(want.outputs[prog.out_arc]).item()
        assert got == math.gcd(a, b) == int(gcd(jnp.int32(a),
                                                jnp.int32(b)))
        for backend in ("reference", "xla", "pallas"):
            for K in (1, 16):
                run = compile(prog, backend=backend, block_cycles=K)
                _check_full(run(feeds), want, (a, b, backend, K))
        # the unrolled token-presence SSA executor agrees too
        run = compile(prog, backend="unrolled")
        _check_full(run(feeds), want, (a, b, "unrolled"))


def test_loop_region_passes_win_without_changing_observables():
    """Region-scoped legality (ISSUE 5 acceptance): >= 1 fold on a
    loop-bearing graph, outputs and token counts untouched."""
    def f(a, n, k):
        return lax.fori_loop(0, n, lambda i, c: c + k, a + k * 2)

    prog = trace(f, I32, I32, I32, const_args={2: 5}, name="loopfold")
    opt, report = passes.optimize_graph(prog)
    assert report.folded >= 1, report.summary()
    for a, n in [(3, 4), (0, 0), (7, 2)]:
        feeds = prog.make_feeds([a], [n])
        want = run_reference(prog, feeds)
        assert np.asarray(want.outputs[prog.out_arc]).item() == \
            int(f(jnp.int32(a), jnp.int32(n), jnp.int32(5)))
        for g in (opt,):
            got = run_reference(g, feeds)
            assert got.counts == want.counts, (a, n)
            for arc, c in want.counts.items():
                if c:
                    assert np.asarray(got.outputs[arc]).item() == \
                        np.asarray(want.outputs[arc]).item(), (a, n, arc)
        eng = DataflowEngine(opt, backend="xla", block_cycles=4,
                             optimize=True)
        got = eng.run(feeds)
        assert got.counts == want.counts
        assert np.asarray(got.outputs[prog.out_arc]).item() == \
            np.asarray(want.outputs[prog.out_arc]).item()


def test_gcd_serves_with_exact_token_metrics():
    """End-to-end through DataflowServer (ISSUE 5 acceptance): one
    request per evaluation, data-dependent residency, exact tokens."""
    from repro.serve.dataflow_server import DataflowServer
    srv = DataflowServer.for_fn(_gcd_fn(), I32, I32, name="gcd",
                                slots=3, block_cycles=8, backend="xla")
    cases = [(12, 18), (100, 64), (7, 7), (81, 27), (360, 84), (13, 9)]
    uids = [srv.submit_args(a, b) for a, b in cases]
    res = {r.uid: r for r in srv.drain()}
    for uid, (a, b) in zip(uids, cases):
        r = res[uid]
        assert np.asarray(
            r.engine.outputs[srv.traced.out_arc]).item() == math.gcd(a, b)
        assert r.metrics.tokens_out == 1
        assert not r.metrics.truncated
        # bit-identical to a solo engine run, whatever rode alongside
        solo = srv.engine.run(srv.make_feeds(a, b))
        _check_full(r.engine, solo, (a, b))


def test_divergent_loop_is_truncated_not_wedged():
    """A loop whose predicate never goes false hits the max_cycles cap:
    the slot is force-harvested with metrics.truncated set, and
    co-resident healthy requests are unaffected."""
    from repro.serve.dataflow_server import DataflowServer

    def diverge(a):
        return lax.while_loop(lambda c: c > 0, lambda c: c + 1, a)

    srv = DataflowServer.for_fn(diverge, I32, slots=2, block_cycles=8,
                                backend="xla", max_cycles=64)
    u_bad = srv.submit_args(1)      # diverges
    u_ok = srv.submit_args(0)       # zero-trip, quiesces immediately
    res = {r.uid: r for r in srv.drain()}
    assert res[u_bad].metrics.truncated
    assert not res[u_ok].metrics.truncated
    assert np.asarray(
        res[u_ok].engine.outputs[srv.traced.out_arc]).item() == 0
    assert srv.pending == 0 and not srv.state.active.any()


# ---------------------------------------------------------------------------
# schema coverage: fori / scan / invariants / nesting / edge cases
# ---------------------------------------------------------------------------
def test_fori_loop_traced_bound_synthetic_carry():
    """Dynamic fori lowers to while; the bound is loop-invariant but
    streamy, so it rides a synthetic pass-through carry."""
    def fib(n):
        r = lax.fori_loop(0, n, lambda i, c: (c[1], c[0] + c[1]),
                          (jnp.int32(0), jnp.int32(1)))
        return r[0]

    prog = trace(fib, I32, name="fib")
    assert prog.has_loops and prog.inits   # compile-time carry inits
    for n in range(10):
        r = run_reference(prog, prog.make_feeds([n]))
        assert np.asarray(r.outputs[prog.out_arc]).item() == \
            int(fib(jnp.int32(n))), n


def test_static_fori_is_carry_only_scan():
    """Static bounds trace to the scan primitive: a synthetic counter
    carry + IFLT trip decider; the x carry is a pure pass-through."""
    def horner_loop(x):
        r = lax.fori_loop(0, 6, lambda i, c: (c[0] * c[1] + 1, c[1]),
                          (jnp.int32(1), x))
        return r[0]

    prog = trace(horner_loop, I32, name="hl")
    assert prog.has_loops
    # counter init + the two carry inits are initial-token annotations
    assert len(prog.inits) >= 1
    for x in (-3, 0, 1, 2, 4):
        r = run_reference(prog, prog.make_feeds([x]))
        assert np.asarray(r.outputs[prog.out_arc]).item() == \
            int(horner_loop(jnp.int32(x))), x


def test_zero_trip_loops_exit_with_init_values():
    def f(a):
        return lax.fori_loop(0, 0, lambda i, c: c + 1, a)
    prog = trace(f, I32, name="zero_trip")
    r = run_reference(prog, prog.make_feeds([41]))
    assert r.counts[prog.out_arc] == 1
    assert np.asarray(r.outputs[prog.out_arc]).item() == 41

    def g(a):       # while whose predicate is false on entry
        return lax.while_loop(lambda c: c < 0, lambda c: c - 1, a)
    prog2 = trace(g, I32, name="zero_trip_while")
    r2 = run_reference(prog2, prog2.make_feeds([5]))
    assert np.asarray(r2.outputs[prog2.out_arc]).item() == 5


def test_nested_loops():
    def f(n):
        def outer(i, acc):
            inner = lax.fori_loop(0, 3, lambda j, s: s + i + 1, acc)
            return inner
        return lax.fori_loop(0, n, outer, jnp.int32(0))

    prog = trace(f, I32, name="nested")
    for n in (0, 1, 2, 4):
        r = run_reference(prog, prog.make_feeds([n]))
        assert np.asarray(r.outputs[prog.out_arc]).item() == \
            int(f(jnp.int32(n))), n


def test_literal_next_state_is_materialized_per_iteration():
    """A body returning a literal gets a DMERGE materializer gated on a
    streamy back value — the const bus must NOT free-run into the entry
    merge (that would re-initiate the loop after exit)."""
    def f(a):
        def body(c):
            return (jnp.int32(0), c[1] + 1)
        r = lax.while_loop(lambda c: c[0] != 0, body, (a, jnp.int32(0)))
        return r[1]

    prog = trace(f, I32, name="reset_count")
    for a in (0, 1, 5):
        feeds = prog.make_feeds([a])
        want = int(f(jnp.int32(a)))
        r = run_reference(prog, feeds)
        assert r.counts[prog.out_arc] == 1      # no re-initiation
        assert np.asarray(r.outputs[prog.out_arc]).item() == want, a
        assert r.cycles < 100_000               # quiesces
        eng = DataflowEngine(prog, backend="pallas", block_cycles=4)
        _check_full(eng.run(feeds), r, a)


def test_all_const_next_state_uses_predicate_gate():
    """A loop whose EVERY next-state value is a literal is still
    data-dependent (the zero-trip path returns the inits), so it must
    lower — the const-token materializer gates off the predicate when
    no streamy back value exists."""
    def f(x, y):
        return lax.while_loop(lambda c: c[0] == c[1],
                              lambda c: (jnp.int32(1), jnp.int32(2)),
                              (x, y))[0]

    prog = trace(f, I32, I32, name="const_state")
    for x, y in [(5, 9), (5, 5), (1, 2), (2, 2)]:
        feeds = prog.make_feeds([x], [y])
        want = int(f(jnp.int32(x), jnp.int32(y)))
        r = run_reference(prog, feeds)
        assert r.counts[prog.out_arc] == 1, (x, y, r.counts)
        assert np.asarray(r.outputs[prog.out_arc]).item() == want, (x, y)
        assert r.cycles < 100_000
        eng = DataflowEngine(prog, backend="pallas", block_cycles=4)
        _check_full(eng.run(feeds), r, (x, y))


def test_const_args_invariants_ride_sticky_buses():
    """A const-bound loop invariant is a sticky const bus inside the
    cones — no synthetic carry, and the folder sees const-fed nodes."""
    def f(a, k):
        return lax.fori_loop(0, 4, lambda i, c: c * k + 1, a)

    prog = trace(f, I32, I32, const_args={1: 3}, name="inv_const")
    for a in (0, 1, 5):
        r = run_reference(prog, prog.make_feeds([a]))
        assert np.asarray(r.outputs[prog.out_arc]).item() == \
            int(f(jnp.int32(a), jnp.int32(3))), a


def test_float_while_loop_matches_jax_bitwise():
    def newton(n):
        return lax.fori_loop(0, 8, lambda i, x: 0.5 * (x + n / x),
                             n * 0.5 + 0.5)

    prog = trace(newton, np.float32, name="newton")
    for v in (2.0, 9.0, 81.0, 0.25):
        r = run_reference(prog, prog.make_feeds([v]), dtype=np.float32)
        got = np.float32(np.asarray(r.outputs[prog.out_arc]))
        want = np.float32(newton(jnp.float32(v)))
        assert got.tobytes() == want.tobytes(), (v, got, want)
        eng = DataflowEngine(prog, dtype=np.float32, backend="xla",
                             block_cycles=8)
        r2 = eng.run(prog.make_feeds([v]))
        assert np.float32(np.asarray(
            r2.outputs[prog.out_arc])).tobytes() == want.tobytes()


def test_loop_fabric_round_trips_through_asm():
    """Initial-token annotations survive emit -> parse -> emit (the
    serving signature cache hashes the emission)."""
    prog = trace(_gcd_fn(), I32, I32, name="gcd")
    hl = trace(lambda x: lax.fori_loop(
        0, 5, lambda i, c: (c[0] + c[1], c[1]), (jnp.int32(0), x))[0],
        I32, name="hl")
    assert hl.inits            # scan counter + carry initial tokens
    for g in (prog, hl):
        text = asm.emit(g)
        g2 = asm.parse(text, name=g.name)
        assert asm.emit(g2) == text
        assert {a: float(v) for a, v in g2.inits.items()} == \
               {a: float(v) for a, v in g.inits.items()}
        feeds = {a: [7] for a in g.input_arcs()}
        _check_full(run_reference(g2, feeds), run_reference(g, feeds),
                    g.name)


def test_single_initiation_feed_contract():
    prog = trace(_gcd_fn(), I32, I32, name="gcd")
    with pytest.raises(ValueError, match="initiate once"):
        prog.make_feeds([1, 2], [3, 4])
    # scalars broadcast to the single shot fine
    feeds = prog.make_feeds(6, 4)
    assert all(len(v) == 1 for v in feeds.values())


# ---------------------------------------------------------------------------
# the GraphTraits probe + unified compile() routing (ISSUE 5 satellite)
# ---------------------------------------------------------------------------
def test_traits_probe_classifies_fabrics():
    dag = library.vector_sum_graph(8).graph
    t = GraphTraits.probe(dag)
    assert t.tokens_out_static and not t.cyclic and not t.control_ops
    loop = trace(_gcd_fn(), I32, I32, name="gcd")
    t2 = GraphTraits.probe(loop)
    assert t2.cyclic and "NDMERGE" in t2.control_ops
    assert not t2.tokens_out_static
    fib_init = trace(lambda x: lax.fori_loop(
        0, 3, lambda i, c: c + x * 0 + 1, x), I32, name="f")
    assert GraphTraits.probe(fib_init).has_inits


def test_dag_executor_refuses_token_presence_graphs_naming_trait():
    """The satellite bugfix: asking the lockstep executor for a fabric
    that needs token-presence semantics raises a precise error naming
    the blocking trait — never a silently-lockstep compilation."""
    prog = trace(_gcd_fn(), I32, I32, name="gcd")
    with pytest.raises(ValueError, match="cyclic=True"):
        compile(prog, backend="dag")
    with pytest.raises(ValueError, match="control_ops"):
        compile(prog, backend="dag")
    sel = trace(lambda x, y: jnp.where(x > y, x - y, y - x), I32, I32)
    with pytest.raises(ValueError, match="control_ops=.*DMERGE"):
        compile(sel, backend="dag")
    with pytest.raises(ValueError, match="cyclic=True"):
        compile_fn(_gcd_fn(), I32, I32, backend="dag")
    with pytest.raises(ValueError, match="backend 'bogus' not in"):
        compile(prog, backend="bogus")
    # auto + the engine default route loop fabrics correctly
    for backend in ("auto", "xla"):
        run = compile_fn(_gcd_fn(), I32, I32, backend=backend)
        r = run(run.make_feeds([21], [14]))
        assert np.asarray(r.outputs[run.out_arcs[0]]).item() == 7
        assert run.traits.cyclic


def test_deprecated_wrappers_are_thin():
    from repro.core.compile import compile_cyclic, compile_graph
    bench = library.fibonacci_graph()
    feeds = bench.make_feeds(9)
    want = run_reference(bench.graph, feeds)
    _check_full(compile_graph(bench.graph, backend="xla",
                              block_cycles=4)(feeds), want, "wrapper")
    _check_full(compile_cyclic(bench.graph)(feeds), want, "cyclic")
    run = compile_graph(bench.graph)     # auto -> unrolled, with traits
    assert run.traits.cyclic
    _check_full(run(feeds), want, "auto")


# ---------------------------------------------------------------------------
# rejected programs: precise LoweringErrors
# ---------------------------------------------------------------------------
def test_loop_lowering_errors_name_the_problem():
    # a scan that STACKS per-iteration outputs is not carry-only
    with pytest.raises(LoweringError, match="carry-only"):
        trace(lambda x: lax.scan(lambda c, _: (c + 1, c), x, None,
                                 length=4)[0], I32)
    # non-scalar loop state (the broadcast feeding it already cannot
    # ride a scalar-token arc)
    with pytest.raises(LoweringError, match="shape"):
        trace(lambda x: lax.while_loop(
            lambda c: c.sum() < 5, lambda c: c + 1,
            jnp.zeros((3,), jnp.int32) + x)[0], I32)
    with pytest.raises(LoweringError, match="predicate"):
        trace(lambda x: lax.while_loop(
            lambda c: jnp.bool_(False), lambda c: c + 1, x), I32)
