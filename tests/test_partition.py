"""Multi-fabric sharding (DESIGN.md §14): partition properties +
sharded-vs-solo bit-identity.

The sharded runtime must be indistinguishable from the single-fabric
engine in EVERY EngineResult field — outputs, counts, cycles, fired,
node_fires, and the merged FabricProfile — because the lockstep channel
exchange reproduces the global cycle exactly (the K-deep channel history
only batches the *communication*, never the *semantics*).  These tests
pin that equivalence against the numpy oracle across partition widths,
block depths, optimize levels, and the slot/serve layers, plus the
partition pass's own invariants (valid cover, loop cycles never cut,
init tokens preserved).

In-process this host exposes a single jax device, so the engine takes
the vmap spmd fallback; the shard_map path over real host devices runs
in a subprocess that sets ``--xla_force_host_platform_device_count``
before importing jax (same pattern as test_pipeline.py).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import compile as C
from repro.core import library
from repro.core.engine import (DataflowEngine, PLAN_CACHE_STATS,
                               clear_plan_cache, run_reference)
from repro.core.graph import Graph, Op
from repro.core.partition import (Partition, auto_partition,
                                  partition_graph, resolve_partition)
from repro.serve.dataflow_server import (CACHE_STATS, DataflowServer,
                                         cached_engine,
                                         clear_engine_cache)


def _chain_graph():
    """4-node pipeline with a const — every 2-way partition cuts it."""
    g = Graph(name="chain")
    g.const("c", 1)
    g.add(Op.ADD, ["x", "c"], ["a1"])
    g.add(Op.MUL, ["a1", "c"], ["a2"])
    g.add(Op.ADD, ["a2", "c"], ["a3"])
    g.add(Op.MUL, ["a3", "c"], ["o"])
    g.validate()
    return g


def _loop_graph():
    """Init-bearing accumulator loop + acyclic post-chain: the loop SCC
    pins one region, the cut lands on the post-chain."""
    g = Graph(name="loop_post")
    g.const("one", 1)
    g.init("acc", 0)
    g.add(Op.ADD, ["acc", "inc"], ["s"])
    g.add(Op.COPY, ["s"], ["acc", "tap"])
    g.add(Op.MUL, ["tap", "one"], ["post1"])
    g.add(Op.ADD, ["post1", "one"], ["out"])
    g.validate()
    return g


def _assert_identical(r, q, *, profile=False):
    assert set(r.outputs) == set(q.outputs)
    for a in q.outputs:
        np.testing.assert_array_equal(np.asarray(r.outputs[a]),
                                      np.asarray(q.outputs[a]))
    assert r.counts == q.counts
    assert r.cycles == q.cycles
    assert r.fired == q.fired
    if profile:
        assert (r.node_fires == q.node_fires).all()
        assert (r.profile.node_fires == q.profile.node_fires).all()
        r.profile.check()


# ---------------------------------------------------------------------------
# Partition pass properties
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["vector_sum", "pop_count", "gcd",
                                  "fibonacci"])
@pytest.mark.parametrize("P", [2, 3])
def test_partition_valid_cover(name, P):
    g = library.BENCHES[name]().graph
    try:
        part = partition_graph(g, P)
    except ValueError as e:
        # legal refusal: fewer SCC supernodes than regions
        assert "loop cycles" in str(e)
        return
    part.validate(g)                      # cover + no cut SCC + range
    assign = np.asarray(part.assign)
    assert assign.shape == (len(g.nodes),)        # each node exactly once
    assert sorted(set(assign.tolist())) == list(range(P))  # none empty
    # determinism: the pass is a pure function of (graph, P)
    assert partition_graph(g, P).assign == part.assign


def test_partition_never_cuts_loops():
    g = _loop_graph()
    part = partition_graph(g, 2)
    part.validate(g)
    # nodes 0 (ADD) and 1 (COPY) form the loop SCC — same region
    assert part.assign[0] == part.assign[1]
    # hand-built partition that cuts the SCC must be rejected
    bad = Partition(2, (0, 1, 1, 1))
    with pytest.raises(ValueError, match="cycle"):
        bad.validate(g)
    # more regions than supernodes: impossible without cutting
    with pytest.raises(ValueError, match="[Ll]oop cycles|supernode"):
        partition_graph(g, len(g.nodes) + 1)


def test_partition_p1_and_resolve():
    g = _chain_graph()
    p1 = partition_graph(g, 1)
    assert p1.P == 1 and p1.cut_arcs(g) == []
    eng = DataflowEngine(g, partition=p1)
    assert not eng._part_on               # degenerate: plain engine
    assert resolve_partition(g, None) is None
    assert resolve_partition(g, 2).P == 2
    assert resolve_partition(g, "auto").P == auto_partition(g).P
    with pytest.raises(ValueError):
        resolve_partition(g, "bogus")


def test_partition_spec_is_assignment_hash():
    g = _chain_graph()
    a = Partition(2, (0, 0, 1, 1))
    b = Partition(2, (0, 1, 1, 1))
    assert a.spec() != b.spec()
    assert a.spec() == Partition(2, (0, 0, 1, 1)).spec()
    assert a.spec().startswith("2:")


# ---------------------------------------------------------------------------
# Sharded vs solo bit-identity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("P", [2, 4])
@pytest.mark.parametrize("K", [1, 4, 16])
def test_bit_identity_dag(P, K):
    bench = library.vector_sum_graph(16)
    feeds = library.random_feeds("vector_sum", bench, 5,
                                 rng=np.random.default_rng(P * 100 + K))
    ref = run_reference(bench.graph, feeds)
    eng = DataflowEngine(bench.graph, block_cycles=K, partition=P)
    _assert_identical(eng.run(feeds), ref)


@pytest.mark.parametrize("optimize", [False, True])
def test_bit_identity_optimize_levels(optimize):
    bench = library.popcount_graph(8)
    feeds = bench.make_feeds([7, 255, 0, 41])
    ref = run_reference(bench.graph, feeds)
    eng = DataflowEngine(bench.graph, block_cycles=4, partition=2,
                         optimize=optimize, profile=True)
    _assert_identical(eng.run(feeds), ref, profile=False)


@pytest.mark.parametrize("K", [1, 16])
def test_bit_identity_cyclic(K):
    bench = library.gcd_graph()
    feeds = bench.make_feeds(21, 6)
    ref = run_reference(bench.graph, feeds)
    eng = DataflowEngine(bench.graph, block_cycles=K, partition=2)
    r = eng.run(feeds)
    _assert_identical(r, ref)
    assert int(np.asarray(r.outputs[bench.out_arc])) == 3


def test_bit_identity_inits_preserved():
    g = _loop_graph()
    feeds = {"inc": [1, 2, 3, 4, 5]}
    ref = run_reference(g, feeds)
    for P in (2, 3):
        try:
            part = partition_graph(g, P)
        except ValueError:
            continue
        eng = DataflowEngine(g, block_cycles=4, partition=part)
        _assert_identical(eng.run(feeds), ref)


def test_bit_identity_pallas_backend():
    bench = library.vector_sum_graph(8)
    feeds = library.random_feeds("vector_sum", bench, 3,
                                 rng=np.random.default_rng(7))
    ref = run_reference(bench.graph, feeds)
    eng = DataflowEngine(bench.graph, backend="pallas", block_cycles=4,
                         partition=2)
    _assert_identical(eng.run(feeds), ref)


def test_bit_identity_batch():
    g = _chain_graph()
    batch = [{"x": [1, 2, 3]}, {"x": [9]}, {"x": [4, 5]}]
    eng = DataflowEngine(g, block_cycles=4, partition=2)
    rs = eng.run_batch(batch)
    for r, feeds in zip(rs, batch):
        _assert_identical(r, run_reference(g, feeds))


# ---------------------------------------------------------------------------
# Merged profile
# ---------------------------------------------------------------------------
def test_profile_merge_exact_at_k1():
    g = _chain_graph()
    feeds = {"x": list(range(8))}
    ref = run_reference(g, feeds, profile=True)
    eng = DataflowEngine(g, block_cycles=1, partition=2, profile=True)
    r = eng.run(feeds)
    _assert_identical(r, ref, profile=True)
    p, q = r.profile, ref.profile
    assert p.cycles == q.cycles
    assert (p.stall_in == q.stall_in).all()
    assert (p.stall_out == q.stall_out).all()
    assert (p.arc_busy == q.arc_busy).all()
    assert (p.arc_hw == q.arc_hw).all()
    # channel counters: one cut arc, a token crossing every stream elem
    assert p.ch_names and p.ch_depth == 1
    assert (p.ch_pushes >= 1).all() and (p.ch_hw <= 1).all()
    assert "channels" in p.to_json()


def test_profile_merge_invariants_at_k4():
    g = _loop_graph()
    feeds = {"inc": [1, 2, 3]}
    ref = run_reference(g, feeds, profile=True)
    eng = DataflowEngine(g, block_cycles=4, partition=2, profile=True)
    r = eng.run(feeds)
    _assert_identical(r, ref, profile=True)
    p, q = r.profile, ref.profile
    # node_fires exact; stall_in absorbs the uniform idle tail K leaves
    tail = p.cycles - q.cycles
    assert tail >= 0
    assert (p.stall_in - q.stall_in == tail).all()
    assert (p.stall_out == q.stall_out).all()
    assert (p.arc_hw == q.arc_hw).all()


# ---------------------------------------------------------------------------
# compile() / slot API / server threading
# ---------------------------------------------------------------------------
def test_compile_partition_threading():
    g = _chain_graph()
    feeds = {"x": [3, 4, 5]}
    ref = run_reference(g, feeds, profile=True)
    run = C.compile(g, backend="auto", partition=2, profile=True)
    assert run.partition.P == 2
    assert run.engine.backend == "xla"    # auto routed off the SSA path
    r = run(feeds)
    _assert_identical(r, ref, profile=True)
    # degenerate resolution falls back to the traits dispatch (dag here)
    run1 = C.compile(g, partition=1)
    assert run1.partition.P == 1 and not hasattr(run1, "engine")
    # partition="auto" resolves from the device count (>=1 everywhere)
    runa = C.compile(g, backend="xla", partition="auto")
    assert runa.partition is None or runa.partition.P >= 1


def test_compile_partition_errors():
    g = _chain_graph()
    with pytest.raises(ValueError, match="shard"):
        C.compile(g, backend="dag", partition=2)
    with pytest.raises(ValueError, match="shard"):
        C.compile(g, backend="unrolled", partition=2)
    with pytest.raises(ValueError, match="reference"):
        DataflowEngine(g, backend="reference", partition=2)
    with pytest.raises(ValueError, match="schedule"):
        DataflowEngine(g, schedule=True, partition=2)


def test_slot_api_sharded():
    g = _chain_graph()
    eng = DataflowEngine(g, block_cycles=4, partition=2, profile=True)
    st = eng.init_state(slots=3)
    st = eng.reset_slots(st, [0, 2], [{"x": [1, 2, 3]}, {"x": [10]}])
    while not st.quiesced[st.active > 0].all():
        st = eng.step_block(st)
    st, res = eng.harvest(st, [0, 2])
    for r, feeds in zip(res, [{"x": [1, 2, 3]}, {"x": [10]}]):
        _assert_identical(r, run_reference(g, feeds, profile=True),
                          profile=True)
    # freed slots readmit cleanly (channel registers reset per slot)
    st = eng.reset_slots(st, [0], [{"x": [7, 8]}])
    while not st.quiesced[st.active > 0].all():
        st = eng.step_block(st)
    st, res2 = eng.harvest(st, [0])
    _assert_identical(res2[0], run_reference(g, {"x": [7, 8]},
                                             profile=True), profile=True)


def test_server_sharded():
    g = _chain_graph()
    srv = DataflowServer(g, slots=4, block_cycles=4, backend="xla",
                         partition=2, profile=True)
    assert srv.engine._part_on
    batches = [{"x": [1, 2]}, {"x": [9]}, {"x": [3, 1, 4]}]
    uids = [srv.submit(f) for f in batches]
    by = {r.uid: r for r in srv.drain()}
    for uid, feeds in zip(uids, batches):
        assert by[uid].status == "ok"
        _assert_identical(by[uid].engine,
                          run_reference(g, feeds, profile=True),
                          profile=True)


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------
def test_cached_engine_partition_collision():
    """PR-3-style collision regression: sharded and unsharded compiles
    of the same asm signature must never alias one engine — and two
    different region assignments must not alias each other."""
    g = _chain_graph()
    clear_engine_cache()
    solo = cached_engine(g, block_cycles=4)
    p2 = cached_engine(g, block_cycles=4, partition=2)
    assert solo is not p2
    assert not solo._part_on and p2._part_on
    other = cached_engine(g, block_cycles=4,
                          partition=Partition(2, (0, 1, 1, 1)))
    assert other is not p2
    # same spec hits; P=1 degenerates to the unsharded key
    assert cached_engine(g, block_cycles=4, partition=2) is p2
    assert cached_engine(g, block_cycles=4, partition=1) is solo
    assert CACHE_STATS["hits"] >= 2


def test_plan_memo_hits():
    g = _chain_graph()
    clear_plan_cache()
    assert PLAN_CACHE_STATS == {"hits": 0, "misses": 0, "evictions": 0}
    DataflowEngine(g).run({"x": [1]})
    m0 = PLAN_CACHE_STATS["misses"]
    assert m0 >= 1
    DataflowEngine(g).run({"x": [2]})
    assert PLAN_CACHE_STATS["hits"] >= 1
    assert PLAN_CACHE_STATS["misses"] == m0   # second build: all hits
    # the serve-layer stats expose the same live dict
    assert CACHE_STATS["plan"] is PLAN_CACHE_STATS


# ---------------------------------------------------------------------------
# shard_map over real host devices (subprocess: XLA_FLAGS before import)
# ---------------------------------------------------------------------------
_SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=2")
import numpy as np
import jax
assert len(jax.devices()) == 2, jax.devices()
from repro.core import library
from repro.core.engine import DataflowEngine, run_reference

bench = library.vector_sum_graph(16)
feeds = library.random_feeds("vector_sum", bench, 4,
                             rng=np.random.default_rng(0))
ref = run_reference(bench.graph, feeds)
eng = DataflowEngine(bench.graph, block_cycles=8, partition=2,
                     profile=True)
mf = eng._mf_ctx()
assert mf.use_shard_map, "2 devices present: shard_map path expected"
r = eng.run(feeds)
assert r.counts == ref.counts and r.cycles == ref.cycles
assert r.fired == ref.fired
for a in ref.outputs:
    np.testing.assert_array_equal(np.asarray(r.outputs[a]),
                                  np.asarray(ref.outputs[a]))
r.profile.check()
print("OK shard_map")
"""


def test_shard_map_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    r = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "OK shard_map" in r.stdout
