"""Substrate tests: optimizer, data determinism, checkpoint atomicity,
fault-tolerant loop (failure injection + byte-exact restart), serving."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.configs.base import get_arch
from repro.data.pipeline import SyntheticLM, prefetch
from repro.models import transformer as tfm
from repro.optim import adamw
from repro.serve.engine import Request, ServeEngine
from repro.train import loop as train_loop


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def test_adamw_reduces_quadratic():
    cfg = adamw.OptConfig(lr=0.1, warmup_steps=0, total_steps=200,
                          weight_decay=0.0, clip_norm=100.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw.init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, m = adamw.update(cfg, grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_clips_gradients():
    cfg = adamw.OptConfig(clip_norm=1.0, warmup_steps=0)
    params = {"w": jnp.ones((4,))}
    state = adamw.init(params)
    grads = {"w": jnp.full((4,), 1e6)}
    _, _, m = adamw.update(cfg, grads, state, params)
    assert float(m["grad_norm"]) > 1e5  # reported pre-clip


def test_schedule_warmup_and_decay():
    cfg = adamw.OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
    assert float(adamw.schedule(cfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(adamw.schedule(cfg, jnp.int32(10))) == pytest.approx(
        1.0, abs=1e-3)
    assert float(adamw.schedule(cfg, jnp.int32(100))) == pytest.approx(
        0.1, abs=1e-3)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_data_deterministic_per_step():
    src = SyntheticLM(vocab=100, seq_len=16, global_batch=4, seed=3)
    b1, b2 = src.batch_for_step(7), src.batch_for_step(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = src.batch_for_step(8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next tokens
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_prefetch_matches_direct():
    src = SyntheticLM(vocab=50, seq_len=8, global_batch=2, seed=1)
    it = prefetch(src, start_step=3)
    for step in range(3, 6):
        got = next(it)
        want = src.batch_for_step(step)
        np.testing.assert_array_equal(got["tokens"], want["tokens"])
    it.close()


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3),
            "b": {"c": jnp.float32(2.5), "d": jnp.ones((4,), jnp.bfloat16)}}
    ckpt.save(str(tmp_path), 5, tree)
    step, back = ckpt.restore(str(tmp_path), tree)
    assert step == 5
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert x.dtype == y.dtype


def test_checkpoint_latest_and_cleanup(tmp_path):
    tree = {"a": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        ckpt.save(str(tmp_path), s, tree)
    assert ckpt.latest_step(str(tmp_path)) == 4
    ckpt.cleanup(str(tmp_path), keep=2)
    names = sorted(os.listdir(tmp_path))
    assert "step_00000003" in names and "step_00000004" in names
    assert "step_00000001" not in names


def test_checkpoint_partial_write_is_invisible(tmp_path):
    tree = {"a": jnp.zeros((2,))}
    ckpt.save(str(tmp_path), 1, tree)
    # simulate a crash mid-save: tmp dir exists but LATEST not updated
    os.makedirs(tmp_path / "step_00000002.tmp")
    assert ckpt.latest_step(str(tmp_path)) == 1
    step, _ = ckpt.restore(str(tmp_path), tree)
    assert step == 1


# ---------------------------------------------------------------------------
# fault-tolerant loop
# ---------------------------------------------------------------------------
def _tiny_setup(tmp_path, total=8, fail_at=None):
    cfg = get_arch("internlm2-1.8b").reduced()
    src = SyntheticLM(vocab=cfg.vocab, seq_len=32, global_batch=2, seed=0)
    lp = train_loop.LoopConfig(
        total_steps=total, ckpt_every=3, ckpt_dir=str(tmp_path),
        log_every=100, fail_at_step=fail_at)
    opt = adamw.OptConfig(lr=1e-3, warmup_steps=2, total_steps=total)
    return cfg, src, lp, opt


def test_loop_failure_injection_and_exact_restart(tmp_path):
    cfg, src, lp, opt = _tiny_setup(tmp_path, total=8, fail_at=5)
    with pytest.raises(train_loop.SimulatedFailure):
        train_loop.run(cfg, lp, opt, src, key=jax.random.key(0))
    # restart: resumes from step 3 checkpoint, completes
    lp2 = train_loop.LoopConfig(
        total_steps=8, ckpt_every=3, ckpt_dir=str(tmp_path), log_every=100)
    out = train_loop.run(cfg, lp2, opt, src, key=jax.random.key(0))
    assert out["resumed"] and out["start_step"] == 3

    # byte-exact: a never-failed run must produce identical final params
    cfg2, src2, lp3, opt2 = _tiny_setup(tmp_path / "clean", total=8)
    ref = train_loop.run(cfg2, lp3, opt2, src2, key=jax.random.key(0))
    for a, b in zip(jax.tree.leaves(out["state"][0]),
                    jax.tree.leaves(ref["state"][0])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_loop_loss_decreases(tmp_path):
    cfg, src, lp, opt = _tiny_setup(tmp_path, total=30)
    lp.ckpt_every = 1000
    opt = adamw.OptConfig(lr=3e-3, warmup_steps=5, total_steps=30)
    out = train_loop.run(cfg, lp, opt, src, key=jax.random.key(1))
    first = np.mean(out["losses"][:5])
    last = np.mean(out["losses"][-5:])
    assert last < first - 0.1, (first, last)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------
def test_serve_engine_batched_waves():
    cfg = get_arch("internlm2-1.8b").reduced()
    params = tfm.init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, batch_size=4, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, (l,)).astype(np.int32),
                    max_new_tokens=5)
            for i, l in enumerate([3, 9, 5, 12, 7])]
    results = eng.run(reqs)
    assert [r.uid for r in results] == [0, 1, 2, 3, 4]
    for r in results:
        assert 1 <= len(r.tokens) <= 5
        assert np.all(r.tokens >= 0) and np.all(r.tokens < cfg.vocab)


def test_serve_greedy_deterministic():
    cfg = get_arch("stablelm-1.6b").reduced()
    params = tfm.init_params(cfg, jax.random.key(1))
    eng = ServeEngine(cfg, params, batch_size=2, max_len=32)
    prompt = np.arange(6, dtype=np.int32)
    r1 = eng.run([Request(0, prompt, 6)])
    r2 = eng.run([Request(0, prompt, 6)])
    np.testing.assert_array_equal(r1[0].tokens, r2[0].tokens)
