"""Benchmark driver — one section per paper table / report table.

  table1_*   paper Table 1 analogue (dataflow benchmarks: resources +
             engine cycles + compiled throughput)
  engine_*   block-fused/batched engine executor sweep (also serialized
             to BENCH_dataflow.json for cross-PR perf tracking)
  opt_*      graph-compiler optimization sweep: off vs spec vs full vs
             sched across backends x K x B (BENCH_opt.json; --opt runs
             it alone, --quick --opt is the CI smoke and
             --quick --sched the scheduled-vs-dynamic one)
  profile_*  §12 fabric-counter sweep (profiled engines; BENCH_profile
             .json feeds roofline.py's fabric section; --trace runs it
             alone, --quick --trace is the CI smoke)
  shard_*    §14 multi-fabric sharding sweep over P regions
             (BENCH_shard.json feeds roofline.py's shard section;
             --shard runs it alone, --quick --shard is the CI
             sharded-vs-solo bit-identity smoke over forced host
             devices)
  kernel_*   Pallas kernel micro-benchmarks vs jnp references
  train_*    end-to-end reduced-config train-step timings (per family)
  roofline_* aggregated dry-run roofline terms (if records exist)

Prints ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import json
import os
import sys
import time

if __name__ == "__main__" and "--shard" in sys.argv:
    # multi-fabric sharding (DESIGN.md §14) wants real host devices;
    # XLA only honors this flag if it is set before jax is imported
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4")

import jax
import numpy as np


def _train_steps():
    from repro.configs.base import get_arch
    from repro.data.pipeline import SyntheticLM
    from repro.optim import adamw
    from repro.train.loop import init_state, make_train_step

    for name in ("internlm2-1.8b", "kimi-k2-1t-a32b", "rwkv6-1.6b",
                 "zamba2-7b", "whisper-medium"):
        cfg = get_arch(name).reduced()
        src = SyntheticLM(vocab=cfg.vocab, seq_len=64, global_batch=4,
                          seed=0, frontend=cfg.frontend,
                          n_patches=cfg.n_patches,
                          frontend_dim=cfg.frontend_dim,
                          enc_seq=cfg.enc_seq)
        step = make_train_step(cfg, adamw.OptConfig(), donate=False)
        state = init_state(cfg, jax.random.key(0))
        b = src.batch_for_step(0)
        state, m = step(state, b)          # compile
        ts = []
        for i in range(1, 4):
            b = src.batch_for_step(i)
            t0 = time.perf_counter()
            state, m = step(state, b)
            float(m["loss"])
            ts.append(time.perf_counter() - t0)
        us = float(np.median(ts)) * 1e6
        toks = 4 * 64
        print(f"train_step_{name},{us:.0f},"
              f"tok_per_s={toks / us * 1e6:.0f};reduced_cfg;loss="
              f"{float(m['loss']):.3f}")


def dataflow_json(path: str | None = None) -> list[dict]:
    """Run the engine-backend sweep and write BENCH_dataflow.json (one
    record per bench/backend/B/K: us_per_call, cycles/s, tokens/s,
    dispatches) so the perf trajectory is machine-readable across PRs."""
    from benchmarks import table1_dataflow

    recs = table1_dataflow.backend_rows()
    path = path or os.path.join(os.path.dirname(__file__), "..",
                                "BENCH_dataflow.json")
    with open(path, "w") as f:
        json.dump(recs, f, indent=1)
    table1_dataflow.print_backend_csv(recs)
    return recs


def opt_json(path: str | None = None) -> list[dict]:
    """Run the --opt/--no-opt optimization sweep (off vs spec vs full
    across backends x K x B) and write BENCH_opt.json, so the
    graph-compiler speedup is tracked across PRs alongside
    BENCH_dataflow.json."""
    from benchmarks import table1_dataflow

    recs = table1_dataflow.opt_rows()
    payload = dict(records=recs, summary=table1_dataflow.opt_summary(recs))
    path = path or os.path.join(os.path.dirname(__file__), "..",
                                "BENCH_opt.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    table1_dataflow.print_opt_csv(recs)
    return recs


def profile_json(path: str | None = None, quick: bool = False,
                 benches=None, backends=("xla", "pallas", "reference"),
                 k_tokens: int = 8, block: int = 8) -> list[dict]:
    """``--trace``: run library benches with DESIGN.md §12 profiling on
    and write BENCH_profile.json — one record per bench x backend with
    the FabricProfile export (per-node fires/stalls, per-arc occupancy,
    fires-per-dispatch).  roofline.py's fabric section reads this file.

    Each record is cross-checked before it is written: profiling must
    not perturb results (outputs/fired/cycles bit-identical to an
    unprofiled engine) and the §12 partition invariant must hold."""
    from repro.core import library
    from repro.core.engine import DataflowEngine

    benches = benches or (("vector_sum", "gcd") if quick else
                          ("vector_sum", "fir", "fibonacci", "gcd",
                           "newton_sqrt", "bubble_sort"))
    recs = []
    for name in benches:
        bench = library.BENCHES[name]()
        if np.dtype(bench.dtype) != np.int32:
            continue
        feeds = library.random_feeds(name, bench, k_tokens,
                                     np.random.default_rng(42))
        for backend in backends:
            eng = DataflowEngine(bench.graph, backend=backend,
                                 block_cycles=block, profile=True)
            res = eng.run(feeds)
            prof = res.profile
            prof.check()
            base = DataflowEngine(bench.graph, backend=backend,
                                  block_cycles=block).run(feeds)
            assert base.outputs == res.outputs \
                and base.fired == res.fired \
                and base.cycles == res.cycles, \
                f"profiling perturbed {name}/{backend}"
            assert prof.fired == res.fired
            recs.append(dict(name=name, backend=backend, K=block,
                             k_tokens=k_tokens, profile=prof.to_json()))
            print(f"profile_{name}_{backend},0,{prof.summary()}")
    if not quick:
        path = path or os.path.join(os.path.dirname(__file__), "..",
                                    "BENCH_profile.json")
        with open(path, "w") as f:
            json.dump(recs, f, indent=1)
    return recs


def quick_opt() -> None:
    """CI smoke for the optimization sweep: 2 benches, tiny workloads,
    every level, no JSON (the committed BENCH_opt.json is a full-run
    artifact) — keeps the specialized kernels + rewrite passes
    exercised on every push."""
    from benchmarks import table1_dataflow
    recs = table1_dataflow.opt_rows(
        Bs=(1, 2), Ks=(4,), reps=1, k_tokens=4, fib_iters=8,
        benches=("fir", "fibonacci", "fir_traced", "gcd"))
    table1_dataflow.print_opt_csv(recs)


def quick_sched() -> None:
    """CI smoke for static firing schedules (DESIGN.md §13): scheduled
    vs dynamic rows on a control-free bench (fir: schedules engage,
    steady-state cadence reported) and a control-bearing one (gcd:
    scheduled compile falls back dynamically) across both device
    backends, plus a bit-identity cross-check against the dynamic
    engine.  No JSON — the committed BENCH_opt.json is a full-run
    artifact."""
    from benchmarks import table1_dataflow
    from repro.core import library
    from repro.core.compile import compile as _compile

    recs = table1_dataflow.opt_rows(
        Bs=(1, 2), Ks=(4,), reps=1, k_tokens=4, fib_iters=8,
        benches=("fir", "gcd"), levels=("full", "sched"))
    table1_dataflow.print_opt_csv(recs)
    sched = {r["name"]: r for r in recs if r["opt"] == "sched"
             and r["B"] == 1}
    assert sched["fir"]["scheduled"], "fir must compile a schedule"
    assert not sched["gcd"]["scheduled"], "gcd must fall back dynamic"
    for name in ("fir", "gcd"):
        bench = library.BENCHES[name]()
        k = 8 if name in library.SINGLE_SHOT else 4
        feeds = library.random_feeds(name, bench, k,
                                     np.random.default_rng(7))
        dyn = _compile(bench.graph, backend="xla", optimize="full",
                       block_cycles=4)(feeds)
        sch = _compile(bench.graph, backend="xla", optimize="sched",
                       block_cycles=4)(feeds)
        assert dyn.outputs == sch.outputs and dyn.cycles == sch.cycles \
            and dyn.fired == sch.fired, f"sched diverged on {name}"
        print(f"sched_check_{name},0,bit_identical=1")


def _lanes_graph(lanes: int = 4, depth: int = 24):
    """Embarrassingly-spatial fabric: `lanes` independent ADD/MUL
    chains sharing one const bus — the partitioner finds a zero-cut
    split, so sharding it measures pure per-region compute scaling
    (channel exchange cost ~0)."""
    from repro.core.graph import Graph, Op
    g = Graph(name=f"lanes_{lanes}x{depth}")
    g.const("c", 3)
    for ln in range(lanes):
        cur = f"in{ln}"
        for d in range(depth):
            nxt = f"l{ln}_{d}"
            g.add(Op.ADD if d % 2 == 0 else Op.MUL, [cur, "c"], [nxt])
            cur = nxt
    g.validate()
    return g


def _shard_benches():
    from repro.core import library
    vs = library.vector_sum_graph(64)
    pc = library.popcount_graph(16)
    rng = np.random.default_rng(11)
    lanes = _lanes_graph(4, 24)
    return [
        ("vector_sum_64", vs.graph,
         library.random_feeds("vector_sum", vs, 8, rng)),
        ("pop_count_16", pc.graph,
         library.random_feeds("pop_count", pc, 8, rng)),
        ("lanes_4x24", lanes,
         {f"in{ln}": rng.integers(0, 9, (8,)) for ln in range(4)}),
    ]


def shard_json(path: str | None = None, Ps=(1, 2, 4), block: int = 8,
               reps: int = 3) -> list[dict]:
    """``--shard``: the multi-fabric sharding sweep (DESIGN.md §14) over
    P regions on the large control-free benches, written to
    BENCH_shard.json.  Every sharded run is bit-identity-checked against
    the P=1 engine before its timing is recorded.

    Records carry the honest context a reader needs to interpret the
    wall clock: host core count and device count (forced host devices on
    one core time-slice a single CPU, so cycles/s cannot exceed P=1
    there — the *capacity* metrics, region balance and cut traffic, are
    the device-independent scaling story)."""
    from repro.core.engine import DataflowEngine
    from repro.core.partition import partition_graph

    recs = []
    ncpu = os.cpu_count() or 1
    ndev = len(jax.devices())
    for name, graph, feeds in _shard_benches():
        base = None
        for P in Ps:
            part = partition_graph(graph, P)
            eng = DataflowEngine(graph, block_cycles=block,
                                 partition=part)
            r = eng.run(feeds)
            if base is None:
                base = r
                base_us = None
            assert r.outputs == base.outputs and r.cycles == base.cycles \
                and r.fired == base.fired, f"shard diverged on {name} P={P}"
            ts = []
            for _ in range(reps):
                t0 = time.perf_counter()
                eng.run(feeds)
                ts.append(time.perf_counter() - t0)
            us = float(np.median(ts)) * 1e6
            if base_us is None:
                base_us = us
            w = part.region_weights(graph)
            cut = part.cut_arcs(graph)
            mf = eng._mf_ctx() if eng._part_on else None
            pushes_per_block = ch_hw = None
            if mf is not None:
                # measured cut-arc traffic: one profiled run (§12/§14
                # counters), tokens crossing channels per K-cycle block
                pr = DataflowEngine(graph, block_cycles=block,
                                    partition=part,
                                    profile=True).run(feeds)
                prof = pr.profile
                prof.check()
                pushes_per_block = 0.0 if not cut else round(
                    float(np.sum(prof.ch_pushes))
                    / max(pr.dispatches, 1), 3)
                ch_hw = int(np.max(prof.ch_hw)) if cut else 0
            rec = dict(
                name=name, P=P, K=block, us_per_call=round(us, 1),
                cycles=r.cycles,
                cycles_per_s=round(r.cycles / (us / 1e6), 1),
                speedup_vs_p1=round(base_us / us, 3),
                cut_arcs=len(cut),
                cut_tokens_per_block=pushes_per_block,
                channel_high_water=ch_hw,
                max_region_frac=round(max(w) / max(sum(w), 1), 4),
                region_weights=[int(x) for x in w],
                shard_map=bool(mf is not None and mf.use_shard_map),
                devices=ndev, host_cpus=ncpu)
            recs.append(rec)
            print(f"shard_{name}_P{P},{us:.1f},"
                  f"cycles_per_s={rec['cycles_per_s']};"
                  f"speedup_vs_p1={rec['speedup_vs_p1']};"
                  f"cut={rec['cut_arcs']};"
                  f"max_region_frac={rec['max_region_frac']};"
                  f"shard_map={int(rec['shard_map'])}")
    payload = dict(devices=ndev, host_cpus=ncpu, records=recs)
    path = path or os.path.join(os.path.dirname(__file__), "..",
                                "BENCH_shard.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return recs


def quick_shard() -> None:
    """CI smoke for multi-fabric sharding: in-process sharded-vs-solo
    bit-identity cross-check (every EngineResult field) on a control-free
    and a cyclic bench, under the forced 2+ host devices the --shard
    pre-import guard set up (so the shard_map path, not the vmap
    fallback, is what CI exercises).  No JSON — the committed
    BENCH_shard.json is a full-run artifact."""
    from repro.core import library
    from repro.core.engine import DataflowEngine

    ndev = len(jax.devices())
    for name, P, K in (("vector_sum", 2, 4), ("gcd", 2, 8)):
        bench = library.BENCHES[name]()
        k = 12 if name in library.SINGLE_SHOT else 4
        feeds = library.random_feeds(name, bench, k,
                                     np.random.default_rng(3))
        solo = DataflowEngine(bench.graph, block_cycles=K).run(feeds)
        eng = DataflowEngine(bench.graph, block_cycles=K, partition=P)
        shard = eng.run(feeds)
        assert shard.outputs == solo.outputs \
            and shard.counts == solo.counts \
            and shard.cycles == solo.cycles \
            and shard.fired == solo.fired, f"shard diverged on {name}"
        mf = eng._mf_ctx()
        print(f"shard_check_{name},0,bit_identical=1;P={P};"
              f"devices={ndev};shard_map={int(mf.use_shard_map)}")


def main() -> None:
    from benchmarks import table1_dataflow, kernels_bench, roofline
    table1_dataflow.main()
    dataflow_json()
    opt_json()
    profile_json()
    kernels_bench.main()
    _train_steps()
    roofline.main()


def quick() -> None:
    """CI smoke: the dataflow executor sweep at tiny K/B over 2 benches
    (serve_bench.py has its own --quick).  Catches benchmark-code rot
    without the full sweep's runtime; writes no JSON (the committed
    BENCH_*.json files are full-run artifacts)."""
    from benchmarks import table1_dataflow
    for r in table1_dataflow.rows(benches=("fibonacci", "vector_sum",
                                           "horner", "relu_chain",
                                           "gcd", "newton_sqrt")):
        print(f"table1_{r['name']},{r['compiled_us_per_token']},"
              f"nodes={r['nodes']};lat_cyc={r['latency_cycles']}")
    recs = table1_dataflow.backend_rows(
        Bs=(1, 2), block=4, reps=1, k_tokens=2,
        benches=("fibonacci", "vector_sum", "relu_chain", "gcd"))
    table1_dataflow.print_backend_csv(recs)


if __name__ == "__main__":
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))   # `benchmarks` importable from CLI
    if "--shard" in sys.argv:
        if "--quick" in sys.argv:
            quick_shard()              # CI: shard_map bit-identity smoke
        else:
            shard_json()               # the §14 sharding sweep alone
    elif "--trace" in sys.argv:
        profile_json(quick="--quick" in sys.argv)  # the §12 sweep alone
    elif "--quick" in sys.argv:
        if "--sched" in sys.argv:
            quick_sched()
        elif "--opt" in sys.argv:
            quick_opt()
        else:
            quick()
    elif "--opt" in sys.argv:
        opt_json()                     # the opt sweep alone
    else:
        main()
