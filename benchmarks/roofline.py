"""Roofline table: aggregate the dry-run JSON records (launch/dryrun.py)
into the per-(arch x shape x mesh) table for EXPERIMENTS.md §Roofline.

CSV: name,us_per_call,derived  (us_per_call = dominant term in us)
"""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..",
                          "experiments", "dryrun")


def load(tag: str | None = None, mesh: str | None = None):
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if tag and r.get("tag") != tag:
            continue
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def table(recs):
    rows = []
    for r in recs:
        if r.get("status") != "ok":
            rows.append((r["arch"], r["shape"], r["mesh"], r["status"],
                         None))
            continue
        rows.append((r["arch"], r["shape"], r["mesh"], "ok",
                     r["roofline"]))
    return rows


def main():
    recs = load(tag="baseline", mesh="pod")
    if not recs:
        print("roofline_no_records,0,run launch/dryrun.py first")
        return
    for arch, shape, mesh, status, rf in table(recs):
        if rf is None:
            print(f"roofline_{arch}_{shape},0,{status}")
            continue
        dom_s = rf[f"{rf['dominant']}_s"]
        derived = (f"dominant={rf['dominant']};"
                   f"compute_s={rf['compute_s']:.3e};"
                   f"memory_s={rf['memory_s']:.3e};"
                   f"collective_s={rf['collective_s']:.3e};"
                   f"useful={rf['useful_flops_ratio']:.3f}")
        print(f"roofline_{arch}_{shape},{dom_s * 1e6:.1f},{derived}")


if __name__ == "__main__":
    main()
