"""Roofline table: aggregate the dry-run JSON records (launch/dryrun.py)
into the per-(arch x shape x mesh) table for EXPERIMENTS.md §Roofline,
plus the fabric-interior roofline from the §12 counter sweep
(BENCH_profile.json, written by ``run.py --trace``).

The fabric section compares each bench's *achieved* cadence against
the paper fabric's handshake bound: an arc's full/empty register pair
moves at most one token every 2 cycles, so per-arc occupancy is
bounded by 0.5 at steady state and a node can fire at most every
other cycle.  ``cadence_frac`` = hottest node's fires-per-cycle over
that 0.5 bound — the dataflow analogue of "fraction of peak FLOPs".

The scheduled section (``sched_rows``, from BENCH_opt.json's sched
records) plots each control-free bench's *scheduled* steady-state
output cadence — tokens per cycle of the locked period (DESIGN.md
§13) — against the same 0.5 tokens/cycle handshake bound and the
dynamic engine's measured output cadence, showing where software-
pipelined arc registers push throughput past the handshake cadence.

The sharding section (``shard_rows``, from BENCH_shard.json, written
by ``run.py --shard``) inspects the §14 multi-fabric speedup story the
same way: *per-region cadence* — the ideal speedup is bounded by the
hottest region's weight fraction (1/max_region_frac, the spatial
Amdahl term) — vs *channel-bound cadence* — each cut arc is a
register-pair channel moving at most one token every 2 cycles, so a
K-cycle block carries at most 0.5*K tokens per channel; measured
cut-arc traffic per block over that capacity says whether the fabric
is compute- or channel-limited at this partition.

CSV: name,us_per_call,derived  (us_per_call = dominant term in us)
"""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..",
                          "experiments", "dryrun")

PROFILE_JSON = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_profile.json")

OPT_JSON = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_opt.json")

SHARD_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_shard.json")

# handshake cadence bound: 1 token per 2 cycles per arc (DESIGN.md §2)
CADENCE_BOUND = 0.5


def fabric_rows(path: str | None = None) -> list[dict]:
    """Fabric-interior roofline rows from the §12 profile sweep."""
    path = path or PROFILE_JSON
    if not os.path.exists(path):
        return []
    with open(path) as f:
        recs = json.load(f)
    rows = []
    for r in recs:
        p = r["profile"]
        cycles = max(p["cycles"], 1)
        hot = max(p["nodes"], key=lambda n: n["fires"],
                  default={"name": "-", "fires": 0})
        hot_rate = hot["fires"] / cycles
        occ = [a["busy"] / cycles for a in p["arcs"]]
        rows.append(dict(
            name=r["name"], backend=r["backend"],
            cycles=p["cycles"], fired=p["fired"],
            dispatches=p["dispatches"],
            fires_per_dispatch=round(p["fires_per_dispatch"], 1),
            utilization=round(p["utilization"], 4),
            hot_node=hot["name"],
            hot_fires_per_cycle=round(hot_rate, 4),
            cadence_frac=round(hot_rate / CADENCE_BOUND, 4),
            max_arc_occupancy=round(max(occ, default=0.0), 4),
            mean_arc_occupancy=round(
                sum(occ) / len(occ), 4) if occ else 0.0))
    return rows


def fabric_main(path: str | None = None) -> None:
    rows = fabric_rows(path)
    if not rows:
        print("roofline_fabric_no_records,0,run run.py --trace first")
        return
    for r in rows:
        print(f"roofline_fabric_{r['name']}_{r['backend']},0,"
              f"fires_per_dispatch={r['fires_per_dispatch']};"
              f"util={r['utilization']};"
              f"hot={r['hot_node']}@{r['hot_fires_per_cycle']}/cyc;"
              f"cadence_frac={r['cadence_frac']}"
              f"(bound={CADENCE_BOUND}/arc);"
              f"arc_occ_max={r['max_arc_occupancy']};"
              f"arc_occ_mean={r['mean_arc_occupancy']}")


def sched_rows(path: str | None = None) -> list[dict]:
    """Scheduled-cadence rows from BENCH_opt.json's sched records
    (largest K, B=1): the locked period's tokens/cycle vs the 0.5
    handshake bound vs the dynamic engine's measured output cadence
    (tokens/cycle of the matching opt="full" record)."""
    path = path or OPT_JSON
    if not os.path.exists(path):
        return []
    with open(path) as f:
        payload = json.load(f)
    recs = payload["records"] if isinstance(payload, dict) else payload
    if not recs:
        return []
    K = max(r["K"] for r in recs)
    rows = []
    for r in recs:
        if (r.get("opt") != "sched" or not r.get("scheduled")
                or r["B"] != 1 or r["K"] != K
                or "steady_tokens_per_cycle" not in r):
            continue
        dyn = next((d for d in recs
                    if d["name"] == r["name"]
                    and d["backend"] == r["backend"]
                    and d["B"] == 1 and d["K"] == K
                    and d["opt"] == "full"), None)
        steady = r["steady_tokens_per_cycle"]
        row = dict(name=r["name"], backend=r["backend"], K=K,
                   period_cycles=r["period_cycles"],
                   period_tokens=r["period_tokens"],
                   steady_tokens_per_cycle=steady,
                   bound_frac=round(steady / CADENCE_BOUND, 4))
        if dyn is not None:
            row["dynamic_tokens_per_cycle"] = round(
                dyn["tokens_per_s"] / max(dyn["cycles_per_s"], 1), 4)
            row["speedup_vs_dynamic"] = round(
                r["cycles_per_s"] / max(dyn["cycles_per_s"], 1), 2)
        rows.append(row)
    return rows


def sched_main(path: str | None = None) -> None:
    rows = sched_rows(path)
    if not rows:
        print("roofline_sched_no_records,0,run run.py --opt first")
        return
    for r in rows:
        dyn = r.get("dynamic_tokens_per_cycle", "-")
        spd = r.get("speedup_vs_dynamic", "-")
        print(f"roofline_sched_{r['name']}_{r['backend']},0,"
              f"steady={r['steady_tokens_per_cycle']}tok/cyc"
              f"(period={r['period_tokens']}tok/"
              f"{r['period_cycles']}cyc);"
              f"bound_frac={r['bound_frac']}"
              f"(handshake={CADENCE_BOUND}tok/cyc);"
              f"dynamic={dyn}tok/cyc;"
              f"speedup_vs_dynamic={spd}x")


def shard_rows(path: str | None = None) -> list[dict]:
    """Sharding roofline rows from BENCH_shard.json (P>1 records):
    measured speedup vs the per-region cadence bound (1/max_region_frac
    — the hottest region paces the lockstep global cycle) and cut-arc
    traffic per block vs the channel capacity (0.5*K tokens per channel
    per block, the handshake cadence over a K-cycle block)."""
    path = path or SHARD_JSON
    if not os.path.exists(path):
        return []
    with open(path) as f:
        payload = json.load(f)
    recs = payload["records"] if isinstance(payload, dict) else payload
    rows = []
    for r in recs:
        if r["P"] <= 1:
            continue
        ideal = 1.0 / max(r["max_region_frac"], 1e-9)
        cap = CADENCE_BOUND * r["K"] * r["cut_arcs"]
        traffic = r.get("cut_tokens_per_block") or 0.0
        rows.append(dict(
            name=r["name"], P=r["P"], K=r["K"],
            speedup_vs_p1=r["speedup_vs_p1"],
            region_bound_speedup=round(ideal, 3),
            region_cadence_frac=round(r["speedup_vs_p1"] / ideal, 4),
            cut_arcs=r["cut_arcs"],
            cut_tokens_per_block=traffic,
            channel_capacity_per_block=round(cap, 1),
            channel_bound_frac=round(traffic / cap, 4) if cap else 0.0,
            shard_map=r.get("shard_map", False),
            devices=r.get("devices"), host_cpus=r.get("host_cpus")))
    return rows


def shard_main(path: str | None = None) -> None:
    rows = shard_rows(path)
    if not rows:
        print("roofline_shard_no_records,0,run run.py --shard first")
        return
    for r in rows:
        print(f"roofline_shard_{r['name']}_P{r['P']},0,"
              f"speedup={r['speedup_vs_p1']}x"
              f"(region_bound={r['region_bound_speedup']}x);"
              f"region_cadence_frac={r['region_cadence_frac']};"
              f"cut_traffic={r['cut_tokens_per_block']}tok/blk"
              f"(cap={r['channel_capacity_per_block']});"
              f"channel_bound_frac={r['channel_bound_frac']};"
              f"devices={r['devices']};host_cpus={r['host_cpus']}")


def load(tag: str | None = None, mesh: str | None = None):
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if tag and r.get("tag") != tag:
            continue
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def table(recs):
    rows = []
    for r in recs:
        if r.get("status") != "ok":
            rows.append((r["arch"], r["shape"], r["mesh"], r["status"],
                         None))
            continue
        rows.append((r["arch"], r["shape"], r["mesh"], "ok",
                     r["roofline"]))
    return rows


def main():
    fabric_main()
    sched_main()
    shard_main()
    recs = load(tag="baseline", mesh="pod")
    if not recs:
        print("roofline_no_records,0,run launch/dryrun.py first")
        return
    for arch, shape, mesh, status, rf in table(recs):
        if rf is None:
            print(f"roofline_{arch}_{shape},0,{status}")
            continue
        dom_s = rf[f"{rf['dominant']}_s"]
        derived = (f"dominant={rf['dominant']};"
                   f"compute_s={rf['compute_s']:.3e};"
                   f"memory_s={rf['memory_s']:.3e};"
                   f"collective_s={rf['collective_s']:.3e};"
                   f"useful={rf['useful_flops_ratio']:.3f}")
        print(f"roofline_{arch}_{shape},{dom_s * 1e6:.1f},{derived}")


if __name__ == "__main__":
    main()
