"""Kernel micro-benchmarks: Pallas (interpret on CPU) vs jnp reference.

On-CPU wall times measure the *reference path* speed and validate the
harness; the kernels' TPU performance is assessed structurally (BlockSpec
VMEM footprints) in EXPERIMENTS.md §Roofline.

CSV: name,us_per_call,derived
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.core import library
from repro.kernels import ops as kops


def _time(fn, reps=5):
    fn()
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6


def main():
    key = jax.random.key(0)
    k1, k2, k3 = jax.random.split(key, 3)
    B, S, H, hd = 1, 512, 8, 64
    q = jax.random.normal(k1, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(k2, (B, S, 2, hd), jnp.float32)
    v = jax.random.normal(k3, (B, S, 2, hd), jnp.float32)
    ref_fa = jax.jit(lambda q, k, v: ref.flash_attention_ref(
        q, k, v, causal=True))
    us = _time(lambda: ref_fa(q, k, v))
    flops = 2 * 2 * B * H * S * S * hd
    print(f"kernel_flash_ref_jnp,{us:.1f},"
          f"gflops={flops / us / 1e3:.1f};shape={B}x{S}x{H}x{hd}")
    # pallas interpret (correctness path; slow on CPU by design)
    us_p = _time(lambda: flash_attention_pallas(
        q[:, :128], k[:, :128], v[:, :128], causal=True, bq=64, bk=64))
    print(f"kernel_flash_pallas_interpret,{us_p:.1f},"
          f"note=interpret-mode;vmem_tile=64x{hd}")

    x = jax.random.normal(k1, (4096, 1024), jnp.float32)
    w = jnp.ones((1024,))
    ref_rn = jax.jit(lambda x, w: ref.rmsnorm_ref(x, w))
    us = _time(lambda: ref_rn(x, w))
    gbs = 2 * x.size * 4 / us / 1e3
    print(f"kernel_rmsnorm_ref_jnp,{us:.1f},gbps={gbs:.1f}")
    us_p = _time(lambda: rmsnorm_pallas(x[:256], w, rows_blk=256))
    print(f"kernel_rmsnorm_pallas_interpret,{us_p:.1f},"
          f"note=interpret-mode;vmem_tile=256x1024")

    # dataflow fire step (one cycle of the popcount fabric)
    bench = library.popcount_graph(16)
    tables, step = kops.make_fire_step(bench.graph)
    A2 = tables["plan"]["A"] + 2
    full = jnp.zeros((A2,), jnp.int32).at[tables["plan"]["FULL_PAD"]].set(1)
    val = jnp.zeros((A2,), jnp.int32)
    us = _time(lambda: step(full, val))
    n = len(bench.graph.nodes)
    print(f"kernel_dataflow_fire_interpret,{us:.1f},"
          f"nodes={n};arcs={A2 - 2};note=one-cycle")


if __name__ == "__main__":
    main()
