"""Paper Table 1 analogue: per-benchmark fabric resources + speed.

Paper columns FF / LUT / Slices / Fmax map to (DESIGN.md §2):
  FF     -> arc register bits (16-bit data + 1-bit status per arc)
  LUT    -> summed operator datapath complexity weights
  Slices -> node count
  Fmax   -> engine throughput (cycles/token when streaming; the
            architecture-determined rate, like the paper's 613 MHz) and
            the compiled backend's wall-clock tokens/s on this host.

Besides the resource table, ``backend_rows`` sweeps the cycle-accurate
executors (DESIGN.md §3): the seed per-cycle Pallas driver, the XLA
engine at K ∈ {1, block}, and the fused Pallas block engine, each at
batch sizes B ∈ {1, 8, 64} — reporting us/call, cycles/s, tokens/s and
device dispatches.  ``benchmarks/run.py`` serializes these records to
BENCH_dataflow.json so the perf trajectory is tracked across PRs.

CSV: name,us_per_call,derived
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import library
from repro.core.compile import compile
from repro.core.engine import DataflowEngine


def _time(fn, *args, reps=5):
    fn(*args)   # warmup/compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6   # us


def rows(benches=None):
    rng = np.random.default_rng(0)
    out = []
    stream_k = 64
    for name, mk in library.BENCHES.items():
        if benches is not None and name not in benches:
            continue
        bench = mk()
        g = bench.graph
        dt = np.dtype(bench.dtype)
        r = g.resources()
        eng = DataflowEngine(g, dtype=dt)
        if name in library.SINGLE_SHOT:
            feeds1 = feeds_k = library.random_feeds(name, bench, 20, rng)
            n_stream = 1
        else:
            feeds_k = library.random_feeds(name, bench, stream_k, rng)
            feeds1 = {a: np.asarray(v)[:1] for a, v in feeds_k.items()}
            n_stream = stream_k
        # the unified compile() probes GraphTraits and picks the
        # executor: lockstep stream-vmapped SSA for control-free DAGs,
        # the trace-time-unrolled token-presence executor for cyclic /
        # control-bearing / init-bearing fabrics (loop benches)
        run = compile(g, dtype=dt)
        fk = feeds_k
        if run.traits.tokens_out_static:
            feeds_np = {k: np.asarray(v, dt) for k, v in feeds_k.items()}
            compiled_call = lambda: run(feeds_np)
            get_vals = lambda res: list(res.values())
        else:
            compiled_call = lambda: run(fk)
            get_vals = lambda res: list(res.outputs.values())

        lat = eng.run(feeds1).cycles
        thr = eng.run(feeds_k).cycles if n_stream > 1 else lat
        cyc_per_tok = (thr - lat) / max(n_stream - 1, 1) if n_stream > 1 \
            else lat
        us = _time(lambda: np.asarray(get_vals(compiled_call())[0]))
        out.append({
            "name": name, "nodes": r["nodes"], "arcs": r["arcs"],
            "ff_bits": r["ff_bits"], "lut_weight": r["lut_weight"],
            "latency_cycles": lat,
            "cycles_per_token": round(cyc_per_tok, 2),
            "compiled_us_per_stream": round(us, 1),
            "compiled_us_per_token": round(us / n_stream, 2),
        })
    return out


def backend_rows(Bs=(1, 8, 64), block=16, reps=3, k_tokens=8,
                 benches=None):
    """Executor sweep: one JSON-able record per (bench, backend, B, K).

    Backends:
      pallas-percycle — seed baseline: one pallas dispatch PER CYCLE
                        (kernels.ops.run_fabric), B=1 only.
      xla             — jnp cycle body in a while_loop, K cycles fused
                        per loop iteration (K=1 is the seed engine).
      pallas          — fused fire-block kernel, K cycles + environment
                        per dispatch; batched via the in-kernel B grid.

    benches: optional iterable of bench names to restrict the sweep
    (the --quick smoke path).
    """
    from repro.kernels import ops

    out = []
    for name, mk in library.BENCHES.items():
        if benches is not None and name not in benches:
            continue
        bench = mk()
        g = bench.graph
        dt = np.dtype(bench.dtype)
        k = 20 if name in library.SINGLE_SHOT else k_tokens
        feeds = library.random_feeds(name, bench, k,
                                     np.random.default_rng(0))
        tok1 = library.tokens_out(name, k)

        def record(backend, B, K, call, res):
            rs = res if isinstance(res, list) else [res]
            us = _time(call, reps=reps)
            cyc = sum(r.cycles for r in rs)
            out.append(dict(
                name=name, backend=backend, B=B, K=K,
                us_per_call=round(us, 1),
                cycles_per_s=round(cyc / us * 1e6),
                tokens_per_s=round(B * tok1 / us * 1e6),
                dispatches=rs[0].dispatches,
                cycles=rs[0].cycles))

        if dt == np.int32:      # the pallas kernels are int32-only
            compiled = ops.make_fire_step(g)
            base_call = lambda: ops.run_fabric(g, feeds, compiled=compiled)
            record("pallas-percycle", 1, 1, base_call, base_call())

        for be, K in (("xla", 1), ("xla", block), ("pallas", block)):
            if be == "pallas" and dt != np.int32:
                continue
            eng = DataflowEngine(g, dtype=dt, backend=be, block_cycles=K)
            for B in Bs:
                if B == 1:
                    call = lambda: eng.run(feeds)
                else:
                    fb = [library.random_feeds(
                        name, bench, k, np.random.default_rng(b))
                        for b in range(B)]
                    call = lambda: eng.run_batch(fb)
                record(be, B, K, call, call())
    return out


def _steady_info(eng, feeds):
    """Scheduled-engine extras: the locked steady-state period and its
    token cadence (None when the engine is dynamic or the plan quiesced
    before a period formed)."""
    if not getattr(eng, "_sched_on", False):
        return None
    from repro.core.engine import pack_feeds
    ctx = eng._sched_ctx()
    _, fl = pack_feeds(eng.p["input_arcs"], feeds, eng.token_shape,
                       ctx.np_dtype)
    plan = ctx.plan_for(tuple(int(x) for x in fl))
    plan.ensure(eng.max_cycles)
    s = plan.steady()
    if s is None:
        return None
    pc, pt = s
    return dict(period_cycles=pc, period_tokens=pt,
                steady_tokens_per_cycle=round(pt / pc, 4))


def opt_rows(Bs=(1, 8), Ks=(4, 16), reps=7, k_tokens=64, fib_iters=300,
             benches=None, backends=("xla", "pallas"),
             levels=(False, "spec", "full", "sched")):
    """--opt/--no-opt sweep (ISSUE 3 + 8): every optimization level
    across backends x K x B, one JSON-able record per configuration.

    Levels:
      off   — the graph exactly as authored, dense ~20-way ALU
              where-chain per cycle (the PR 1/2 engine).
      spec  — opcode-class-specialized plan only (DESIGN.md §8):
              bucketed fire bodies over only the opcodes present;
              bit-identical in every EngineResult field.
      full  — graph rewrite passes (constant folding, identity
              elimination, DCE) + the specialized plan; fabrics shrink,
              so simulated cycles may drop too.
      sched — "full" + static firing schedules (DESIGN.md §13): on
              control-free fabrics the per-cycle fire sets compile out
              of the run loop entirely (no ready-mask reduction) and
              the record gains period_cycles / period_tokens /
              steady_tokens_per_cycle; cyclic / control-bearing benches
              fall back to the dynamic engine (rows mirror "full").

    Streams are long (k_tokens tokens / fib_iters loop iterations) so
    per-cycle compute, not dispatch overhead, dominates; timings take
    the best of ``reps`` to shed scheduler noise.  cycles_per_s is the
    figure of merit: simulated fabric cycles per wall-clock second.
    """
    out = []
    for name, mk in library.BENCHES.items():
        if benches is not None and name not in benches:
            continue
        bench = mk()
        dt = np.dtype(bench.dtype)
        k = fib_iters if name in library.SINGLE_SHOT else k_tokens
        feeds = library.random_feeds(name, bench, k,
                                     np.random.default_rng(0))
        tok1 = library.tokens_out(name, k)
        for be in backends:
            if be == "pallas" and dt != np.int32:
                continue        # the pallas kernels are int32-only
            for K in Ks:
                for opt in levels:
                    run = compile(bench.graph, dtype=dt, backend=be,
                                  block_cycles=K, optimize=opt)
                    eng = run.engine
                    for B in Bs:
                        if B == 1:
                            call = lambda e=eng, f=feeds: e.run(f)
                        else:
                            fb = [library.random_feeds(
                                name, bench, k, np.random.default_rng(b))
                                for b in range(B)]
                            call = lambda e=eng, f=fb: e.run_batch(f)
                        res = call()    # warmup/compile
                        rs = res if isinstance(res, list) else [res]
                        ts = []
                        for _ in range(reps):
                            t0 = time.perf_counter()
                            call()
                            ts.append(time.perf_counter() - t0)
                        us = float(min(ts)) * 1e6
                        cyc = sum(r.cycles for r in rs)
                        rec = dict(
                            name=name, backend=be, B=B, K=K,
                            opt="off" if opt is False else opt,
                            nodes=len(run.graph.nodes),
                            us_per_call=round(us, 1),
                            cycles_per_s=round(cyc / us * 1e6),
                            tokens_per_s=round(B * tok1 / us * 1e6),
                            dispatches=rs[0].dispatches,
                            cycles=rs[0].cycles)
                        if opt == "sched":
                            rec["scheduled"] = bool(
                                getattr(eng, "_sched_on", False))
                            steady = _steady_info(eng, feeds)
                            if steady is not None:
                                rec.update(steady)
                        out.append(rec)
    return out


def opt_summary(recs, K=None, B=None):
    """Per-backend win count at the canonical (K, B) point — largest K,
    smallest B present in the records unless overridden: benches where
    the best opt-on cycles/s beats opt-off."""
    if not recs:
        return []
    K = max(r["K"] for r in recs) if K is None else K
    B = min(r["B"] for r in recs) if B is None else B
    rows = [r for r in recs if r["K"] == K and r["B"] == B]
    summary = []
    for be in sorted({r["backend"] for r in rows}):
        wins = []
        for name in sorted({r["name"] for r in rows}):
            cfg = {r["opt"]: r["cycles_per_s"] for r in rows
                   if r["backend"] == be and r["name"] == name}
            if not cfg or "off" not in cfg:
                continue
            best = max(v for o, v in cfg.items() if o != "off")
            if best > cfg["off"]:
                wins.append(f"{name}:{best / cfg['off']:.2f}x")
        summary.append(dict(backend=be, K=K, B=B, wins=len(wins),
                            total=len({r["name"] for r in rows}),
                            detail=wins))
    return summary


def print_opt_csv(recs):
    for r in recs:
        print(f"opt_{r['name']}_{r['backend']}_B{r['B']}_K{r['K']}_"
              f"{r['opt']},{r['us_per_call']},"
              f"cycles_per_s={r['cycles_per_s']};"
              f"tokens_per_s={r['tokens_per_s']};"
              f"nodes={r['nodes']};dispatches={r['dispatches']}")
    for s in opt_summary(recs):
        print(f"opt_summary_{s['backend']}_K{s['K']}_B{s['B']},0,"
              f"opt_beats_off_on={s['wins']}/{s['total']}:"
              f"{'+'.join(s['detail'])}")


def print_backend_csv(recs):
    """One CSV line per executor record (shared with benchmarks/run.py)."""
    for r in recs:
        print(f"engine_{r['name']}_{r['backend']}_B{r['B']}_K{r['K']},"
              f"{r['us_per_call']},"
              f"cycles_per_s={r['cycles_per_s']};"
              f"tokens_per_s={r['tokens_per_s']};"
              f"dispatches={r['dispatches']}")


def main(with_backends: bool = False):
    for r in rows():
        derived = (f"nodes={r['nodes']};arcs={r['arcs']};"
                   f"ff_bits={r['ff_bits']};lut={r['lut_weight']};"
                   f"lat_cyc={r['latency_cycles']};"
                   f"cyc_per_tok={r['cycles_per_token']}")
        print(f"table1_{r['name']},{r['compiled_us_per_token']},{derived}")
    if with_backends:
        print_backend_csv(backend_rows())


if __name__ == "__main__":
    import sys
    main(with_backends="--backends" in sys.argv)
