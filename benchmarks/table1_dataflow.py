"""Paper Table 1 analogue: per-benchmark fabric resources + speed.

Paper columns FF / LUT / Slices / Fmax map to (DESIGN.md §2):
  FF     -> arc register bits (16-bit data + 1-bit status per arc)
  LUT    -> summed operator datapath complexity weights
  Slices -> node count
  Fmax   -> engine throughput (cycles/token when streaming; the
            architecture-determined rate, like the paper's 613 MHz) and
            the compiled backend's wall-clock tokens/s on this host.

CSV: name,us_per_call,derived
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import library
from repro.core.compile import compile_dag_stream, compile_cyclic
from repro.core.engine import DataflowEngine


def _time(fn, *args, reps=5):
    fn(*args)   # warmup/compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6   # us


def rows():
    rng = np.random.default_rng(0)
    out = []
    stream_k = 64
    for name, mk in library.BENCHES.items():
        bench = mk()
        g = bench.graph
        r = g.resources()
        eng = DataflowEngine(g)
        if name == "fibonacci":
            feeds1 = bench.make_feeds(20)
            feeds_k = feeds1
            run = compile_cyclic(g)
            compiled_call = lambda: run(feeds1)
            n_stream = 1
        else:
            n = len(g.input_arcs())
            if name == "dot_prod":
                a = rng.integers(0, 9, (stream_k, n // 2))
                b = rng.integers(0, 9, (stream_k, n // 2))
                feeds1 = bench.make_feeds(a[:1], b[:1])
                feeds_k = bench.make_feeds(a, b)
            elif name == "pop_count":
                x = rng.integers(0, 2 ** 16, (stream_k,))
                feeds1 = bench.make_feeds(x[:1])
                feeds_k = bench.make_feeds(x)
            else:
                v = rng.integers(0, 99, (stream_k, n))
                feeds1 = bench.make_feeds(v[:1])
                feeds_k = bench.make_feeds(v)
            fn = compile_dag_stream(g)
            feeds_np = {k: np.asarray(v, np.int32)
                        for k, v in feeds_k.items()}
            compiled_call = lambda: fn(feeds_np)
            n_stream = stream_k

        lat = eng.run(feeds1).cycles
        thr = eng.run(feeds_k).cycles if n_stream > 1 else lat
        cyc_per_tok = (thr - lat) / max(n_stream - 1, 1) if n_stream > 1 \
            else lat
        us = _time(lambda: np.asarray(
            list(compiled_call().outputs.values() if name == "fibonacci"
                 else compiled_call().values())[0]))
        out.append({
            "name": name, "nodes": r["nodes"], "arcs": r["arcs"],
            "ff_bits": r["ff_bits"], "lut_weight": r["lut_weight"],
            "latency_cycles": lat,
            "cycles_per_token": round(cyc_per_tok, 2),
            "compiled_us_per_stream": round(us, 1),
            "compiled_us_per_token": round(us / n_stream, 2),
        })
    return out


def main():
    for r in rows():
        derived = (f"nodes={r['nodes']};arcs={r['arcs']};"
                   f"ff_bits={r['ff_bits']};lut={r['lut_weight']};"
                   f"lat_cyc={r['latency_cycles']};"
                   f"cyc_per_tok={r['cycles_per_token']}")
        print(f"table1_{r['name']},{r['compiled_us_per_token']},{derived}")


if __name__ == "__main__":
    main()
