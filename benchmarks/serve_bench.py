"""Continuous vs wave batching on mixed-length dataflow workloads.

The workload is a deterministic synthetic arrival trace over each
library bench: R requests in a fixed submission order whose stream
lengths mix many short requests with periodic long ones (the shape
that breaks wave batching — every wave of B inherits its slowest
member's residency, so the short requests idle in their slots).

Two servers, same engine, same arrival order:

  wave        — ``DataflowEngine.run_batch`` over successive groups of
                ``slots`` requests (the PR 1 API: a global barrier per
                group).
  continuous  — :class:`repro.serve.dataflow_server.DataflowServer`:
                per-slot quiescence detection + mid-flight refill from
                the queue, free slots clock-gated out of the fabric.

Each continuous row also reports serving-quality metrics (DESIGN.md
§11): per-request wall-latency p50/p99 (submit -> result, measured on
an instrumented step loop) and the queue's high-water mark.
``fault_rows()`` re-runs a subset through a seeded
:class:`~repro.serve.faults.FaultPlan` ("_faulted" rows) so the
overhead of the retry/watchdog/poison machinery is tracked next to the
clean numbers.

``main()`` sweeps every library bench x {xla, pallas} and writes
BENCH_serve.json (committed, so the requests/s trajectory is tracked
across PRs).  ``--quick`` runs 3 benches at tiny K/B with reps=1 as a
CI smoke step — it writes the same JSON schema so CI artifacts carry
the latency percentiles too.

CSV: name,us_per_call,derived  (one line per bench/backend/mode).
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from repro.core import library
from repro.serve.dataflow_server import DataflowServer, cached_engine
from repro.serve.faults import FaultPlan


def workload(name: str, bench, R: int, long_len: int = 200,
             every: int = 4):
    """Deterministic mixed-length trace: request i is *long*
    (``long_len`` tokens / loop iterations) when i % every == 0, else
    short (1-3 tokens).  Values are seeded per-request, so the trace is
    reproducible across runs and modes."""
    lens = [long_len if i % every == 0 else 1 + i % 3 for i in range(R)]
    return [library.random_feeds(name, bench, k,
                                 np.random.default_rng(1_000 + i))
            for i, k in enumerate(lens)]


def _time(fn, reps: int):
    fn()                       # warmup: compile every block/reset shape
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _latency_probe(mk_server, feeds):
    """One instrumented serve of ``feeds``: submit everything, then
    step (never drain) so each result's arrival is timestamped.
    Returns (results, per-request wall latencies in seconds, server)."""
    srv = mk_server()
    t0 = time.perf_counter()
    submit_t = {}
    for f in feeds:
        uid = srv.submit(f)
        submit_t[uid] = time.perf_counter()
    res, lat = [], []
    while srv.pending:
        for r in srv.step():
            now = time.perf_counter()
            res.append(r)
            lat.append(now - submit_t.get(r.uid, t0))
    return res, lat, srv


def _pcts(lat):
    return (round(float(np.percentile(lat, 50)) * 1e3, 3),
            round(float(np.percentile(lat, 99)) * 1e3, 3))


def serve_rows(benches=None, backends=("xla", "pallas"), R: int = 16,
               slots: int = 4, block: int = 32, reps: int = 3,
               long_len: int = 200, every: int = 4):
    out = []
    for name, mk in library.BENCHES.items():
        if benches is not None and name not in benches:
            continue
        bench = mk()
        if np.dtype(bench.dtype) != np.int32:
            continue    # the resumable slot API is int32-only
        feeds = workload(name, bench, R, long_len=long_len, every=every)
        for backend in backends:
            eng = cached_engine(bench.graph, backend=backend,
                                block_cycles=block)

            def run_wave():
                res = []
                for i in range(0, R, slots):
                    res.extend(eng.run_batch(feeds[i:i + slots]))
                return res

            def run_cont(out=None):
                srv = DataflowServer(bench.graph, slots=slots,
                                     block_cycles=block, engine=eng)
                for f in feeds:
                    srv.submit(f)
                res = srv.drain()
                if out is not None:
                    out.append((res, srv))
                return res

            wave_res = run_wave()
            probe: list = []
            run_cont(out=probe)
            cont_res, srv = probe[0]
            # same work was done (sanity — results are property-tested
            # bit-identical in tests/test_dataflow_server.py)
            assert len(cont_res) == len(wave_res) == R
            wave_disp = sum(r.dispatches for r in wave_res[::slots])
            cont_disp = srv.block + srv.admission_rounds
            waits = [r.metrics.queue_wait_blocks for r in cont_res]
            wave_s = _time(run_wave, reps)
            cont_s = _time(run_cont, reps)
            # per-request wall latency, measured on a separate
            # instrumented pass (the timed passes above stay untouched)
            _, lat, probe_srv = _latency_probe(
                lambda: DataflowServer(bench.graph, slots=slots,
                                       block_cycles=block, engine=eng),
                feeds)
            p50, p99 = _pcts(lat)
            out.append(dict(
                name=name, backend=backend, R=R, slots=slots, K=block,
                long_len=long_len,
                wave_s=round(wave_s, 4), cont_s=round(cont_s, 4),
                wave_req_per_s=round(R / wave_s, 1),
                cont_req_per_s=round(R / cont_s, 1),
                speedup=round(wave_s / cont_s, 2),
                wave_dispatches=wave_disp, cont_dispatches=cont_disp,
                cont_p50_ms=p50, cont_p99_ms=p99,
                max_queue_depth=probe_srv.max_queue_depth,
                mean_queue_wait_blocks=round(float(np.mean(waits)), 2),
                mean_residency_cycles=round(float(np.mean(
                    [r.metrics.residency_cycles for r in cont_res])), 1)))
    return out


def fault_rows(benches=("vector_sum",), backend="xla", R: int = 16,
               slots: int = 4, block: int = 8,
               long_len: int = 64, every: int = 4):
    """"_faulted" rows: the same mixed-length trace served through a
    seeded FaultPlan (transient dispatch failures + wedges + poisoned
    feeds) — measuring what the fault-tolerance machinery costs and
    recording the disposition mix.  Every request must still be
    answered; the row asserts conservation before it is emitted."""
    out = []
    for name in benches:
        bench = library.BENCHES[name]()
        if np.dtype(bench.dtype) != np.int32:
            continue
        feeds = workload(name, bench, R, long_len=long_len, every=every)

        def mk():
            return DataflowServer(
                bench.graph, slots=slots, block_cycles=block,
                backend=backend, max_retries=3, wedge_timeout_blocks=4,
                faults=FaultPlan(seed=11, dispatch_fail_rate=0.05,
                                 transient_attempts=1,
                                 wedge_rate=0.1, poison_rate=0.1))

        _latency_probe(mk, feeds)          # warmup (compiles)
        t0 = time.perf_counter()
        res, lat, srv = _latency_probe(mk, feeds)
        total_s = time.perf_counter() - t0
        assert len(res) == R, "every request must be answered"
        p50, p99 = _pcts(lat)
        statuses: dict[str, int] = {}
        for r in res:
            statuses[r.status] = statuses.get(r.status, 0) + 1
        out.append(dict(
            name=f"{name}_faulted", backend=backend, R=R, slots=slots,
            K=block, long_len=long_len,
            cont_s=round(total_s, 4),
            cont_req_per_s=round(R / total_s, 1),
            cont_p50_ms=p50, cont_p99_ms=p99,
            max_queue_depth=srv.max_queue_depth,
            statuses=statuses, retries=len(
                [e for e in srv.events if e["kind"] == "dispatch-retry"])))
    return out


def export_observability(bench_name: str = "vector_sum",
                         backend: str = "xla", R: int = 8,
                         slots: int = 2, block: int = 4,
                         long_len: int = 8,
                         trace_path: str | None = None,
                         metrics_path: str | None = None) -> dict:
    """``--trace``: one fully instrumented serve (profile + trace +
    metrics all on); writes BENCH_serve_trace.json (Chrome trace-event
    JSON — load it in Perfetto / chrome://tracing) and
    BENCH_serve_metrics.json, then re-loads and validates both so a
    malformed export fails the CI smoke right here.

    Honours ``REPRO_FAULTS``: when the chaos job sets it (anything but
    "off"), the serve runs under a seeded FaultPlan and the export must
    contain fault-injection events."""
    from repro.obs import (MetricsRegistry, TraceRecorder, load_chrome,
                           validate_chrome, validate_snapshot)
    bench = library.BENCHES[bench_name]()
    feeds = workload(bench_name, bench, R, long_len=long_len, every=3)
    chaos = os.environ.get("REPRO_FAULTS", "").lower() not in ("", "off")
    plan = FaultPlan.scaled(seed=11, dispatch_fail_rate=0.1,
                            transient_attempts=1, wedge_rate=0.15,
                            poison_rate=0.15) if chaos else None
    tr, mr = TraceRecorder(), MetricsRegistry()
    srv = DataflowServer(bench.graph, slots=slots, block_cycles=block,
                         backend=backend, wedge_timeout_blocks=4,
                         faults=plan, profile=True, trace=tr, metrics=mr)
    for f in feeds:
        srv.submit(f)
    res = srv.drain()
    assert len(res) == R, "every request must be answered"
    profiled = [r for r in res
                if r.engine is not None and r.engine.profile is not None]
    for r in profiled:
        r.engine.profile.check()
    fires = sum(r.engine.profile.fired for r in profiled)
    root = os.path.join(os.path.dirname(__file__), "..")
    trace_path = trace_path or os.path.join(root, "BENCH_serve_trace.json")
    metrics_path = metrics_path or os.path.join(root,
                                                "BENCH_serve_metrics.json")
    tr.save(trace_path)
    mr.save(metrics_path)
    info = validate_chrome(load_chrome(trace_path))
    with open(metrics_path) as f:
        validate_snapshot(json.load(f))
    kinds = sorted({e.kind for e in tr.events})
    if plan is not None and plan.log:
        assert "fault" in kinds, \
            f"chaos run injected faults but the trace has none: {kinds}"
    print(f"serve_trace_{bench_name}_{backend},0,"
          f"events={info['events']};uids={info['uids']};"
          f"tracks={info['tracks']};fires={fires};"
          f"chaos={int(chaos)};kinds={'+'.join(kinds)}")
    return dict(trace=trace_path, metrics=metrics_path, kinds=kinds,
                fires=fires, **info)


def print_csv(recs):
    for r in recs:
        base = f"serve_{r['name']}_{r['backend']}"
        if "wave_s" in r:
            print(f"{base}_wave,{r['wave_s'] * 1e6:.0f},"
                  f"req_per_s={r['wave_req_per_s']};"
                  f"dispatches={r['wave_dispatches']}")
        tail = (f"speedup={r['speedup']};"
                f"wait_blocks={r['mean_queue_wait_blocks']}"
                if "speedup" in r else
                f"statuses={r['statuses']};retries={r['retries']}")
        print(f"{base}_cont,{r['cont_s'] * 1e6:.0f},"
              f"req_per_s={r['cont_req_per_s']};"
              f"p50_ms={r['cont_p50_ms']};p99_ms={r['cont_p99_ms']};"
              f"max_queue={r['max_queue_depth']};" + tail)


def _write(recs, path: str | None) -> None:
    path = path or os.path.join(os.path.dirname(__file__), "..",
                                "BENCH_serve.json")
    with open(path, "w") as f:
        json.dump(recs, f, indent=1)


def main(path: str | None = None) -> list[dict]:
    recs = serve_rows() + fault_rows()
    _write(recs, path)
    print_csv(recs)
    for backend in ("xla", "pallas"):
        rows = [r for r in recs if r["backend"] == backend
                and "speedup" in r]
        wins = [r["name"] for r in rows if r["speedup"] > 1.0]
        print(f"serve_summary_{backend},0,continuous_beats_wave_on="
              f"{len(wins)}/{len(rows)}:{'+'.join(wins)}")
    return recs


def quick(path: str | None = None) -> list[dict]:
    """CI smoke: 3 benches at tiny K/B, reps=1 — exercises the code
    paths (incl. the faulted row) and writes the full JSON schema, p50/
    p99 latency and queue high-water included, without reproducing the
    committed full-run speedups."""
    recs = serve_rows(benches=("vector_sum", "fibonacci", "gcd"),
                      backends=("xla", "pallas"), R=6, slots=2, block=4,
                      reps=1, long_len=8, every=3)
    recs += fault_rows(R=6, slots=2, block=4, long_len=8)
    _write(recs, path)
    print_csv(recs)
    return recs


if __name__ == "__main__":
    quick() if "--quick" in sys.argv else main()
    if "--trace" in sys.argv:
        export_observability()
