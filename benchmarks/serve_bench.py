"""Continuous vs wave batching on mixed-length dataflow workloads.

The workload is a deterministic synthetic arrival trace over each
library bench: R requests in a fixed submission order whose stream
lengths mix many short requests with periodic long ones (the shape
that breaks wave batching — every wave of B inherits its slowest
member's residency, so the short requests idle in their slots).

Two servers, same engine, same arrival order:

  wave        — ``DataflowEngine.run_batch`` over successive groups of
                ``slots`` requests (the PR 1 API: a global barrier per
                group).
  continuous  — :class:`repro.serve.dataflow_server.DataflowServer`:
                per-slot quiescence detection + mid-flight refill from
                the queue, free slots clock-gated out of the fabric.

``main()`` sweeps every library bench x {xla, pallas} and writes
BENCH_serve.json (committed, so the requests/s trajectory is tracked
across PRs).  ``--quick`` runs 2 benches at tiny K/B with reps=1 as a
CI smoke step.

CSV: name,us_per_call,derived  (one line per bench/backend/mode).
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from repro.core import library
from repro.serve.dataflow_server import DataflowServer, cached_engine


def workload(name: str, bench, R: int, long_len: int = 200,
             every: int = 4):
    """Deterministic mixed-length trace: request i is *long*
    (``long_len`` tokens / loop iterations) when i % every == 0, else
    short (1-3 tokens).  Values are seeded per-request, so the trace is
    reproducible across runs and modes."""
    lens = [long_len if i % every == 0 else 1 + i % 3 for i in range(R)]
    return [library.random_feeds(name, bench, k,
                                 np.random.default_rng(1_000 + i))
            for i, k in enumerate(lens)]


def _time(fn, reps: int):
    fn()                       # warmup: compile every block/reset shape
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def serve_rows(benches=None, backends=("xla", "pallas"), R: int = 16,
               slots: int = 4, block: int = 32, reps: int = 3,
               long_len: int = 200, every: int = 4):
    out = []
    for name, mk in library.BENCHES.items():
        if benches is not None and name not in benches:
            continue
        bench = mk()
        if np.dtype(bench.dtype) != np.int32:
            continue    # the resumable slot API is int32-only
        feeds = workload(name, bench, R, long_len=long_len, every=every)
        for backend in backends:
            eng = cached_engine(bench.graph, backend=backend,
                                block_cycles=block)

            def run_wave():
                res = []
                for i in range(0, R, slots):
                    res.extend(eng.run_batch(feeds[i:i + slots]))
                return res

            def run_cont(out=None):
                srv = DataflowServer(bench.graph, slots=slots,
                                     block_cycles=block, engine=eng)
                for f in feeds:
                    srv.submit(f)
                res = srv.drain()
                if out is not None:
                    out.append((res, srv))
                return res

            wave_res = run_wave()
            probe: list = []
            run_cont(out=probe)
            cont_res, srv = probe[0]
            # same work was done (sanity — results are property-tested
            # bit-identical in tests/test_dataflow_server.py)
            assert len(cont_res) == len(wave_res) == R
            wave_disp = sum(r.dispatches for r in wave_res[::slots])
            cont_disp = srv.block + srv.admission_rounds
            waits = [r.metrics.queue_wait_blocks for r in cont_res]
            wave_s = _time(run_wave, reps)
            cont_s = _time(run_cont, reps)
            out.append(dict(
                name=name, backend=backend, R=R, slots=slots, K=block,
                long_len=long_len,
                wave_s=round(wave_s, 4), cont_s=round(cont_s, 4),
                wave_req_per_s=round(R / wave_s, 1),
                cont_req_per_s=round(R / cont_s, 1),
                speedup=round(wave_s / cont_s, 2),
                wave_dispatches=wave_disp, cont_dispatches=cont_disp,
                mean_queue_wait_blocks=round(float(np.mean(waits)), 2),
                mean_residency_cycles=round(float(np.mean(
                    [r.metrics.residency_cycles for r in cont_res])), 1)))
    return out


def print_csv(recs):
    for r in recs:
        base = f"serve_{r['name']}_{r['backend']}"
        print(f"{base}_wave,{r['wave_s'] * 1e6:.0f},"
              f"req_per_s={r['wave_req_per_s']};"
              f"dispatches={r['wave_dispatches']}")
        print(f"{base}_cont,{r['cont_s'] * 1e6:.0f},"
              f"req_per_s={r['cont_req_per_s']};"
              f"dispatches={r['cont_dispatches']};"
              f"speedup={r['speedup']};"
              f"wait_blocks={r['mean_queue_wait_blocks']}")


def main(path: str | None = None) -> list[dict]:
    recs = serve_rows()
    path = path or os.path.join(os.path.dirname(__file__), "..",
                                "BENCH_serve.json")
    with open(path, "w") as f:
        json.dump(recs, f, indent=1)
    print_csv(recs)
    for backend in ("xla", "pallas"):
        rows = [r for r in recs if r["backend"] == backend]
        wins = [r["name"] for r in rows if r["speedup"] > 1.0]
        print(f"serve_summary_{backend},0,continuous_beats_wave_on="
              f"{len(wins)}/{len(rows)}:{'+'.join(wins)}")
    return recs


def quick() -> list[dict]:
    """CI smoke: 2 benches, tiny K/B, no JSON (the committed file is a
    full-run artifact; quick exists to exercise the code paths, not to
    reproduce the speedups)."""
    recs = serve_rows(benches=("vector_sum", "fibonacci", "gcd"),
                      backends=("xla", "pallas"), R=6, slots=2, block=4,
                      reps=1, long_len=8, every=3)
    print_csv(recs)
    return recs


if __name__ == "__main__":
    quick() if "--quick" in sys.argv else main()
