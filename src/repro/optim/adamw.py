"""AdamW with cosine schedule and global-norm clipping (pure JAX pytrees).

Master weights are kept in the params' own dtype (configs default f32);
moments in f32.  ``update`` is functional: (grads, state, params) -> (new
params, new state).  Optimizer state sharding follows the parameter
sharding (ZeRO-style when params are FSDP-sharded — see parallel/).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class OptConfig(NamedTuple):
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any
    master: Any = None   # f32 master copy when params are bf16 (ZeRO-ish)


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, frac)


def init(params, master_weights: bool = False) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params) \
        if master_weights else None
    return OptState(step=jnp.int32(0), m=zeros,
                    v=jax.tree.map(jnp.copy, zeros), master=master)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: OptConfig, grads, state: OptState, params):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p, mw):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0  # no decay on norms
        src = mw if mw is not None else p.astype(jnp.float32)
        p_new = src - lr * (delta + wd * src)
        return p_new.astype(p.dtype), m_new, v_new, \
            (p_new if mw is not None else None)

    masters = state.master if state.master is not None else \
        jax.tree.map(lambda _: None, params)
    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_mw = jax.tree.leaves(state.master) \
        if state.master is not None else [None] * len(flat_p)
    out = [upd(g, m, v, p, mw) for g, m, v, p, mw in
           zip(flat_g, flat_m, flat_v, flat_p, flat_mw)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_master = jax.tree.unflatten(treedef, [o[3] for o in out]) \
        if state.master is not None else None
    return new_params, OptState(step, new_m, new_v, new_master), \
        {"grad_norm": gnorm, "lr": lr}
