"""Transformer building blocks: norms, RoPE, GQA flash attention, MLPs.

Attention is a *doubly-chunked online-softmax* implementation (pure JAX):
an unrolled loop over query blocks with an inner ``lax.scan`` over KV
blocks, carrying (m, l, acc).  This bounds live memory to one
[block_q × block_kv] score tile per head regardless of sequence length —
the same blocking the Pallas TPU kernel (kernels/flash_attention.py) uses,
so the dry-run lowering reflects the kernel's memory behaviour.  Causal
masking is block-exact: query block i only scans KV blocks 0..i, so the
compiled FLOPs match the triangular work (no 2× waste).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rmsnorm(x, w, eps=1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * \
        w.astype(x.dtype)


def layernorm(x, w, b, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    y = y.astype(x.dtype) * w.astype(x.dtype)
    return y + b.astype(x.dtype) if b is not None else y


def apply_norm(cfg, p, x):
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, p["w"])
    return layernorm(x, p["w"], p.get("b"))


def init_norm(cfg, d):
    p = {"w": jnp.ones((d,), jnp.dtype(cfg.param_dtype))}
    if cfg.norm == "layernorm":
        p["b"] = jnp.zeros((d,), jnp.dtype(cfg.param_dtype))
    return p


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope(x, pos, theta: float):
    """x: [B, S, H, hd], pos: [B, S] int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos[..., None].astype(jnp.float32) * freqs     # [B, S, half]
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1)


# ---------------------------------------------------------------------------
# flash attention (pure JAX, chunked)
# ---------------------------------------------------------------------------
def flash_attention(q, k, v, *, causal: bool, q_block: int = 512,
                    kv_block: int = 1024, q_offset=0, kv_len=None):
    """Online-softmax attention.

    q: [B, Sq, H, hd]; k, v: [B, Skv, Hkv, hd] with H % Hkv == 0.
    q_offset: absolute position of q[0] (decode: cache length so far).
    kv_len:   number of valid cache entries (decode with a preallocated
              cache); None means all Skv are valid.
    Returns [B, Sq, H, hd] in q.dtype; accumulation in f32.
    """
    B, Sq, H, hd = q.shape
    _, Skv, Hkv, _ = k.shape
    G = H // Hkv
    scale = 1.0 / math.sqrt(hd)

    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Skv)
    pad_q = (-Sq) % q_block
    pad_kv = (-Skv) % kv_block
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    Sq_p, Skv_p = Sq + pad_q, Skv + pad_kv
    n_q, n_kv = Sq_p // q_block, Skv_p // kv_block
    if kv_len is None:
        kv_valid = jnp.asarray(Skv, jnp.int32)
    else:
        kv_valid = jnp.asarray(kv_len, jnp.int32)

    # [B, Sq, Hkv, G, hd] -> blocks
    qb = q.reshape(B, n_q, q_block, Hkv, G, hd)
    kb = k.reshape(B, n_kv, kv_block, Hkv, hd)
    vb = v.reshape(B, n_kv, kv_block, Hkv, hd)
    kpos = jnp.arange(Skv_p, dtype=jnp.int32).reshape(n_kv, kv_block)

    outs = []
    for i in range(n_q):                      # unrolled: static shapes
        qi = qb[:, i].astype(jnp.float32) * scale    # [B,bq,Hkv,G,hd]
        qpos = q_offset + i * q_block + jnp.arange(q_block)
        if causal and isinstance(q_offset, int):
            # block-exact causal: KV block j needed iff it can contain a
            # position <= the last q position of this q block
            hi = min(n_kv, (q_offset + (i + 1) * q_block - 1) // kv_block + 1)
        else:
            hi = n_kv  # dynamic offset (decode): keep all, rely on mask

        def step(carry, inp):
            m, l, acc = carry
            kj, vj, kpj = inp
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qi, kj.astype(jnp.float32))
            mask = kpj[None, :] < kv_valid
            if causal:
                mask = mask & (qpos[:, None] >= kpj[None, :])
            s = jnp.where(mask[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, vj.astype(jnp.float32))
            acc = acc * corr[..., None] + pv
            return (m_new, l, acc), None

        m0 = jnp.full((B, Hkv, G, q_block), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_block), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, q_block, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            step, (m0, l0, a0),
            (kb[:, :hi].swapaxes(0, 1), vb[:, :hi].swapaxes(0, 1),
             kpos[:hi]))
        l = jnp.where(l == 0, 1.0, l)        # fully-masked rows (padding)
        o = (acc / l[..., None]).astype(q.dtype)   # [B,Hkv,G,bq,hd]
        outs.append(o.transpose(0, 3, 1, 2, 4).reshape(B, q_block, H, hd))
    out = jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    return out[:, :Sq]


def naive_attention(q, k, v, *, causal: bool, q_offset=0, kv_len=None):
    """Reference (materializes full scores) — oracle for tests."""
    B, Sq, H, hd = q.shape
    _, Skv, Hkv, _ = k.shape
    G = H // Hkv
    qr = q.reshape(B, Sq, Hkv, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qr,
                   k.astype(jnp.float32)) / math.sqrt(hd)
    kpos = jnp.arange(Skv)
    qpos = q_offset + jnp.arange(Sq)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if kv_len is not None:
        mask &= (kpos < kv_len)[None, :]
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# attention block (projections + cache handling)
# ---------------------------------------------------------------------------
def init_attn(cfg, key, d=None):
    d = d or cfg.d_model
    hd, H, Hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    pdt = jnp.dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    std = d ** -0.5
    p = {}
    if cfg.fused_qkv:
        p["wqkv"] = (jax.random.normal(k1, (d, (H + 2 * Hkv) * hd)) *
                     std).astype(pdt)
        if cfg.qkv_bias:
            p["bqkv"] = jnp.zeros(((H + 2 * Hkv) * hd,), pdt)
    else:
        kq, kk, kv = jax.random.split(k1, 3)
        p["wq"] = (jax.random.normal(kq, (d, H * hd)) * std).astype(pdt)
        p["wk"] = (jax.random.normal(kk, (d, Hkv * hd)) * std).astype(pdt)
        p["wv"] = (jax.random.normal(kv, (d, Hkv * hd)) * std).astype(pdt)
        if cfg.qkv_bias:
            p["bq"] = jnp.zeros((H * hd,), pdt)
            p["bk"] = jnp.zeros((Hkv * hd,), pdt)
            p["bv"] = jnp.zeros((Hkv * hd,), pdt)
    p["wo"] = (jax.random.normal(k2, (H * hd, d)) *
               (H * hd) ** -0.5).astype(pdt)
    if cfg.attn_out_bias:
        p["bo"] = jnp.zeros((d,), pdt)
    return p


def qkv_proj(cfg, p, x):
    B, S, d = x.shape
    hd, H, Hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    if cfg.fused_qkv:
        qkv = x @ p["wqkv"].astype(x.dtype)
        if "bqkv" in p:
            qkv = qkv + p["bqkv"].astype(x.dtype)
        q, k, v = jnp.split(qkv, [H * hd, (H + Hkv) * hd], axis=-1)
    else:
        q = x @ p["wq"].astype(x.dtype)
        k = x @ p["wk"].astype(x.dtype)
        v = x @ p["wv"].astype(x.dtype)
        if "bq" in p:
            q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (q.reshape(B, S, H, hd), k.reshape(B, S, Hkv, hd),
            v.reshape(B, S, Hkv, hd))


class KVCache(NamedTuple):
    k: jax.Array   # [B, S_max, Hkv, hd]
    v: jax.Array
    length: jax.Array  # [] int32 — valid entries


def _attn_constraint(cfg, q, k, v):
    """Optional sequence-parallel attention: shard q's sequence dim over
    the model axis (kv replicated over model) — used when head counts
    don't divide the mesh (e.g. starcoder2's 36 heads on a 16-way axis).
    """
    if cfg.attn_partition != "seq" or not cfg.mesh_axes:
        return q, k, v
    from jax.sharding import PartitionSpec as P
    data = tuple(a for a in cfg.mesh_axes if a != "model")
    d = data if len(data) > 1 else data[0]
    wsc = jax.lax.with_sharding_constraint
    q = wsc(q, P(d, "model", None, None))
    k = wsc(k, P(d, None, None, None))
    v = wsc(v, P(d, None, None, None))
    return q, k, v


def attn_block(cfg, p, x, pos, *, causal=True, cache: KVCache | None = None):
    """Self-attention with optional decode cache.

    cache: decode mode — append k/v at cache.length, attend over cache.
    """
    B, S, _ = x.shape
    q, k, v = qkv_proj(cfg, p, x)
    if cfg.rope:
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
    if cache is None:
        q, k, v = _attn_constraint(cfg, q, k, v)
    if cache is not None:
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k.astype(cache.k.dtype), cache.length, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v.astype(cache.v.dtype), cache.length, axis=1)
        new_len = cache.length + S
        o = flash_attention(q, ck, cv, causal=causal,
                            q_block=cfg.attn_q_block,
                            kv_block=cfg.attn_kv_block,
                            q_offset=cache.length, kv_len=new_len)
        new_cache = KVCache(ck, cv, new_len)
    else:
        o = flash_attention(q, k, v, causal=causal,
                            q_block=cfg.attn_q_block,
                            kv_block=cfg.attn_kv_block)
        new_cache = None
    o = o.reshape(B, S, -1) @ p["wo"].astype(x.dtype)
    if "bo" in p:
        o = o + p["bo"].astype(x.dtype)
    return o, new_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
def init_mlp(cfg, key, d=None, ff=None):
    d, ff = d or cfg.d_model, ff or cfg.d_ff
    pdt = jnp.dtype(cfg.param_dtype)
    if cfg.act == "swiglu":
        k1, k2, k3 = jax.random.split(key, 3)
        return {"w1": (jax.random.normal(k1, (d, ff)) * d**-0.5).astype(pdt),
                "w3": (jax.random.normal(k3, (d, ff)) * d**-0.5).astype(pdt),
                "w2": (jax.random.normal(k2, (ff, d)) * ff**-0.5).astype(pdt)}
    k1, k2 = jax.random.split(key)
    return {"fc1": (jax.random.normal(k1, (d, ff)) * d**-0.5).astype(pdt),
            "b1": jnp.zeros((ff,), pdt),
            "fc2": (jax.random.normal(k2, (ff, d)) * ff**-0.5).astype(pdt),
            "b2": jnp.zeros((d,), pdt)}


def mlp_block(cfg, p, x):
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ p["w1"].astype(x.dtype)) * \
            (x @ p["w3"].astype(x.dtype))
        return h @ p["w2"].astype(x.dtype)
    h = jax.nn.gelu(x @ p["fc1"].astype(x.dtype) + p["b1"].astype(x.dtype))
    return h @ p["fc2"].astype(x.dtype) + p["b2"].astype(x.dtype)
