"""Mixture-of-Experts layer (GShard-style capacity dispatch).

Routing is the paper's conditional dataflow made tensor-scale: the router
is a `branch` operator fanning tokens out to expert sub-fabrics and a
`dmerge` combining them back (DESIGN.md §5).

Implementation: top-k routing with capacity C = ceil(k·S_g/E · cf) over
*groups* of S_g tokens (``cfg.moe_group_size``).  The dispatch/combine
tensors are [G, S_g, E, C]; their size is k·S_g² *independent of E*, so
group size — not expert count — controls the memory knee.  Groups shard
over the data axis, experts over the model axis (EP); the token exchange
lowers to all-to-all on a (data × model) mesh.

Tokens over capacity are dropped (standard Switch/GShard semantics);
aux load-balancing loss returned alongside.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def init_moe(cfg, key):
    d, ff, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    pdt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    p = {
        "router": (jax.random.normal(ks[0], (d, E)) * d**-0.5).astype(pdt),
        "w1": (jax.random.normal(ks[1], (E, d, ff)) * d**-0.5).astype(pdt),
        "w3": (jax.random.normal(ks[2], (E, d, ff)) * d**-0.5).astype(pdt),
        "w2": (jax.random.normal(ks[3], (E, ff, d)) * ff**-0.5).astype(pdt),
    }
    if cfg.shared_expert:
        from repro.models.layers import init_mlp
        p["shared"] = init_mlp(cfg, ks[4], d=d, ff=ff)
    return p


def moe_block(cfg, p, x):
    """x: [B, S, d] -> (y, aux_loss)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    Sg = min(cfg.moe_group_size, B * S)
    T = B * S
    assert T % Sg == 0, (T, Sg)
    G = T // Sg
    xg = x.reshape(G, Sg, d)

    logits = (xg.astype(jnp.float32) @
              p["router"].astype(jnp.float32))          # [G,Sg,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)            # [G,Sg,k]
    gate_vals = gate_vals / jnp.clip(
        gate_vals.sum(-1, keepdims=True), 1e-9)         # renormalize top-k

    C = int(np.ceil(k * Sg / E * cfg.capacity_factor))
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)  # [G,Sg,k,E]
    # position of each (token, slot) within its expert's queue
    pos = jnp.cumsum(onehot.reshape(G, Sg * k, E), axis=1) \
        .reshape(G, Sg, k, E) - onehot                  # [G,Sg,k,E]
    keep = (pos < C) & (onehot > 0)
    pos_c = jnp.einsum("gske,gske->gsk", pos, onehot).astype(jnp.int32)
    pos_oh = jax.nn.one_hot(pos_c, C, dtype=jnp.float32)  # [G,Sg,k,C]
    keep_f = keep.astype(jnp.float32)                     # [G,Sg,k,E]
    dispatch = jnp.einsum("gske,gskc->gsec", keep_f, pos_oh)
    combine = jnp.einsum("gske,gsk,gskc->gsec", keep_f, gate_vals, pos_oh)

    def _constrain(t):
        """moe_partition="tokens": pin expert activations to (expert ->
        model, token-group -> data).  Forces XLA to all-gather the (small)
        FSDP weight shards per layer instead of all-reducing the (huge)
        expert activations over the data axis — see EXPERIMENTS.md §Perf
        H3."""
        if getattr(cfg, "moe_partition", "auto") != "tokens" or \
                not cfg.mesh_axes:
            return t
        from jax.sharding import PartitionSpec as P
        data = tuple(a for a in cfg.mesh_axes if a != "model")
        d_ax = data if len(data) > 1 else data[0]
        return jax.lax.with_sharding_constraint(
            t, P("model", d_ax, *([None] * (t.ndim - 2))))

    cdt = x.dtype
    xe = jnp.einsum("gsec,gsd->egcd", dispatch.astype(cdt), xg)  # [E,G,C,d]
    xe = _constrain(xe)
    h = jnp.einsum("egcd,edf->egcf", xe, p["w1"].astype(cdt))
    if cfg.act == "swiglu":
        h = jax.nn.silu(h) * jnp.einsum("egcd,edf->egcf", xe,
                                        p["w3"].astype(cdt))
    else:
        h = jax.nn.gelu(h)
    h = _constrain(h)
    ye = jnp.einsum("egcf,efd->egcd", h, p["w2"].astype(cdt))
    ye = _constrain(ye)
    y = jnp.einsum("egcd,gsec->gsd", ye, combine.astype(cdt))

    if cfg.shared_expert:
        from repro.models.layers import mlp_block
        y = y + mlp_block(cfg, {kk: v for kk, v in p["shared"].items()},
                          xg)

    # Switch aux loss: E * sum_e fraction_tokens_e * mean_prob_e
    frac = jnp.mean(onehot[:, :, 0, :], axis=(0, 1))    # top-1 assignment
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac * mean_prob)
    return y.reshape(B, S, d), aux
