"""Model zoo: one composable LM covering all assigned families.

Families:
  dense   — pre-norm transformer (GQA + MLP), scan-over-layers
  moe     — dense attention + MoE FFN (leading dense layers supported)
  hybrid  — zamba2: Mamba2 backbone + ONE weight-shared attention block
            applied every `attn_every` layers
  ssm     — rwkv6: attention-free time-mix/channel-mix
  vlm     — dense backbone; stub patch frontend (precomputed patch
            embeddings projected & spliced over the first n_patches slots)
  audio   — whisper: encoder (stub frame embeddings) + decoder with
            cross-attention

All layer stacks are ``lax.scan`` over stacked parameters (compile-time
O(1) in depth) with optional per-layer remat.  The model is also exposed
as a coarse dataflow graph for the pipeline scheduler (see
repro/core/pipeline.py): embed -> layer* -> norm -> head are the operator
nodes, activations are the tokens.

The training loss is *chunked-vocab* cross-entropy: logits are produced
seq-chunk by seq-chunk inside a scan so the [B,S,V] tensor is never live
(a beyond-paper memory optimization; see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import ssm
from repro.models.layers import (KVCache, apply_norm, attn_block,
                                 flash_attention, init_attn, init_mlp,
                                 init_norm, mlp_block)
from repro.models.moe import init_moe, moe_block

Params = Any


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_params(cfg: ArchConfig, key) -> Params:
    pdt = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, cfg.n_layers + cfg.n_enc_layers + 8)
    p: dict = {}
    p["embed"] = (jax.random.normal(keys[-1], (cfg.vocab, cfg.d_model))
                  * 0.02).astype(pdt)
    if not cfg.tie_embeddings:
        p["head"] = (jax.random.normal(keys[-2], (cfg.d_model, cfg.vocab))
                     * cfg.d_model**-0.5).astype(pdt)
    p["final_norm"] = init_norm(cfg, cfg.d_model)
    if cfg.frontend == "patches":
        p["patch_proj"] = (jax.random.normal(
            keys[-3], (cfg.frontend_dim, cfg.d_model)) *
            cfg.frontend_dim**-0.5).astype(pdt)
    if cfg.frontend == "frames":
        p["frame_proj"] = (jax.random.normal(
            keys[-3], (cfg.frontend_dim, cfg.d_model)) *
            cfg.frontend_dim**-0.5).astype(pdt)

    def dense_layer(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": init_norm(cfg, cfg.d_model),
                "attn": init_attn(cfg, k1),
                "ln2": init_norm(cfg, cfg.d_model),
                "mlp": init_mlp(cfg, k2)}

    def moe_layer(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": init_norm(cfg, cfg.d_model),
                "attn": init_attn(cfg, k1),
                "ln2": init_norm(cfg, cfg.d_model),
                "moe": init_moe(cfg, k2)}

    if cfg.rwkv:
        p["layers"] = _stack([
            {"ln1": init_norm(cfg, cfg.d_model),
             "tm": ssm.init_rwkv6(cfg, keys[i]),
             "ln2": init_norm(cfg, cfg.d_model)}
            for i in range(cfg.n_layers)])
    elif cfg.family == "hybrid":
        p["layers"] = _stack([
            {"ln": init_norm(cfg, cfg.d_model),
             "mamba": ssm.init_mamba2(cfg, keys[i])}
            for i in range(cfg.n_layers)])
        p["shared"] = dense_layer(keys[cfg.n_layers])  # ONE shared block
    elif cfg.n_experts:
        nd = cfg.n_dense_layers
        if nd:
            p["dense_layers"] = _stack(
                [dense_layer(keys[i]) for i in range(nd)])
        p["layers"] = _stack(
            [moe_layer(keys[nd + i]) for i in range(cfg.n_layers - nd)])
    else:
        p["layers"] = _stack(
            [dense_layer(keys[i]) for i in range(cfg.n_layers)])

    if cfg.enc_dec:
        p["enc_layers"] = _stack(
            [dense_layer(keys[cfg.n_layers + i])
             for i in range(cfg.n_enc_layers)])
        p["enc_norm"] = init_norm(cfg, cfg.d_model)
        # decoder cross-attention (per decoder layer)
        def xattn(k):
            q = init_attn(dataclasses.replace(cfg, fused_qkv=False), k)
            return {"ln": init_norm(cfg, cfg.d_model), **q}
        p["xattn"] = _stack(
            [xattn(keys[cfg.n_layers + cfg.n_enc_layers - 1 - i])
             for i in range(cfg.n_layers)])
    return p


def count_params(params) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(params)))


# ---------------------------------------------------------------------------
# layer bodies
# ---------------------------------------------------------------------------
def _dense_body(cfg, lp, x, pos, cache=None, causal=True):
    a, new_cache = attn_block(cfg, lp["attn"], apply_norm(cfg, lp["ln1"], x),
                              pos, causal=causal, cache=cache)
    x = x + a
    x = x + mlp_block(cfg, lp["mlp"], apply_norm(cfg, lp["ln2"], x))
    return x, new_cache


def _moe_body(cfg, lp, x, pos, cache=None):
    a, new_cache = attn_block(cfg, lp["attn"], apply_norm(cfg, lp["ln1"], x),
                              pos, causal=True, cache=cache)
    x = x + a
    y, aux = moe_block(cfg, lp["moe"], apply_norm(cfg, lp["ln2"], x))
    return x + y, aux, new_cache


def _rwkv_body(cfg, lp, x, state=None):
    y, st_tm = ssm.rwkv6_timemix(cfg, lp["tm"],
                                 apply_norm(cfg, lp["ln1"], x),
                                 state=state)
    x = x + y
    y, st_cm = ssm.rwkv6_channelmix(cfg, lp["tm"],
                                    apply_norm(cfg, lp["ln2"], x),
                                    state=state)
    x = x + y
    return x, {**st_tm, **st_cm}


# ---------------------------------------------------------------------------
# forward (training / prefill without cache)
# ---------------------------------------------------------------------------
def embed_inputs(cfg, params, batch):
    cdt = jnp.dtype(cfg.compute_dtype)
    tokens = batch["tokens"]
    x = params["embed"][tokens].astype(cdt)
    if cfg.frontend == "patches":
        pe = batch["patches"].astype(cdt) @ params["patch_proj"].astype(cdt)
        B = x.shape[0]
        npatch = pe.shape[1]
        x = jnp.concatenate([pe, x[:, npatch:]], axis=1)
    return x


def _sinusoid(S, d, dtype):
    pos = np.arange(S)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * i / d)
    pe = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(pe, dtype)


def encode(cfg, params, frames):
    """Whisper encoder on stub frame embeddings [B, S_enc, frontend_dim]."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = frames.astype(cdt) @ params["frame_proj"].astype(cdt)
    x = x + _sinusoid(x.shape[1], cfg.d_model, cdt)[None]
    pos = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

    def body(x, lp):
        x, _ = _dense_body(cfg, lp, x, pos, causal=False)
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return apply_norm(cfg, params["enc_norm"], x)


def _xattn_body(cfg, lp, x, enc_kv):
    """Cross-attention: q from x, k/v precomputed from encoder output."""
    B, S, _ = x.shape
    hd, H = cfg.head_dim, cfg.n_heads
    h = apply_norm(cfg, lp["ln"], x)
    q = (h @ lp["wq"].astype(x.dtype)).reshape(B, S, H, hd)
    o = flash_attention(q, enc_kv[0], enc_kv[1], causal=False,
                        q_block=cfg.attn_q_block,
                        kv_block=cfg.attn_kv_block)
    return x + o.reshape(B, S, -1) @ lp["wo"].astype(x.dtype)


def cross_kv(cfg, xp, enc_out):
    """Precompute per-layer cross k/v: xp is the stacked xattn params."""
    B, S, _ = enc_out.shape
    hd, Hkv = cfg.head_dim, cfg.n_kv_heads

    def one(lp):
        k = (enc_out @ lp["wk"].astype(enc_out.dtype)).reshape(
            B, S, Hkv, hd)
        v = (enc_out @ lp["wv"].astype(enc_out.dtype)).reshape(
            B, S, Hkv, hd)
        return k, v

    return jax.vmap(one)(xp)  # [L, B, S, Hkv, hd] x2


def forward(cfg: ArchConfig, params, batch):
    """Full forward -> final hidden states [B, S, d] (pre final-norm).

    batch: tokens [B,S] (+ patches/frames for vlm/audio) — training path
    (no cache).  Returns (h, aux_loss).
    """
    cdt = jnp.dtype(cfg.compute_dtype)
    x = embed_inputs(cfg, params, batch)
    B, S, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    aux = jnp.float32(0)

    if cfg.enc_dec:
        enc_out = encode(cfg, params, batch["frames"])
        xkv = cross_kv(cfg, params["xattn"], enc_out)
        x = x + _sinusoid(S, cfg.d_model, cdt)[None]

        def body(x, lps):
            lp, xp, kv = lps
            x, _ = _dense_body(cfg, lp, x, pos, causal=True)
            x = _xattn_body(cfg, xp, x, kv)
            return x, None

        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, (params["layers"], params["xattn"],
                                      xkv))
        return apply_norm(cfg, params["final_norm"], x), aux

    if cfg.rwkv:
        def body(x, lp):
            x, _ = _rwkv_body(cfg, lp, x)
            return x, None
        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["layers"])
        return apply_norm(cfg, params["final_norm"], x), aux

    if cfg.family == "hybrid":
        shared = params["shared"]
        every = cfg.attn_every

        def body(carry, inp):
            x, i = carry
            lp = inp
            x = x + ssm.mamba2_block(cfg, lp["mamba"],
                                     apply_norm(cfg, lp["ln"], x))

            def with_attn(x):
                y, _ = _dense_body(cfg, shared, x, pos, causal=True)
                return y

            x = jax.lax.cond((i + 1) % every == 0, with_attn,
                             lambda x: x, x)
            return (x, i + 1), None

        if cfg.remat:
            body = jax.checkpoint(body)
        (x, _), _ = jax.lax.scan(body, (x, jnp.int32(0)), params["layers"])
        return apply_norm(cfg, params["final_norm"], x), aux

    if cfg.n_experts:
        if cfg.n_dense_layers:
            def dbody(x, lp):
                x, _ = _dense_body(cfg, lp, x, pos)
                return x, None
            if cfg.remat:
                dbody = jax.checkpoint(dbody)
            x, _ = jax.lax.scan(dbody, x, params["dense_layers"])

        def body(carry, lp):
            x, aux = carry
            x, a, _ = _moe_body(cfg, lp, x, pos)
            return (x, aux + a), None

        if cfg.remat:
            body = jax.checkpoint(body)
        (x, aux), _ = jax.lax.scan(body, (x, aux), params["layers"])
        return apply_norm(cfg, params["final_norm"], x), aux

    def body(x, lp):
        x, _ = _dense_body(cfg, lp, x, pos)
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["layers"])
    return apply_norm(cfg, params["final_norm"], x), aux


# ---------------------------------------------------------------------------
# loss (chunked-vocab cross entropy)
# ---------------------------------------------------------------------------
def unembed(cfg, params, h):
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    return h @ w.astype(h.dtype)


def loss_fn(cfg: ArchConfig, params, batch):
    """Causal LM loss. labels: next-token ids, -1 = masked."""
    h, aux = forward(cfg, params, batch)
    labels = batch["labels"]
    B, S, d = h.shape
    ck = min(cfg.loss_chunk, S)
    assert S % ck == 0
    nch = S // ck

    def chunk(carry, inp):
        hs, ls = inp                       # [nc, B, ck, ...]
        logits = unembed(cfg, params, hs).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(ls, 0)[..., None], axis=-1)[..., 0]
        mask = (ls >= 0).astype(jnp.float32)
        nll = (lse - tgt) * mask
        return (carry[0] + nll.sum(), carry[1] + mask.sum()), None

    hs = h.reshape(B, nch, ck, d).swapaxes(0, 1)
    ls = labels.reshape(B, nch, ck).swapaxes(0, 1)
    (tot, cnt), _ = jax.lax.scan(chunk, (jnp.float32(0), jnp.float32(0)),
                                 (hs, ls))
    loss = tot / jnp.maximum(cnt, 1.0)
    if cfg.n_experts:
        loss = loss + 0.01 * aux
    return loss, {"nll": tot, "tokens": cnt, "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------
def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    """Decode-state pytree (preallocated)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    hd, Hkv = cfg.head_dim, cfg.n_kv_heads

    def kv(n):
        return {"k": jnp.zeros((n, batch, max_len, Hkv, hd), cdt),
                "v": jnp.zeros((n, batch, max_len, Hkv, hd), cdt),
                "len": jnp.int32(0)}

    if cfg.rwkv:
        d, H, P = ssm.rwkv6_dims(cfg)
        return {"S": jnp.zeros((cfg.n_layers, batch, H, P, P), jnp.float32),
                "x_tm": jnp.zeros((cfg.n_layers, batch, 1, d), cdt),
                "x_cm": jnp.zeros((cfg.n_layers, batch, 1, d), cdt)}
    if cfg.family == "hybrid":
        d_in, H, N, conv_dim = ssm.mamba2_dims(cfg)
        n_sites = cfg.n_layers // cfg.attn_every
        return {
            "h": jnp.zeros((cfg.n_layers, batch, H, cfg.ssm_head_dim, N),
                           jnp.float32),
            "conv": jnp.zeros((cfg.n_layers, batch, ssm.CONV_K - 1,
                               conv_dim), cdt),
            "attn": kv(n_sites),
        }
    if cfg.enc_dec:
        return {"self": kv(cfg.n_layers), "cross": None}  # set at prefill
    return kv(cfg.n_layers)


def _sinusoid_at(pos, d, dtype):
    """Sinusoidal embedding at a dynamic scalar position -> [d]."""
    i = jnp.arange(d // 2, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) / jnp.power(10000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)]).astype(dtype)


def prefill(cfg: ArchConfig, params, batch, max_len: int):
    """Process the prompt, return (last-token logits [B,V], cache)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    tokens = batch["tokens"]
    B, S = tokens.shape
    cache = init_cache(cfg, B, max_len)
    x = embed_inputs(cfg, params, batch)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    if cfg.enc_dec:
        enc_out = encode(cfg, params, batch["frames"])
        xkv = cross_kv(cfg, params["xattn"], enc_out)
        x = x + _sinusoid(S, cfg.d_model, cdt)[None]

        def body(x, lps):
            lp, xp, kv, ck, cv = lps
            c = KVCache(ck, cv, jnp.int32(0))
            h = apply_norm(cfg, lp["ln1"], x)
            a, nc = attn_block(cfg, lp["attn"], h, pos, causal=True,
                               cache=c)
            x = x + a
            x = _xattn_body(cfg, xp, x, kv)
            x = x + mlp_block(cfg, lp["mlp"], apply_norm(cfg, lp["ln2"], x))
            return x, (nc.k, nc.v)

        x, (cks, cvs) = jax.lax.scan(
            body, x, (params["layers"], params["xattn"], xkv,
                      cache["self"]["k"], cache["self"]["v"]))
        cache = {"self": {"k": cks, "v": cvs, "len": jnp.int32(S)},
                 "cross": xkv}
        h = apply_norm(cfg, params["final_norm"], x[:, -1:])
        return unembed(cfg, params, h)[:, 0].astype(jnp.float32), cache

    if cfg.rwkv:
        def body2(x, lp):
            y, st_tm = ssm.rwkv6_timemix(cfg, lp["tm"],
                                         apply_norm(cfg, lp["ln1"], x))
            x = x + y
            y, st_cm = ssm.rwkv6_channelmix(cfg, lp["tm"],
                                            apply_norm(cfg, lp["ln2"], x))
            x = x + y
            return x, {**st_tm, **st_cm}
        x, sts = jax.lax.scan(body2, x, params["layers"])
        cache = {"S": sts["S"], "x_tm": sts["x_tm"], "x_cm": sts["x_cm"]}
        h = apply_norm(cfg, params["final_norm"], x[:, -1:])
        return unembed(cfg, params, h)[:, 0].astype(jnp.float32), cache

    if cfg.family == "hybrid":
        shared = params["shared"]
        every = cfg.attn_every
        n_sites = cfg.n_layers // every
        Lg = n_sites * every          # layers covered by full groups
        ck0, cv0 = cache["attn"]["k"], cache["attn"]["v"]

        def mamba_body(x, lp):
            y, st = _mamba_prefill(cfg, lp["mamba"],
                                   apply_norm(cfg, lp["ln"], x))
            return x + y, (st["h"], st["conv"])

        def group_body(x, xs):
            glp, ck, cv = xs
            x, (hs, cs) = jax.lax.scan(mamba_body, x, glp)
            c = KVCache(ck, cv, jnp.int32(0))
            h2 = apply_norm(cfg, shared["ln1"], x)
            a, nc = attn_block(cfg, shared["attn"], h2, pos,
                               causal=True, cache=c)
            x = x + a
            x = x + mlp_block(cfg, shared["mlp"],
                              apply_norm(cfg, shared["ln2"], x))
            return x, (hs, cs, nc.k, nc.v)

        grouped = jax.tree.map(
            lambda t: t[:Lg].reshape(n_sites, every, *t.shape[1:]),
            params["layers"])
        x, (hs, cs, cks, cvs) = jax.lax.scan(
            group_body, x, (grouped, ck0, cv0))
        hs = hs.reshape(Lg, *hs.shape[2:])
        cs = cs.reshape(Lg, *cs.shape[2:])
        if Lg < cfg.n_layers:      # trailing mamba layers (no attn site)
            rest = jax.tree.map(lambda t: t[Lg:], params["layers"])
            x, (hs2, cs2) = jax.lax.scan(mamba_body, x, rest)
            hs = jnp.concatenate([hs, hs2])
            cs = jnp.concatenate([cs, cs2])
        cache = {"h": hs, "conv": cs,
                 "attn": {"k": cks, "v": cvs, "len": jnp.int32(S)}}
        h = apply_norm(cfg, params["final_norm"], x[:, -1:])
        return unembed(cfg, params, h)[:, 0].astype(jnp.float32), cache

    # dense / moe / vlm
    def dense_prefill_body(x, lps):
        lp, ck, cv = lps
        c = KVCache(ck, cv, jnp.int32(0))
        if "moe" in lp:
            x, _, nc = _moe_body(cfg, lp, x, pos, cache=c)
        else:
            x, nc = _dense_body(cfg, lp, x, pos, cache=c)
        return x, (nc.k, nc.v)

    stacks = []
    if cfg.n_experts and cfg.n_dense_layers:
        nd = cfg.n_dense_layers
        x, (k1, v1) = jax.lax.scan(
            dense_prefill_body, x,
            (params["dense_layers"], cache["k"][:nd], cache["v"][:nd]))
        x, (k2, v2) = jax.lax.scan(
            dense_prefill_body, x,
            (params["layers"], cache["k"][nd:], cache["v"][nd:]))
        ck = jnp.concatenate([k1, k2])
        cv = jnp.concatenate([v1, v2])
    else:
        x, (ck, cv) = jax.lax.scan(
            dense_prefill_body, x, (params["layers"], cache["k"],
                                    cache["v"]))
    cache = {"k": ck, "v": cv, "len": jnp.int32(S)}
    h = apply_norm(cfg, params["final_norm"], x[:, -1:])
    return unembed(cfg, params, h)[:, 0].astype(jnp.float32), cache


def _mamba_prefill(cfg, p, x):
    """mamba2_block + final recurrent state (for decode continuation)."""
    # state after prefill = run block, then recompute final h via a cheap
    # full-sequence pass of the recurrence on the last chunk. For
    # simplicity we run the step-scan on the final CONV_K-1 tokens for the
    # conv state and take h from a chunked pass that also returns it.
    y = ssm.mamba2_block(cfg, p, x)
    B, S, d = x.shape
    d_in, H, N, conv_dim = ssm.mamba2_dims(cfg)
    # conv state: last K-1 pre-conv channels
    z, xc, Bm, Cm, dt = ssm._mamba_project(cfg, p, x)
    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)
    conv_state = conv_in[:, -(ssm.CONV_K - 1):]
    # final h: rerun the chunked recurrence, keeping only the carry
    h = _mamba_final_state(cfg, p, x)
    return y, {"h": h, "conv": conv_state}


def _mamba_final_state(cfg, p, x, chunk: int = 256):
    B, S, d = x.shape
    d_in, H, N, _ = ssm.mamba2_dims(cfg)
    P = cfg.ssm_head_dim
    z, xc, Bm, Cm, dt = ssm._mamba_project(cfg, p, x)
    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)
    conv_out, _ = ssm._causal_conv(conv_in, p["conv_w"], p["conv_b"])
    conv_out = jax.nn.silu(conv_out)
    xc, Bm, Cm = jnp.split(conv_out, [d_in, d_in + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))
    loga = -jnp.exp(p["A_log"].astype(jnp.float32)) * dt
    xdt = xc.reshape(B, S, H, P).astype(jnp.float32) * dt[..., None]
    Q = min(chunk, S)
    nc = S // Q

    def step(h, inp):
        xdt_c, b_c, la_c = inp
        l = jnp.cumsum(la_c, axis=1)
        decay_out = jnp.exp(l[:, -1:, :] - l)
        h = h * jnp.exp(l[:, -1])[..., None, None] + jnp.einsum(
            "bsh,bshp,bsn->bhpn", decay_out, xdt_c,
            b_c.astype(jnp.float32))
        return h, None

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    h, _ = jax.lax.scan(step, h0, (
        xdt.reshape(B, nc, Q, H, P).swapaxes(0, 1),
        Bm.reshape(B, nc, Q, N).swapaxes(0, 1),
        loga.reshape(B, nc, Q, H).swapaxes(0, 1)))
    return h


def decode_step(cfg: ArchConfig, params, tokens, cache):
    """One decode step. tokens: [B, 1] -> (logits [B, V], new cache)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = params["embed"][tokens].astype(cdt)
    B = x.shape[0]

    if cfg.rwkv:
        def body(x, lps):
            lp, S0, xtm, xcm = lps
            y, st_tm = ssm.rwkv6_timemix(
                cfg, lp["tm"], apply_norm(cfg, lp["ln1"], x),
                state={"S": S0, "x_tm": xtm})
            x = x + y
            y, st_cm = ssm.rwkv6_channelmix(
                cfg, lp["tm"], apply_norm(cfg, lp["ln2"], x),
                state={"x_cm": xcm})
            x = x + y
            return x, (st_tm["S"], st_tm["x_tm"], st_cm["x_cm"])
        x, (S1, xtm1, xcm1) = jax.lax.scan(
            body, x, (params["layers"], cache["S"], cache["x_tm"],
                      cache["x_cm"]))
        new_cache = {"S": S1, "x_tm": xtm1, "x_cm": xcm1}
        h = apply_norm(cfg, params["final_norm"], x)
        return unembed(cfg, params, h)[:, 0].astype(jnp.float32), new_cache

    if cfg.enc_dec:
        pos = jnp.full((B, 1), cache["self"]["len"], jnp.int32)
        x = x + _sinusoid_at(cache["self"]["len"], cfg.d_model,
                             cdt)[None, None]

        def body(x, lps):
            lp, xp, (kx, vx), ck, cv = lps
            c = KVCache(ck, cv, cache["self"]["len"])
            h = apply_norm(cfg, lp["ln1"], x)
            a, nc = attn_block(cfg, lp["attn"], h, pos, causal=True,
                               cache=c)
            x = x + a
            x = _xattn_body(cfg, xp, x, (kx, vx))
            x = x + mlp_block(cfg, lp["mlp"],
                              apply_norm(cfg, lp["ln2"], x))
            return x, (nc.k, nc.v)
        x, (ck, cv) = jax.lax.scan(
            body, x, (params["layers"], params["xattn"], cache["cross"],
                      cache["self"]["k"], cache["self"]["v"]))
        new_cache = {"self": {"k": ck, "v": cv,
                              "len": cache["self"]["len"] + 1},
                     "cross": cache["cross"]}
        h = apply_norm(cfg, params["final_norm"], x)
        return unembed(cfg, params, h)[:, 0].astype(jnp.float32), new_cache

    if cfg.family == "hybrid":
        shared = params["shared"]
        every = cfg.attn_every
        n_sites = cfg.n_layers // every
        Lg = n_sites * every
        ln = cache["attn"]["len"]
        pos = jnp.full((B, 1), ln, jnp.int32)

        def mamba_body(x, xs):
            lp, h0, c0 = xs
            y, st = ssm.mamba2_step(cfg, lp["mamba"],
                                    apply_norm(cfg, lp["ln"], x),
                                    {"h": h0, "conv": c0})
            return x + y, (st["h"], st["conv"])

        def group_body(x, xs):
            glp, gh, gc, ck, cv = xs
            x, (hs, cs) = jax.lax.scan(mamba_body, x, (glp, gh, gc))
            c = KVCache(ck, cv, ln)
            h2 = apply_norm(cfg, shared["ln1"], x)
            a, nc = attn_block(cfg, shared["attn"], h2, pos,
                               causal=True, cache=c)
            x = x + a
            x = x + mlp_block(cfg, shared["mlp"],
                              apply_norm(cfg, shared["ln2"], x))
            return x, (hs, cs, nc.k, nc.v)

        grouped = jax.tree.map(
            lambda t: t[:Lg].reshape(n_sites, every, *t.shape[1:]),
            params["layers"])
        gh = cache["h"][:Lg].reshape(n_sites, every, *cache["h"].shape[1:])
        gc = cache["conv"][:Lg].reshape(n_sites, every,
                                        *cache["conv"].shape[1:])
        x, (hs, cs, cks, cvs) = jax.lax.scan(
            group_body, x, (grouped, gh, gc, cache["attn"]["k"],
                            cache["attn"]["v"]))
        hs = hs.reshape(Lg, *hs.shape[2:])
        cs = cs.reshape(Lg, *cs.shape[2:])
        if Lg < cfg.n_layers:
            rest = jax.tree.map(lambda t: t[Lg:], params["layers"])
            x, (hs2, cs2) = jax.lax.scan(
                mamba_body, x, (rest, cache["h"][Lg:], cache["conv"][Lg:]))
            hs = jnp.concatenate([hs, hs2])
            cs = jnp.concatenate([cs, cs2])
        new_cache = {"h": hs, "conv": cs,
                     "attn": {"k": cks, "v": cvs, "len": ln + 1}}
        h = apply_norm(cfg, params["final_norm"], x)
        return unembed(cfg, params, h)[:, 0].astype(jnp.float32), new_cache

    # dense / moe / vlm
    ln = cache["len"]
    pos = jnp.full((B, 1), ln, jnp.int32)

    def body(x, lps):
        lp, ck, cv = lps
        c = KVCache(ck, cv, ln)
        if "moe" in lp:
            x, _, nc = _moe_body(cfg, lp, x, pos, cache=c)
        else:
            x, nc = _dense_body(cfg, lp, x, pos, cache=c)
        return x, (nc.k, nc.v)

    if cfg.n_experts and cfg.n_dense_layers:
        nd = cfg.n_dense_layers
        x, (k1, v1) = jax.lax.scan(body, x, (
            params["dense_layers"], cache["k"][:nd], cache["v"][:nd]))
        x, (k2, v2) = jax.lax.scan(body, x, (
            params["layers"], cache["k"][nd:], cache["v"][nd:]))
        ck, cv = jnp.concatenate([k1, k2]), jnp.concatenate([v1, v2])
    else:
        x, (ck, cv) = jax.lax.scan(body, x, (params["layers"], cache["k"],
                                             cache["v"]))
    new_cache = {"k": ck, "v": cv, "len": ln + 1}
    h = apply_norm(cfg, params["final_norm"], x)
    return unembed(cfg, params, h)[:, 0].astype(jnp.float32), new_cache
