"""Sub-quadratic sequence mixers: Mamba2 (SSD) and RWKV6 ("Finch").

Both are *chunked* scans: intra-chunk work is a masked matmul against a
decay matrix whose exponents are differences of cumulative log-decays and
therefore always <= 0 (no overflow by construction); inter-chunk state is
carried by a ``lax.scan`` over chunks.  This is the TPU-native adaptation
of the recurrence — per-token scans would serialize the MXU and make the
backward pass store O(seq) states.

Recurrent decode (`*_step`) updates O(1) state per token — this is what
makes ``long_500k`` runnable for zamba2/rwkv6 while pure-attention archs
skip it.

Mamba2 here follows the SSD scalar-decay-per-head form (A is scalar per
head), single B/C group.  RWKV6 has data-dependent *per-channel* decay via
the low-rank ("lora") path of the paper arXiv:2404.05892.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import rmsnorm


# ---------------------------------------------------------------------------
# Mamba2
# ---------------------------------------------------------------------------
CONV_K = 4


def mamba2_dims(cfg):
    d_in = 2 * cfg.d_model
    H = d_in // cfg.ssm_head_dim
    N = cfg.ssm_state
    conv_dim = d_in + 2 * N
    return d_in, H, N, conv_dim


def init_mamba2(cfg, key):
    d = cfg.d_model
    d_in, H, N, conv_dim = mamba2_dims(cfg)
    pdt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    return {
        "in_proj": (jax.random.normal(ks[0], (d, 2 * d_in + 2 * N + H)) *
                    d**-0.5).astype(pdt),
        "conv_w": (jax.random.normal(ks[1], (conv_dim, CONV_K)) *
                   CONV_K**-0.5).astype(pdt),
        "conv_b": jnp.zeros((conv_dim,), pdt),
        "A_log": jnp.zeros((H,), pdt),          # A = -exp(A_log) = -1
        "D": jnp.ones((H,), pdt),
        "dt_bias": jnp.zeros((H,), pdt),
        "norm_w": jnp.ones((d_in,), pdt),
        "out_proj": (jax.random.normal(ks[3], (d_in, d)) *
                     d_in**-0.5).astype(pdt),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv, kernel CONV_K. x: [B,S,C]; state: [B,K-1,C]."""
    B, S, C = x.shape
    if state is None:
        pad = jnp.zeros((B, CONV_K - 1, C), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)           # [B, S+K-1, C]
    out = sum(xp[:, i:i + S] * w[:, i].astype(x.dtype)
              for i in range(CONV_K))
    new_state = xp[:, -(CONV_K - 1):]
    return out + b.astype(x.dtype), new_state


def _ssm_constrain(cfg, t, spec_tail):
    """ssm_partition="tokens": pin batch->data, heads/channels->model.
    Without this the SPMD solver replicates the (large) mamba
    intermediates over the data axis — see EXPERIMENTS.md §Perf H2."""
    if getattr(cfg, "ssm_partition", "auto") != "tokens" or \
            not cfg.mesh_axes:
        return t
    from jax.sharding import PartitionSpec as P
    data = tuple(a for a in cfg.mesh_axes if a != "model")
    d_ax = data if len(data) > 1 else data[0]
    return jax.lax.with_sharding_constraint(t, P(d_ax, *spec_tail))


def _mamba_project(cfg, p, x):
    d_in, H, N, _ = mamba2_dims(cfg)
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xc, Bm, Cm, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N], axis=-1)
    # batch -> data everywhere; wide channel dims -> model; the tiny
    # B/C state channels (N) replicate over model
    z = _ssm_constrain(cfg, z, (None, "model"))
    xc = _ssm_constrain(cfg, xc, (None, "model"))
    Bm = _ssm_constrain(cfg, Bm, (None, None))
    Cm = _ssm_constrain(cfg, Cm, (None, None))
    dt = _ssm_constrain(cfg, dt, (None, "model"))
    return z, xc, Bm, Cm, dt


def mamba2_block(cfg, p, x, chunk: int | None = None):
    """Training/prefill forward. x: [B,S,d] -> y [B,S,d]."""
    chunk = chunk or getattr(cfg, "ssm_chunk", 256)
    B, S, d = x.shape
    d_in, H, N, conv_dim = mamba2_dims(cfg)
    P = cfg.ssm_head_dim
    z, xc, Bm, Cm, dt = _mamba_project(cfg, p, x)
    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)
    conv_out, _ = _causal_conv(conv_in, p["conv_w"], p["conv_b"])
    conv_out = jax.nn.silu(conv_out)
    xc, Bm, Cm = jnp.split(conv_out, [d_in, d_in + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))   # [B,S,H]
    loga = -jnp.exp(p["A_log"].astype(jnp.float32)) * dt     # [B,S,H] <= 0
    xh = xc.reshape(B, S, H, P).astype(jnp.float32)
    xh = _ssm_constrain(cfg, xh, (None, "model", None))
    xdt = xh * dt[..., None]
    Bm32, Cm32 = Bm.astype(jnp.float32), Cm.astype(jnp.float32)

    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    def chunk_step(h, inp):
        xdt_c, b_c, c_c, la_c = inp      # [B,Q,H,P], [B,Q,N], ..., [B,Q,H]
        l = jnp.cumsum(la_c, axis=1)                       # [B,Q,H]
        # intra: L[t,s] = exp(l_t - l_s + la_s?)  -- define h_t = a_t h_{t-1}
        # + B_t xdt_t, y_t = C_t h_t: token s contributes decay
        # prod_{j=s+1..t} a_j = exp(l_t - l_s)
        Lmat = jnp.exp(l[:, :, None, :] - l[:, None, :, :])   # [B,Q,Q,H]
        mask = jnp.tril(jnp.ones((Q, Q), bool))
        Lmat = jnp.where(mask[None, :, :, None], Lmat, 0.0)
        cb = jnp.einsum("bqn,bsn->bqs", c_c, b_c)
        y = jnp.einsum("bqs,bqsh,bshp->bqhp", cb, Lmat, xdt_c)
        # inter: contribution of carried state
        y = y + jnp.einsum("bqn,bhpn->bqhp", c_c, h) * \
            jnp.exp(l)[..., None]
        # state update
        decay_out = jnp.exp(l[:, -1:, :] - l)              # [B,Q,H]
        h_new = h * jnp.exp(l[:, -1])[..., None, None] + \
            jnp.einsum("bsh,bshp,bsn->bhpn", decay_out, xdt_c, b_c)
        return h_new, y

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    xdt_c = xdt.reshape(B, nc, Q, H, P).swapaxes(0, 1)
    b_c = Bm32.reshape(B, nc, Q, N).swapaxes(0, 1)
    c_c = Cm32.reshape(B, nc, Q, N).swapaxes(0, 1)
    la_c = loga.reshape(B, nc, Q, H).swapaxes(0, 1)
    _, ys = jax.lax.scan(chunk_step, h0, (xdt_c, b_c, c_c, la_c))
    y = ys.swapaxes(0, 1).reshape(B, S, H, P)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xh
    y = y.reshape(B, S, d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_w"])
    return y @ p["out_proj"].astype(x.dtype)


def mamba2_init_state(cfg, batch, dtype=jnp.float32):
    d_in, H, N, conv_dim = mamba2_dims(cfg)
    return {"h": jnp.zeros((batch, H, cfg.ssm_head_dim, N), jnp.float32),
            "conv": jnp.zeros((batch, CONV_K - 1, conv_dim), dtype)}


def mamba2_step(cfg, p, x, state):
    """Single-token decode. x: [B,1,d] -> (y [B,1,d], new state)."""
    B, S, d = x.shape
    assert S == 1
    d_in, H, N, _ = mamba2_dims(cfg)
    P = cfg.ssm_head_dim
    z, xc, Bm, Cm, dt = _mamba_project(cfg, p, x)
    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)
    conv_out, conv_state = _causal_conv(conv_in, p["conv_w"], p["conv_b"],
                                        state["conv"])
    conv_out = jax.nn.silu(conv_out)
    xc, Bm, Cm = jnp.split(conv_out, [d_in, d_in + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))[:, 0]   # [B,H]
    a = jnp.exp(-jnp.exp(p["A_log"].astype(jnp.float32)) * dt)     # [B,H]
    xh = xc.reshape(B, H, P).astype(jnp.float32)
    h = state["h"] * a[..., None, None] + jnp.einsum(
        "bhp,bn,bh->bhpn", xh, Bm[:, 0].astype(jnp.float32), dt)
    y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), h)
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(B, 1, d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_w"])
    return y @ p["out_proj"].astype(x.dtype), \
        {"h": h, "conv": conv_state}


# ---------------------------------------------------------------------------
# RWKV6
# ---------------------------------------------------------------------------
LORA_R = 64


def rwkv6_dims(cfg):
    d = cfg.d_model
    P = cfg.ssm_head_dim
    H = d // P
    return d, H, P


def init_rwkv6(cfg, key):
    d, H, P = rwkv6_dims(cfg)
    pdt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 10)
    std = d**-0.5
    return {
        # time-mix token-shift lerp coefficients for r,k,v,g,w
        "mu": (jax.random.uniform(ks[0], (5, d)) * 0.5).astype(pdt),
        "Wr": (jax.random.normal(ks[1], (d, d)) * std).astype(pdt),
        "Wk": (jax.random.normal(ks[2], (d, d)) * std).astype(pdt),
        "Wv": (jax.random.normal(ks[3], (d, d)) * std).astype(pdt),
        "Wg": (jax.random.normal(ks[4], (d, d)) * std).astype(pdt),
        "Wo": (jax.random.normal(ks[5], (d, d)) * std).astype(pdt),
        "w0": jnp.full((d,), -2.0, pdt),
        "wA": (jax.random.normal(ks[6], (d, LORA_R)) * std).astype(pdt),
        "wB": (jax.random.normal(ks[7], (LORA_R, d)) *
               LORA_R**-0.5).astype(pdt),
        "u": (jax.random.normal(ks[8], (H, P)) * 0.1).astype(pdt),
        "ln_w": jnp.ones((d,), pdt),   # per-head groupnorm approximated
        # channel-mix
        "mu_cm": (jax.random.uniform(ks[9], (2, d)) * 0.5).astype(pdt),
        "Wk_cm": (jax.random.normal(ks[0], (d, cfg.d_ff)) * std).astype(pdt),
        "Wv_cm": (jax.random.normal(ks[1], (cfg.d_ff, d)) *
                  cfg.d_ff**-0.5).astype(pdt),
        "Wr_cm": (jax.random.normal(ks[2], (d, d)) * std).astype(pdt),
    }


def _shift(x, last=None):
    """Token shift: x_{t-1} (zeros / carried last token at t=0)."""
    B, S, d = x.shape
    first = jnp.zeros((B, 1, d), x.dtype) if last is None else \
        last.astype(x.dtype)
    return jnp.concatenate([first, x[:, :-1]], axis=1) if S > 1 else first


def _rwkv_proj(cfg, p, x, xs):
    d, H, P = rwkv6_dims(cfg)
    B, S, _ = x.shape
    mu = p["mu"].astype(x.dtype)
    mix = [x + mu[i] * (xs - x) for i in range(5)]
    r = (mix[0] @ p["Wr"].astype(x.dtype)).reshape(B, S, H, P)
    k = (mix[1] @ p["Wk"].astype(x.dtype)).reshape(B, S, H, P)
    v = (mix[2] @ p["Wv"].astype(x.dtype)).reshape(B, S, H, P)
    g = jax.nn.silu(mix[3] @ p["Wg"].astype(x.dtype))
    ww = p["w0"].astype(jnp.float32) + \
        (jnp.tanh(mix[4].astype(jnp.float32) @ p["wA"].astype(jnp.float32))
         @ p["wB"].astype(jnp.float32))
    logw = -jnp.exp(ww).reshape(B, S, H, P)    # <= 0, data-dependent decay
    return r, k, v, g, logw


def rwkv6_timemix(cfg, p, x, state=None, chunk: int = 32):
    """x: [B,S,d] -> (y, new_state). state: {"S": [B,H,P,P], "x_tm": ...}"""
    d, H, P = rwkv6_dims(cfg)
    B, S, _ = x.shape
    xs = _shift(x, None if state is None else state.get("x_tm"))
    r, k, v, g, logw = _rwkv_proj(cfg, p, x, xs)
    r32, k32, v32 = (t.astype(jnp.float32) for t in (r, k, v))
    u = p["u"].astype(jnp.float32)

    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q

    def chunk_step(Sst, inp):
        r_c, k_c, v_c, lw_c = inp     # [B,Q,H,P] each
        dcum = jnp.cumsum(lw_c, axis=1)                  # [B,Q,H,P]
        dprev = dcum - lw_c                              # cumsum up to t-1
        # intra-chunk: score[t,s] = sum_p r_t k_s exp(dprev_t - dcum_s), s<t
        Ld = jnp.exp(dprev[:, :, None] - dcum[:, None])  # [B,Q,Q,H,P] <=0 ok
        mask = jnp.tril(jnp.ones((Q, Q), bool), k=-1)
        Ld = jnp.where(mask[None, :, :, None, None], Ld, 0.0)
        score = jnp.einsum("bqhp,bshp,bqshp->bqsh", r_c, k_c, Ld)
        y = jnp.einsum("bqsh,bshp->bqhp", score, v_c)
        # diagonal (current token) bonus term
        diag = jnp.einsum("bqhp,hp,bqhp->bqh", r_c, u, k_c)
        y = y + diag[..., None] * v_c
        # inter-chunk: carried state
        y = y + jnp.einsum("bqhp,bhpv->bqhv", r_c * jnp.exp(dprev), Sst)
        # state update: S' = exp(dlast) * S + sum_s exp(dlast - dcum_s) k v
        dlast = dcum[:, -1]                              # [B,H,P]
        Snew = Sst * jnp.exp(dlast)[..., None] + jnp.einsum(
            "bshp,bshv->bhpv", k_c * jnp.exp(dlast[:, None] - dcum), v_c)
        return Snew, y

    S0 = jnp.zeros((B, H, P, P), jnp.float32) if state is None \
        else state["S"]
    rc = r32.reshape(B, nc, Q, H, P).swapaxes(0, 1)
    kc = k32.reshape(B, nc, Q, H, P).swapaxes(0, 1)
    vc = v32.reshape(B, nc, Q, H, P).swapaxes(0, 1)
    lc = logw.reshape(B, nc, Q, H, P).swapaxes(0, 1)
    Sfin, ys = jax.lax.scan(chunk_step, S0, (rc, kc, vc, lc))
    y = ys.swapaxes(0, 1).reshape(B, S, d)
    # per-head "groupnorm" (rmsnorm over head dim), then gate + out proj
    y = y.reshape(B, S, H, P)
    y = y * jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + 1e-5)
    y = (y.reshape(B, S, d) * p["ln_w"].astype(jnp.float32))
    y = (y.astype(x.dtype) * g) @ p["Wo"].astype(x.dtype)
    new_state = {"S": Sfin, "x_tm": x[:, -1:]}
    return y, new_state


def rwkv6_channelmix(cfg, p, x, state=None):
    mu = p["mu_cm"].astype(x.dtype)
    xs = _shift(x, None if state is None else state.get("x_cm"))
    xk = x + mu[0] * (xs - x)
    xr = x + mu[1] * (xs - x)
    kk = jnp.square(jax.nn.relu(xk @ p["Wk_cm"].astype(x.dtype)))
    y = jax.nn.sigmoid(xr @ p["Wr_cm"].astype(x.dtype)) * \
        (kk @ p["Wv_cm"].astype(x.dtype))
    return y, {"x_cm": x[:, -1:]}
