"""Continuous-batching dataflow serving: per-slot stream lifecycle.

The paper's fabric serves one token stream; ``DataflowEngine.run_batch``
(PR 1) serves B streams as a *wave* — all admitted together, the
dispatch loop running until the slowest stream quiesces, so short
requests idle in their slots.  This module removes the wave barrier:

* a :class:`DataflowServer` owns a FIFO request queue and B live
  *slots* on one block-fused fabric (the engine's resumable slot API,
  DESIGN.md §7);
* after each K-cycle block it detects per-slot quiescence (idle block
  tail — idle is absorbing), harvests finished requests, and refills
  those slots from the queue *while the other slots keep running*;
* free/quiesced slots are clock-gated out of feed/fire/drain by the
  per-stream active mask in ``fire_block_batched_pallas`` (the
  "per-row cache clock" serve/engine.py flags as future work for the
  LM path).

This is the serving analogue of a circuit-switched reconfigurable
fabric multiplexing independent stream computations through shared
operators with per-stream flow control (Li et al., arXiv:1310.3356):
the node/arc tables are the shared operator array, a slot is a
circuit, and admission is reconfiguration-free because every request
of a graph signature reuses one compiled plan.

Determinism: admissions happen only at block boundaries and each slot
carries its own cycle clock, so every request's
:class:`~repro.core.engine.EngineResult` is bit-identical to running
it alone via ``DataflowEngine.run`` — regardless of what rides the
other slots or of admission order (property-tested in
tests/test_dataflow_server.py).

Traced programs (:mod:`repro.front`, DESIGN.md §9) serve through the
same machinery: a ``TracedProgram`` is a ``Graph``, so its assembler
emission is its cache signature like any hand-assembled fabric —
:meth:`DataflowServer.for_fn` traces and serves in one step.

Loop programs (DESIGN.md §10) are where per-slot lifecycle earns its
keep: a ``lax.while_loop``-bearing request has a *data-dependent trip
count*, so its residency is unknowable at admission.  Each request is
one loop initiation (:meth:`DataflowServer.submit_args`); the slot's
idle-tail detection IS the loop-termination signal (the exit BRANCH
drains the result and the cycle goes quiet), short loops harvest and
refill while long ones keep iterating, and a divergent loop is
force-harvested at its cycle cap with ``metrics.truncated`` set
instead of wedging its slot.

Fault tolerance (PR 6, DESIGN.md §11): the server is hardened for a
hostile multi-tenant environment, the setting Weisensee & Nathan's
self-reconfigurable platform targets (PAPERS.md, cs/0411075) — shared
reconfigurable hardware must survive misbehaving workloads:

* **bounded admission** — ``max_queue`` + ``policy`` ("reject" |
  "block" | "drop-oldest") with round-robin fairness across
  ``Request.tenant`` keys (:mod:`repro.serve.admission`);
* **deadlines and budgets** — ``Request.deadline_blocks`` expires a
  request (queued or resident) like truncation;
  ``Request.max_cycles`` overrides the engine cap per slot;
* **the stall watchdog** — a slot whose progress counters freeze for
  ``wedge_timeout_blocks`` without quiescing is force-harvested with
  ``metrics.wedged``;
* **error isolation and degradation** — dispatch failures retry with
  exponential backoff; persistent failures tear down only the failing
  backend: residents are re-queued (front of their tenant bucket) and
  restarted on the next backend of the ``pallas → xla → reference``
  chain, the terminal reference mode executing requests one-at-a-time
  on the host with per-request ``Result(error=...)`` capture.  The
  server *always* answers: ``step()``/``drain()`` never raise a
  workload-induced error (property-tested in
  tests/test_server_robustness.py under a seeded
  :class:`~repro.serve.faults.FaultPlan`), and a faulty slot is torn
  down without perturbing co-resident circuits — unfaulted requests
  stay bit-identical to solo runs (Li et al.'s per-circuit isolation).
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import logging
import time
from typing import Iterable, Mapping

import numpy as np

from repro.core import asm
from repro.core.engine import (BACKENDS, PLAN_CACHE_STATS, DataflowEngine,
                               run_reference)
from repro.core.partition import resolve_partition
from repro.core.graph import Graph
from repro.serve.admission import (POLICIES, DroppedError, FairQueue,
                                   QueueFullError, Rejected)
from repro.serve.types import (InvalidRequestError, Request,
                               RequestMetrics, Result)

log = logging.getLogger(__name__)

# ---------------------------------------------------------------------------
# Compiled-plan cache: many requests, one fabric
# ---------------------------------------------------------------------------
_ENGINE_CACHE: "collections.OrderedDict[tuple, DataflowEngine]" = \
    collections.OrderedDict()
_ENGINE_CACHE_MAX = 64      # LRU bound: a long-running service sees a
                            # finite fabric vocabulary; evicted engines
                            # stay alive wherever still referenced
CACHE_STATS = {"hits": 0, "misses": 0, "evictions": 0,
               # live view of the process-wide _plan memo (engine-level;
               # ROADMAP item 3): same dict object, not a snapshot
               "plan": PLAN_CACHE_STATS}


def graph_signature(graph: Graph) -> str:
    """Canonical text of a fabric (assembler emission: consts + node
    table with arc labels).  Two graphs with equal signatures compile
    to identical plans, so their requests can share one engine."""
    return asm.emit(graph)


def cached_engine(graph: Graph, *, backend: str = "xla",
                  block_cycles: int = 16,
                  max_cycles: int = 100_000,
                  token_shape: tuple = (), dtype=np.int32,
                  optimize: bool = False,
                  profile: bool = False,
                  schedule: bool | str = False,
                  partition=None) -> DataflowEngine:
    """Engine for (graph signature, backend, K, token_shape, dtype,
    optimize, profile, schedule, partition) — compiled once, shared by
    every server/request that presents the same fabric (the cache key
    hashes the signature, not the graph object, so structurally equal
    graphs share).

    token_shape/dtype/optimize/profile/schedule/partition are part of
    the key: two servers over the same fabric signature with different
    token shapes or opt flags compile to different plans and must not
    collide on one engine (a profiled engine threads §12 counter state
    through every step, so it cannot share dispatch plans with an
    unprofiled one; a scheduled engine replaces the block stepper
    entirely, so it cannot alias the dynamic engine for the same
    signature; a partitioned engine runs the §14 multi-fabric stepper
    whose state carries channel registers, so a sharded and an unsharded
    compile — or two different region assignments — must never alias).
    The partition key component is ``Partition.spec()``: region count +
    assignment hash."""
    if backend not in BACKENDS:
        raise ValueError(f"backend {backend!r} not in {BACKENDS}")
    token_shape = tuple(int(d) for d in token_shape)
    dtype = np.dtype(str(dtype)) if isinstance(dtype, str) \
        else np.dtype(dtype)
    part = resolve_partition(graph, partition)
    if part is not None and part.P <= 1:
        part = None            # degenerate: same engine as unsharded
    key = (hashlib.sha256(graph_signature(graph).encode()).hexdigest(),
           backend, int(block_cycles), int(max_cycles),
           token_shape, dtype.str, bool(optimize), bool(profile),
           str(schedule), "none" if part is None else part.spec())
    eng = _ENGINE_CACHE.get(key)
    if eng is None:
        CACHE_STATS["misses"] += 1
        eng = DataflowEngine(graph, token_shape, dtype,
                             backend=backend,
                             block_cycles=block_cycles,
                             max_cycles=max_cycles,
                             optimize=optimize,
                             profile=profile,
                             schedule=schedule,
                             partition=part)
        _ENGINE_CACHE[key] = eng
        while len(_ENGINE_CACHE) > _ENGINE_CACHE_MAX:
            _ENGINE_CACHE.popitem(last=False)
            CACHE_STATS["evictions"] += 1
    else:
        CACHE_STATS["hits"] += 1
        _ENGINE_CACHE.move_to_end(key)
    return eng


def clear_engine_cache() -> None:
    _ENGINE_CACHE.clear()
    CACHE_STATS["hits"] = CACHE_STATS["misses"] = 0
    CACHE_STATS["evictions"] = 0


# Degradation order: each backend's next-best survivor.  "reference" is
# terminal — the pure-host oracle has no device dispatch to fail, so a
# server can always still answer from there.
FALLBACK_CHAIN = ("pallas", "xla", "reference")


# ---------------------------------------------------------------------------
# The server
# ---------------------------------------------------------------------------
class DataflowServer:
    """Request-level continuous batching over one block-fused fabric.

    Usage::

        srv = DataflowServer(graph, slots=8, block_cycles=16,
                             backend="pallas",
                             max_queue=64, policy="reject")
        srv.submit(feeds_a)            # returns uid (or typed Rejected)
        srv.submit(Request(uid=7, feeds=feeds_b, deadline_blocks=50))
        done = srv.step()              # one K-cycle block; may finish 0+
        rest = srv.drain()             # run until queue + slots empty

    ``step()`` is the scheduler heartbeat: expire deadline-blown
    requests, force-harvest budget-exhausted and wedged slots, admit
    from the queue into free slots (round-robin across tenants),
    advance every active slot by one K-cycle block (one device
    dispatch, retried with exponential backoff on transient failures),
    harvest slots whose block had an idle tail.  A persistent dispatch
    or compile failure degrades the server down the
    ``pallas → xla → reference`` chain instead of raising — every
    submitted request receives exactly one :class:`Result` (value,
    truncated, expired, wedged, or typed error).
    """

    def __init__(self, graph: Graph, slots: int = 8,
                 block_cycles: int = 16, backend: str = "xla",
                 max_cycles: int = 100_000,
                 engine: DataflowEngine | None = None,
                 optimize: bool = False,
                 max_queue: int | None = None, policy: str = "reject",
                 wedge_timeout_blocks: int = 32,
                 max_retries: int = 3, retry_backoff_s: float = 0.0,
                 faults=None, profile: bool = False,
                 trace=None, metrics=None,
                 schedule: bool | str = False,
                 partition=None):
        if slots < 1:
            raise ValueError("slots must be >= 1")
        if policy not in POLICIES:
            raise ValueError(f"policy {policy!r} not in {POLICIES}")
        if max_queue is not None and max_queue < 1:
            raise ValueError("max_queue must be >= 1 (or None: unbounded)")
        if wedge_timeout_blocks < 1:
            raise ValueError("wedge_timeout_blocks must be >= 1")
        self.graph = graph
        self.slots = slots
        self.max_cycles = int(max_cycles)
        self.max_queue = max_queue
        self.policy = policy
        self.wedge_timeout_blocks = int(wedge_timeout_blocks)
        self.max_retries = int(max_retries)
        self.retry_backoff_s = float(retry_backoff_s)
        self.faults = faults
        # observability (DESIGN.md §12): profile=True compiles §12
        # fabric counters into every slot step, so each harvested
        # Result carries result.engine.profile (a FabricProfile);
        # trace/metrics accept a repro.obs TraceRecorder /
        # MetricsRegistry (or None: zero recording overhead).
        self.profile = bool(profile)
        self.trace = trace
        self.metrics = metrics
        self._gauged_tenants: set[str] = set()
        if faults is not None and trace is not None \
                and getattr(faults, "notify", None) is None:
            # injected faults land on the trace timeline next to the
            # lifecycle events they cause
            faults.notify = lambda kind, *key: self._trace(
                "fault", injected=kind, key=list(map(str, key)))
        self._block_cycles = int(block_cycles)
        self._optimize = bool(optimize)
        # schedule="auto" serves static firing schedules (DESIGN.md
        # §13) when the fabric is schedulable, dynamic otherwise; it
        # rides the cache key so scheduled and dynamic engines for the
        # same fabric signature never alias
        self._schedule = schedule
        # partition=P|"auto"|Partition serves the fabric sharded across
        # regions (DESIGN.md §14) — results stay bit-identical, so the
        # reference fallback simply ignores it
        self._partition = partition
        self._input_arcs = tuple(graph.input_arcs())
        self.queue = FairQueue()
        self.block = 0            # server block clock (dispatches issued)
        self.admission_rounds = 0  # fused reset dispatches issued
        self.max_queue_depth = 0   # high-water mark of the queue
        self.events: list[dict] = []   # degradations/retries/drops log
        self._queued_at: dict[int, int] = {}     # uid -> block at submit
        self._resident: dict[int, tuple[Request, int]] = {}  # slot -> (req, admitted)
        self._retries: dict[int, int] = {}       # uid -> dispatch retries
        self._wedge_traced: set[int] = set()     # first-wedge trace dedupe
        self._degraded_uids: set[int] = set()    # restarted by degradation
        self._done: list[Result] = []  # results finished out-of-band
        #                                (drops, blocking-submit pumps)
        self._auto_uid = 0
        self._reference = False
        self.engine: DataflowEngine | None = None
        self.state = None
        if engine is not None:
            # an explicit engine wins over backend/block_cycles/max_cycles
            # (block size is a perf knob, never a semantics one), but it
            # must serve THIS fabric — a mismatched plan would silently
            # produce another graph's results
            if graph_signature(engine.graph) != graph_signature(graph):
                raise ValueError(
                    "engine= was compiled for a different fabric "
                    f"({engine.graph.name!r}, not {graph.name!r})")
            self._primary_backend = engine.backend
            self.engine = engine
            self.max_cycles = engine.max_cycles
            self.profile = bool(engine.profile)  # the engine decides
        else:
            if backend not in BACKENDS:
                raise ValueError(f"backend {backend!r} not in {BACKENDS}")
            self._primary_backend = backend
            # construction-time fallback: a backend whose engine cannot
            # be built (fault-injected or real) degrades immediately —
            # the server comes up answering, just slower
            for be in self._chain_from(backend):
                if be == "reference":
                    self._enter_reference(None)
                    break
                try:
                    if self.faults is not None:
                        self.faults.check_compile(be)
                    # optimize=True shares the opcode-class-specialized
                    # plan (DESIGN.md §8) across every slot; it joins the
                    # cache key because specialized and dense plans
                    # compile differently
                    self.engine = cached_engine(
                        graph, backend=be, block_cycles=block_cycles,
                        max_cycles=max_cycles, optimize=optimize,
                        profile=self.profile, schedule=schedule,
                        partition=partition)
                    break
                except Exception as e:
                    self._log_event("compile-degrade", backend=be,
                                    error=repr(e))
        if self.engine is not None and not self._reference:
            self.state = self.engine.init_state(slots)

    # -- construction helpers -------------------------------------------
    def _chain_from(self, backend: str) -> tuple[str, ...]:
        if backend in FALLBACK_CHAIN:
            return FALLBACK_CHAIN[FALLBACK_CHAIN.index(backend):]
        return (backend, *FALLBACK_CHAIN)

    def _log_event(self, kind: str, **kw) -> None:
        ev = dict(kind=kind, block=self.block, **kw)
        self.events.append(ev)
        log.warning("dataflow-server %s: %s", kind, kw)

    # -- observability plumbing (no-ops when trace/metrics are None) ----
    def _trace(self, kind: str, *, uid=None, slot=None, tenant=None,
               status=None, block=None, **args) -> None:
        """Record one lifecycle event at the server's block clock (or an
        explicit ``block`` when the event's RequestMetrics timestamp
        differs, e.g. the reference path's finished_block)."""
        if self.trace is not None:
            self.trace.record(
                kind, block=self.block if block is None else block,
                uid=uid, slot=slot,
                tenant=None if tenant is None else str(tenant),
                status=status, **args)

    def _count(self, name: str, n: int = 1, **labels) -> None:
        if self.metrics is not None:
            self.metrics.counter(name, **labels).inc(n)

    def _update_queue_metrics(self) -> None:
        if self.metrics is None:
            return
        self.metrics.gauge("queue_depth").set(len(self.queue))
        depths = {str(t): d for t, d in self.queue.depths().items()}
        self._gauged_tenants |= set(depths)
        for t in self._gauged_tenants:
            self.metrics.gauge("queue_depth", tenant=t).set(
                depths.get(t, 0))

    def _observe_result(self, res: Result) -> Result:
        """Per-request terminal accounting — every Result passes
        through here exactly once, whichever path produced it."""
        if self.metrics is None:
            return res
        self._count("requests_finished", status=res.status)
        m = res.metrics
        if m is not None:
            self.metrics.histogram("queue_wait_blocks").observe(
                m.queue_wait_blocks)
            if m.residency_cycles:
                self.metrics.histogram("residency_cycles").observe(
                    m.residency_cycles)
            if m.backend:
                self._count("requests_served", backend=m.backend)
        return res

    @property
    def backend(self) -> str:
        """Backend currently serving (may differ from the requested one
        after degradation)."""
        return "reference" if self._reference else self.engine.backend

    @property
    def degraded(self) -> bool:
        return self.backend != self._primary_backend

    @classmethod
    def for_fn(cls, fn, *avals, const_args=None, name=None,
               **server_kw) -> "DataflowServer":
        """Serve a traced Python program: lower ``fn`` through the
        :mod:`repro.front` frontend and build the server on the
        synthesized fabric.  A traced program is just another asm
        signature to the compiled-plan cache, so structurally-equal
        traces (across servers, across processes re-tracing the same
        source) share one engine.  The program's positional feed
        adapter rides along as ``server.make_feeds``::

            srv = DataflowServer.for_fn(
                lambda x, y: jnp.where(x > y, x - y, y - x),
                np.int32, np.int32, slots=8, backend="pallas")
            srv.submit(srv.make_feeds([5, 1], [2, 9]))
        """
        from repro.front import trace
        prog = trace(fn, *avals, name=name, const_args=const_args)
        srv = cls(prog, **server_kw)
        srv.traced = prog
        srv.make_feeds = prog.make_feeds
        return srv

    def submit_args(self, *args) -> int:
        """Submit one *evaluation* of a traced program (``for_fn``
        servers): ``make_feeds(*args)`` + ``submit`` in one step.  This
        is the natural request shape for loop fabrics (DESIGN.md §10):
        one initiation per request, data-dependent trip count inside
        the slot, per-slot quiescence detection ending it — requests
        that never quiesce are force-harvested at their cycle cap with
        ``metrics.truncated`` set."""
        if not hasattr(self, "make_feeds"):
            raise AttributeError(
                "submit_args needs a server built by for_fn (only "
                "traced programs carry a positional feed adapter)")
        return self.submit(self.make_feeds(*args))

    # -- admission ------------------------------------------------------
    def submit(self, request):
        """Enqueue a request (a :class:`Request` or a bare feeds dict);
        returns its uid, or a typed :class:`Rejected` when the queue is
        at ``max_queue`` under ``policy="reject"``.  uids must be
        unique among in-flight requests — auto-assigned ones skip any
        the caller has taken."""
        if isinstance(request, Mapping) or request is None:
            while self._auto_uid + 1 in self._queued_at:
                self._auto_uid += 1
            self._auto_uid += 1
            request = Request(uid=self._auto_uid, feeds=dict(request or {}))
        if not isinstance(request, Request):
            raise TypeError(f"submit wants a Request or feeds dict, "
                            f"got {type(request).__name__}")
        # field validation (typed): a deadline or cycle budget below 1
        # could never run — deadline_blocks=0 would expire on the very
        # heartbeat that admits it, max_cycles=0 would truncate a slot
        # before its first cycle
        if request.deadline_blocks is not None \
                and request.deadline_blocks < 1:
            raise InvalidRequestError(
                f"request {request.uid}: deadline_blocks must be >= 1, "
                f"got {request.deadline_blocks}")
        if request.max_cycles is not None and request.max_cycles < 1:
            raise InvalidRequestError(
                f"request {request.uid}: max_cycles must be >= 1, "
                f"got {request.max_cycles}")
        if request.feeds is None:
            raise ValueError(f"request {request.uid} has no feeds — the "
                             "dataflow server serves feed-stream requests")
        if request.uid in self._queued_at:
            raise ValueError(f"uid {request.uid} is already in flight")
        # fail fast on feeds the fabric cannot take: admission batches
        # several requests into one fused reset, so a bad request must
        # be rejected here, not poison its fused reset batch.  Unknown
        # arcs have nowhere to go; MISSING arcs would strand the fabric
        # mid-computation waiting on tokens that never arrive (the slot
        # then burns its whole cycle budget before truncating).
        unknown = set(request.feeds) - set(self._input_arcs)
        if unknown:
            raise ValueError(f"request {request.uid}: feeds for "
                             f"non-input arcs: {sorted(unknown)}")
        missing = [a for a in self._input_arcs if a not in request.feeds]
        if missing:
            raise ValueError(
                f"request {request.uid}: missing feeds for input arcs "
                f"{missing} — every input arc needs a stream")
        # bounded admission (DESIGN.md §11)
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            if self.policy == "reject":
                self._trace("reject", uid=request.uid,
                            tenant=request.tenant,
                            queue_depth=len(self.queue))
                self._count("requests_rejected",
                            tenant=str(request.tenant))
                return Rejected(uid=request.uid,
                                reason=f"queue full ({self.max_queue})",
                                queue_depth=len(self.queue),
                                tenant=request.tenant)
            if self.policy == "drop-oldest":
                victim = self.queue.drop_oldest()
                queued = self._queued_at.pop(victim.uid)
                self._retries.pop(victim.uid, None)
                self._log_event("drop-oldest", uid=victim.uid,
                                tenant=victim.tenant)
                self._trace("drop", uid=victim.uid, tenant=victim.tenant,
                            status="error")
                self._count("requests_dropped", tenant=str(victim.tenant))
                self._done.append(self._observe_result(Result(
                    uid=victim.uid,
                    error=DroppedError(
                        f"request {victim.uid} dropped by admission "
                        f"(queue full at {self.max_queue}, "
                        f"policy=drop-oldest)"),
                    metrics=self._queue_only_metrics(queued))))
            else:       # "block": the submitting host pumps heartbeats
                guard = 0
                while len(self.queue) >= self.max_queue:
                    self._done.extend(self._step_inner())
                    guard += 1
                    if guard > 1_000_000:
                        raise QueueFullError(
                            "blocking submit pumped 1e6 heartbeats "
                            "without a queue slot freeing")
        if self.faults is not None and request.feeds:
            poisoned = self.faults.poison(request.feeds, request.uid,
                                          np.int32)
            if poisoned is not request.feeds:
                self._log_event("poison", uid=request.uid)
                self._trace("poison", uid=request.uid,
                            tenant=request.tenant)
                request = dataclasses.replace(request, feeds=poisoned)
        self.queue.push(request)
        self._queued_at[request.uid] = self.block
        self.max_queue_depth = max(self.max_queue_depth, len(self.queue))
        self._trace("submit", uid=request.uid, tenant=request.tenant,
                    queue_depth=len(self.queue))
        self._count("requests_submitted", tenant=str(request.tenant))
        self._update_queue_metrics()
        return request.uid

    def _queue_only_metrics(self, queued: int,
                            expired: bool = False) -> RequestMetrics:
        """Metrics for a request that never reached a slot (dropped or
        expired while queued): slot == -1, no residency."""
        return RequestMetrics(
            slot=-1, queued_block=queued, admitted_block=-1,
            finished_block=self.block,
            queue_wait_blocks=self.block - queued,
            residency_blocks=0, residency_cycles=0, tokens_out=0,
            expired=expired, backend="",
            degraded=self.degraded)

    def _admit(self) -> None:
        free = self.state.free_slots()
        batch: list[tuple[int, Request]] = []
        while free and self.queue:
            batch.append((free.pop(0), self.queue.pop()))
        if batch:
            self.state = self.engine.reset_slots(
                self.state, [b for b, _ in batch],
                [r.feeds for _, r in batch],
                caps=[r.max_cycles for _, r in batch])
            self.admission_rounds += 1
            for b, r in batch:
                self._resident[b] = (r, self.block)
                self._trace("admit", uid=r.uid, slot=b, tenant=r.tenant,
                            queue_wait_blocks=self.block
                            - self._queued_at[r.uid])
                self._count("requests_admitted", tenant=str(r.tenant))
            self._update_queue_metrics()

    # -- heartbeat ------------------------------------------------------
    def step(self) -> list[Result]:
        """One scheduler heartbeat; returns the requests that finished
        (possibly none) — including any completed out-of-band since the
        last call (queue drops, blocking-submit pumps).

        A heartbeat's block never lets any slot cross its cycle cap
        (engine ``max_cycles`` or ``Request.max_cycles``): it is
        shortened to the smallest remaining per-slot budget when one
        nears its cap (block partitioning does not change cycle
        semantics — property-tested across K), so even a truncated
        request simulates exactly its cap, bit-identical to a solo
        ``run`` under the same cap."""
        done, self._done = self._done, []
        return done + self._step_inner()

    def _step_inner(self) -> list[Result]:
        results = self._expire_queued()
        if self._reference:
            return results + self._step_reference()
        # 1. deadline / budget / watchdog exits on resident slots
        #    (precedence: expired > truncated > wedged)
        results += self._harvest_slots(
            [b for b in sorted(self._resident)
             if not self.state.quiesced[b] and self._deadline_blown(b)],
            kind="expired")
        results += self._harvest_slots(
            [b for b in sorted(self._resident)
             if not self.state.quiesced[b]
             and self.state.base[b] >= self.state.cap[b]],
            kind="truncated")
        results += self._harvest_slots(
            [b for b in sorted(self._resident)
             if int(self.state.stalled[b]) >= self.wedge_timeout_blocks],
            kind="wedged")
        # 2. admission (round-robin across tenants)
        self._admit()
        if not self._resident:
            return results
        # 3. advance one block — with retry, then degradation
        n_cycles = min(
            self.engine.block_cycles,
            min(int(self.state.cap[b]) - int(self.state.base[b])
                for b in self._resident))
        try:
            self.state = self._dispatch_block(n_cycles)
        except Exception as e:      # retries exhausted: degrade, requeue
            self._degrade(e)
            return results
        self.block += 1
        self._count("dispatches", backend=self.engine.backend)
        # 4. harvest quiesced slots; a fault-wedged request's quiescence
        #    signal is suppressed (the slot stalls until the watchdog)
        done = self.state.quiesced_slots()
        if self.faults is not None:
            wedged = [b for b in done
                      if self.faults.wedge(self._resident[b][0].uid)]
            for b in wedged:
                self.state.quiesced[b] = False
                req = self._resident[b][0]
                if req.uid not in self._wedge_traced:
                    # wedging suppresses quiescence every block; trace
                    # only the first suppression per request
                    self._wedge_traced.add(req.uid)
                    self._trace("wedge", uid=req.uid, slot=b,
                                tenant=req.tenant)
            done = [b for b in done if b not in wedged]
        return results + self._harvest_slots(done)

    def _deadline_blown(self, b: int) -> bool:
        req, _ = self._resident[b]
        return (req.deadline_blocks is not None
                and self.block - self._queued_at[req.uid]
                >= req.deadline_blocks)

    def _expire_queued(self) -> list[Result]:
        """Deadline sweep over the queue: requests whose budget elapsed
        before admission are answered as expired without ever touching
        a slot."""
        expired = self.queue.remove_if(
            lambda r: r.deadline_blocks is not None
            and self.block - self._queued_at[r.uid] >= r.deadline_blocks)
        results = []
        for r in expired:
            queued = self._queued_at.pop(r.uid)
            self._retries.pop(r.uid, None)
            self._trace("expire", uid=r.uid, tenant=r.tenant,
                        status="expired", queued_block=queued)
            results.append(self._observe_result(Result(
                uid=r.uid,
                metrics=self._queue_only_metrics(queued, expired=True))))
        if expired:
            self._update_queue_metrics()
        return results

    def _dispatch_block(self, n_cycles: int):
        """One device dispatch, retried with exponential backoff on
        transient failures; raises once ``max_retries`` is exhausted
        (the caller degrades the backend)."""
        attempt = 0
        while True:
            try:
                if self.faults is not None:
                    err = self.faults.dispatch_error(
                        self.engine.backend, self.block, attempt)
                    if err is not None:
                        raise err
                return self.engine.step_block(self.state,
                                              n_cycles=n_cycles)
            except Exception as e:
                attempt += 1
                if attempt > self.max_retries:
                    raise
                for req, _ in self._resident.values():
                    self._retries[req.uid] = \
                        self._retries.get(req.uid, 0) + 1
                self._log_event("dispatch-retry", attempt=attempt,
                                backend=self.engine.backend,
                                error=repr(e))
                self._trace("retry", attempt=attempt,
                            backend=self.engine.backend, error=repr(e))
                self._count("dispatch_retries",
                            backend=self.engine.backend)
                if self.retry_backoff_s > 0.0:
                    time.sleep(self.retry_backoff_s * 2 ** (attempt - 1))

    def _degrade(self, err: Exception) -> None:
        """Tear down the failing backend: re-queue every resident
        request (front of its tenant bucket, original uid and deadline
        intact — execution restarts from the feeds, which is
        deterministic) and bring up the next backend in the chain."""
        failed = self.engine.backend
        seats = [(b, self._resident[b][0]) for b in sorted(self._resident)]
        victims = [req for _, req in seats]
        self._resident.clear()
        for req in reversed(victims):
            self.queue.push_front(req)
            self._degraded_uids.add(req.uid)
        self.max_queue_depth = max(self.max_queue_depth, len(self.queue))
        self._log_event("degrade", from_backend=failed, error=repr(err),
                        requeued=[r.uid for r in victims])
        self._trace("degrade", from_backend=failed, error=repr(err))
        self._count("degradations", from_backend=failed)
        for b, req in seats:
            # the requeue closes the victim's slot span on the trace
            self._trace("requeue", uid=req.uid, slot=b,
                        tenant=req.tenant, from_backend=failed)
        self._update_queue_metrics()
        chain = self._chain_from(failed)
        for be in chain[1:] if chain[0] == failed else chain:
            if be == "reference":
                self._enter_reference(err)
                return
            try:
                if self.faults is not None:
                    self.faults.check_compile(be)
                self.engine = cached_engine(
                    self.graph, backend=be,
                    block_cycles=self._block_cycles,
                    max_cycles=self.max_cycles, optimize=self._optimize,
                    profile=self.profile, schedule=self._schedule)
                self.state = self.engine.init_state(self.slots)
                self._log_event("degrade-to", backend=be)
                return
            except Exception as e:
                self._log_event("compile-degrade", backend=be,
                                error=repr(e))
        self._enter_reference(err)      # unreachable fallback of fallbacks

    def _enter_reference(self, err: Exception | None) -> None:
        """Terminal degradation: serve from the pure-numpy oracle, one
        request per free capacity unit per heartbeat, every failure
        captured per-request.  No device, no dispatch — nothing left to
        fail wholesale."""
        self._reference = True
        self.engine = None
        self.state = None
        self._log_event("degrade-to", backend="reference",
                        error=repr(err) if err else None)

    def _step_reference(self) -> list[Result]:
        results = []
        for _ in range(self.slots):
            if not self.queue:
                break
            req = self.queue.pop()
            queued = self._queued_at.pop(req.uid)
            cap = req.max_cycles or self.max_cycles
            er, err = None, None
            if self.faults is not None:
                err = self.faults.reference_error(req.uid)
            if err is None:
                try:
                    er = run_reference(self.graph, req.feeds, (),
                                       np.int32, cap,
                                       profile=self.profile)
                    er.dispatches = 1
                except Exception as e:
                    err = e
            res = Result(
                uid=req.uid, engine=er, error=err,
                metrics=RequestMetrics(
                    slot=-1, queued_block=queued,
                    admitted_block=self.block,
                    finished_block=self.block + 1,
                    queue_wait_blocks=self.block - queued,
                    residency_blocks=1,
                    residency_cycles=er.cycles if er else 0,
                    tokens_out=sum(er.counts.values()) if er else 0,
                    truncated=bool(er and er.cycles >= cap),
                    degraded=self.degraded,
                    retries=self._retries.pop(req.uid, 0),
                    backend="reference"))
            # slot == -1: reference requests never open a slot span, so
            # the harvest is an instant + tenant-span close only; the
            # block stamp matches metrics.finished_block
            self._trace("harvest", uid=req.uid, slot=-1,
                        tenant=req.tenant, status=res.status,
                        block=self.block + 1, backend="reference")
            results.append(self._observe_result(res))
        if results:
            self.block += 1
            self._update_queue_metrics()
        return results

    def _harvest_slots(self, done: list[int],
                       kind: str = "ok") -> list[Result]:
        if not done:
            return []
        self.state, engine_results = self.engine.harvest(self.state, done)
        results = []
        for b, er in zip(done, engine_results):
            req, admitted = self._resident.pop(b)
            # strict: a uid resident in a slot MUST have submit-time
            # accounting; a silent fallback here would mask the very
            # bookkeeping bug it pretends to tolerate
            queued = self._queued_at.pop(req.uid)
            self._wedge_traced.discard(req.uid)
            res = Result(
                uid=req.uid, engine=er,
                metrics=RequestMetrics(
                    slot=b, queued_block=queued, admitted_block=admitted,
                    finished_block=self.block,
                    queue_wait_blocks=admitted - queued,
                    residency_blocks=er.dispatches,
                    residency_cycles=er.cycles,
                    tokens_out=sum(er.counts.values()),
                    truncated=kind == "truncated",
                    expired=kind == "expired",
                    wedged=kind == "wedged",
                    degraded=(req.uid in self._degraded_uids
                              or self.degraded),
                    retries=self._retries.pop(req.uid, 0),
                    backend=self.engine.backend))
            self._trace("harvest", uid=req.uid, slot=b, tenant=req.tenant,
                        status=res.status, cycles=er.cycles,
                        fired=er.fired, tokens_out=res.metrics.tokens_out,
                        backend=self.engine.backend)
            results.append(self._observe_result(res))
        return results

    def drain(self) -> list[Result]:
        """Step until the queue and every slot are empty."""
        out: list[Result] = []
        while self.queue or self._resident or self._done:
            out.extend(self.step())
        return out

    def run(self, requests: Iterable) -> list[Result]:
        """Serve a closed workload: submit everything, drain, return
        results sorted by uid."""
        for r in requests:
            self.submit(r)
        return sorted(self.drain(), key=lambda r: r.uid)

    @property
    def pending(self) -> int:
        return len(self.queue) + len(self._resident) + len(self._done)
