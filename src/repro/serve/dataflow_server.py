"""Continuous-batching dataflow serving: per-slot stream lifecycle.

The paper's fabric serves one token stream; ``DataflowEngine.run_batch``
(PR 1) serves B streams as a *wave* — all admitted together, the
dispatch loop running until the slowest stream quiesces, so short
requests idle in their slots.  This module removes the wave barrier:

* a :class:`DataflowServer` owns a FIFO request queue and B live
  *slots* on one block-fused fabric (the engine's resumable slot API,
  DESIGN.md §7);
* after each K-cycle block it detects per-slot quiescence (idle block
  tail — idle is absorbing), harvests finished requests, and refills
  those slots from the queue *while the other slots keep running*;
* free/quiesced slots are clock-gated out of feed/fire/drain by the
  per-stream active mask in ``fire_block_batched_pallas`` (the
  "per-row cache clock" serve/engine.py flags as future work for the
  LM path).

This is the serving analogue of a circuit-switched reconfigurable
fabric multiplexing independent stream computations through shared
operators with per-stream flow control (Li et al., arXiv:1310.3356):
the node/arc tables are the shared operator array, a slot is a
circuit, and admission is reconfiguration-free because every request
of a graph signature reuses one compiled plan.

Determinism: admissions happen only at block boundaries and each slot
carries its own cycle clock, so every request's
:class:`~repro.core.engine.EngineResult` is bit-identical to running
it alone via ``DataflowEngine.run`` — regardless of what rides the
other slots or of admission order (property-tested in
tests/test_dataflow_server.py).

Traced programs (:mod:`repro.front`, DESIGN.md §9) serve through the
same machinery: a ``TracedProgram`` is a ``Graph``, so its assembler
emission is its cache signature like any hand-assembled fabric —
:meth:`DataflowServer.for_fn` traces and serves in one step.

Loop programs (DESIGN.md §10) are where per-slot lifecycle earns its
keep: a ``lax.while_loop``-bearing request has a *data-dependent trip
count*, so its residency is unknowable at admission.  Each request is
one loop initiation (:meth:`DataflowServer.submit_args`); the slot's
idle-tail detection IS the loop-termination signal (the exit BRANCH
drains the result and the cycle goes quiet), short loops harvest and
refill while long ones keep iterating, and a divergent loop is
force-harvested at the engine's ``max_cycles`` cap with
``metrics.truncated`` set instead of wedging its slot.
"""
from __future__ import annotations

import collections
import hashlib
from typing import Iterable, Mapping

import numpy as np

from repro.core import asm
from repro.core.engine import BACKENDS, DataflowEngine
from repro.core.graph import Graph
from repro.serve.types import Request, RequestMetrics, Result

# ---------------------------------------------------------------------------
# Compiled-plan cache: many requests, one fabric
# ---------------------------------------------------------------------------
_ENGINE_CACHE: "collections.OrderedDict[tuple, DataflowEngine]" = \
    collections.OrderedDict()
_ENGINE_CACHE_MAX = 64      # LRU bound: a long-running service sees a
                            # finite fabric vocabulary; evicted engines
                            # stay alive wherever still referenced
CACHE_STATS = {"hits": 0, "misses": 0}


def graph_signature(graph: Graph) -> str:
    """Canonical text of a fabric (assembler emission: consts + node
    table with arc labels).  Two graphs with equal signatures compile
    to identical plans, so their requests can share one engine."""
    return asm.emit(graph)


def cached_engine(graph: Graph, *, backend: str = "xla",
                  block_cycles: int = 16,
                  max_cycles: int = 100_000,
                  token_shape: tuple = (), dtype=np.int32,
                  optimize: bool = False) -> DataflowEngine:
    """Engine for (graph signature, backend, K, token_shape, dtype,
    optimize) — compiled once, shared by every server/request that
    presents the same fabric (the cache key hashes the signature, not
    the graph object, so structurally equal graphs share).

    token_shape/dtype/optimize are part of the key: two servers over
    the same fabric signature with different token shapes or opt flags
    compile to different plans and must not collide on one engine."""
    if backend not in BACKENDS:
        raise ValueError(f"backend {backend!r} not in {BACKENDS}")
    token_shape = tuple(int(d) for d in token_shape)
    dtype = np.dtype(str(dtype)) if isinstance(dtype, str) \
        else np.dtype(dtype)
    key = (hashlib.sha256(graph_signature(graph).encode()).hexdigest(),
           backend, int(block_cycles), int(max_cycles),
           token_shape, dtype.str, bool(optimize))
    eng = _ENGINE_CACHE.get(key)
    if eng is None:
        CACHE_STATS["misses"] += 1
        eng = DataflowEngine(graph, token_shape, dtype,
                             backend=backend,
                             block_cycles=block_cycles,
                             max_cycles=max_cycles,
                             optimize=optimize)
        _ENGINE_CACHE[key] = eng
        while len(_ENGINE_CACHE) > _ENGINE_CACHE_MAX:
            _ENGINE_CACHE.popitem(last=False)
    else:
        CACHE_STATS["hits"] += 1
        _ENGINE_CACHE.move_to_end(key)
    return eng


def clear_engine_cache() -> None:
    _ENGINE_CACHE.clear()
    CACHE_STATS["hits"] = CACHE_STATS["misses"] = 0


# ---------------------------------------------------------------------------
# The server
# ---------------------------------------------------------------------------
class DataflowServer:
    """Request-level continuous batching over one block-fused fabric.

    Usage::

        srv = DataflowServer(graph, slots=8, block_cycles=16,
                             backend="pallas")
        srv.submit(feeds_a)            # returns uid
        srv.submit(Request(uid=7, feeds=feeds_b))
        done = srv.step()              # one K-cycle block; may finish 0+
        rest = srv.drain()             # run until queue + slots empty

    ``step()`` is the scheduler heartbeat: admit from the queue into
    free slots, advance every active slot by one K-cycle block (one
    device dispatch), harvest slots whose block had an idle tail.
    Requests that hit the engine's ``max_cycles`` safety cap are
    force-harvested (truncated) rather than wedging their slot.
    """

    def __init__(self, graph: Graph, slots: int = 8,
                 block_cycles: int = 16, backend: str = "xla",
                 max_cycles: int = 100_000,
                 engine: DataflowEngine | None = None,
                 optimize: bool = False):
        if slots < 1:
            raise ValueError("slots must be >= 1")
        if engine is not None:
            # an explicit engine wins over backend/block_cycles/max_cycles
            # (block size is a perf knob, never a semantics one), but it
            # must serve THIS fabric — a mismatched plan would silently
            # produce another graph's results
            if graph_signature(engine.graph) != graph_signature(graph):
                raise ValueError(
                    "engine= was compiled for a different fabric "
                    f"({engine.graph.name!r}, not {graph.name!r})")
            self.engine = engine
        else:
            # optimize=True shares the opcode-class-specialized plan
            # (DESIGN.md §8) across every slot; it joins the cache key
            # because specialized and dense plans compile differently
            self.engine = cached_engine(
                graph, backend=backend, block_cycles=block_cycles,
                max_cycles=max_cycles, optimize=optimize)
        self.state = self.engine.init_state(slots)
        self.slots = slots
        self.queue: collections.deque[Request] = collections.deque()
        self.block = 0            # server block clock (dispatches issued)
        self.admission_rounds = 0  # fused reset dispatches issued
        self._queued_at: dict[int, int] = {}     # uid -> block at submit
        self._resident: dict[int, tuple[Request, int]] = {}  # slot -> (req, admitted)
        self._auto_uid = 0

    @classmethod
    def for_fn(cls, fn, *avals, const_args=None, name=None,
               **server_kw) -> "DataflowServer":
        """Serve a traced Python program: lower ``fn`` through the
        :mod:`repro.front` frontend and build the server on the
        synthesized fabric.  A traced program is just another asm
        signature to the compiled-plan cache, so structurally-equal
        traces (across servers, across processes re-tracing the same
        source) share one engine.  The program's positional feed
        adapter rides along as ``server.make_feeds``::

            srv = DataflowServer.for_fn(
                lambda x, y: jnp.where(x > y, x - y, y - x),
                np.int32, np.int32, slots=8, backend="pallas")
            srv.submit(srv.make_feeds([5, 1], [2, 9]))
        """
        from repro.front import trace
        prog = trace(fn, *avals, name=name, const_args=const_args)
        srv = cls(prog, **server_kw)
        srv.traced = prog
        srv.make_feeds = prog.make_feeds
        return srv

    def submit_args(self, *args) -> int:
        """Submit one *evaluation* of a traced program (``for_fn``
        servers): ``make_feeds(*args)`` + ``submit`` in one step.  This
        is the natural request shape for loop fabrics (DESIGN.md §10):
        one initiation per request, data-dependent trip count inside
        the slot, per-slot quiescence detection ending it — requests
        that never quiesce are force-harvested at the engine's
        ``max_cycles`` cap with ``metrics.truncated`` set."""
        if not hasattr(self, "make_feeds"):
            raise AttributeError(
                "submit_args needs a server built by for_fn (only "
                "traced programs carry a positional feed adapter)")
        return self.submit(self.make_feeds(*args))

    # -- admission ------------------------------------------------------
    def submit(self, request) -> int:
        """Enqueue a request (a :class:`Request` or a bare feeds dict);
        returns its uid.  uids must be unique among in-flight requests —
        auto-assigned ones skip any the caller has taken."""
        if isinstance(request, Mapping) or request is None:
            while self._auto_uid + 1 in self._queued_at:
                self._auto_uid += 1
            self._auto_uid += 1
            request = Request(uid=self._auto_uid, feeds=dict(request or {}))
        if not isinstance(request, Request):
            raise TypeError(f"submit wants a Request or feeds dict, "
                            f"got {type(request).__name__}")
        if request.feeds is None:
            raise ValueError(f"request {request.uid} has no feeds — the "
                             "dataflow server serves feed-stream requests")
        if request.uid in self._queued_at:
            raise ValueError(f"uid {request.uid} is already in flight")
        # fail fast on feeds the fabric cannot take: admission batches
        # several requests into one fused reset, so a bad request must
        # be rejected here, not poison its co-batched neighbours there
        unknown = set(request.feeds) - set(self.engine.p["input_arcs"])
        if unknown:
            raise ValueError(f"request {request.uid}: feeds for "
                             f"non-input arcs: {sorted(unknown)}")
        self.queue.append(request)
        self._queued_at[request.uid] = self.block
        return request.uid

    def _admit(self) -> None:
        free = self.state.free_slots()
        batch: list[tuple[int, Request]] = []
        while free and self.queue:
            batch.append((free.pop(0), self.queue.popleft()))
        if batch:
            self.state = self.engine.reset_slots(
                self.state, [b for b, _ in batch],
                [r.feeds for _, r in batch])
            self.admission_rounds += 1
            for b, r in batch:
                self._resident[b] = (r, self.block)

    # -- heartbeat ------------------------------------------------------
    def step(self) -> list[Result]:
        """Evict cap-exhausted requests, admit, advance one block,
        harvest.  Returns the requests that finished this block
        (possibly none).

        A heartbeat's block never lets any slot cross the engine's
        ``max_cycles`` cap: it is shortened to the smallest remaining
        per-slot budget when one nears the cap (block partitioning does
        not change cycle semantics — property-tested across K), so even
        a truncated request simulates exactly ``max_cycles`` cycles,
        bit-identical to a solo ``run``."""
        cap = self.engine.max_cycles
        results = self._harvest_slots(
            [b for b in sorted(self._resident)
             if not self.state.quiesced[b] and self.state.base[b] >= cap],
            truncated=True)
        self._admit()
        if not self._resident:
            return results
        self.state = self.engine.step_block(self.state, n_cycles=min(
            self.engine.block_cycles,
            min(cap - int(self.state.base[b]) for b in self._resident)))
        self.block += 1
        return results + self._harvest_slots(self.state.quiesced_slots())

    def _harvest_slots(self, done: list[int],
                       truncated: bool = False) -> list[Result]:
        if not done:
            return []
        self.state, engine_results = self.engine.harvest(self.state, done)
        results = []
        for b, er in zip(done, engine_results):
            req, admitted = self._resident.pop(b)
            queued = self._queued_at.pop(req.uid, admitted)
            results.append(Result(
                uid=req.uid, engine=er,
                metrics=RequestMetrics(
                    slot=b, queued_block=queued, admitted_block=admitted,
                    finished_block=self.block,
                    queue_wait_blocks=admitted - queued,
                    residency_blocks=er.dispatches,
                    residency_cycles=er.cycles,
                    tokens_out=sum(er.counts.values()),
                    truncated=truncated)))
        return results

    def drain(self) -> list[Result]:
        """Step until the queue and every slot are empty."""
        out: list[Result] = []
        while self.queue or self._resident:
            out.extend(self.step())
        return out

    def run(self, requests: Iterable) -> list[Result]:
        """Serve a closed workload: submit everything, drain, return
        results sorted by uid."""
        for r in requests:
            self.submit(r)
        return sorted(self.drain(), key=lambda r: r.uid)

    @property
    def pending(self) -> int:
        return len(self.queue) + len(self._resident)
