"""Shared request/result vocabulary for both serving paths.

The repo serves two kinds of traffic through one set of dataclasses:

* **LM waves** (:class:`repro.serve.engine.ServeEngine`) — a request
  carries a token ``prompt`` and decode budget; the result carries the
  generated ``tokens``.
* **Dataflow streams**
  (:class:`repro.serve.dataflow_server.DataflowServer`) — a request
  carries ``feeds`` (arc -> token-stream dict, the environment buses of
  a fabric run); the result carries the fabric's
  :class:`~repro.core.engine.EngineResult` plus admission/residency
  metrics.

One vocabulary means schedulers, traces, and metrics code can treat
"requests in, results out" uniformly regardless of which engine is
behind the queue.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.engine import EngineResult


class InvalidRequestError(ValueError):
    """A request carried an unusable field value (e.g.
    ``deadline_blocks < 1`` or ``max_cycles < 1``) — raised by
    ``submit`` before the request touches the queue, so a malformed
    request can never poison an admission batch or expire instantly."""


@dataclasses.dataclass
class Request:
    """One unit of admission-controlled work.

    LM path fields: ``prompt`` / ``max_new_tokens`` / ``eos_id``.
    Dataflow path field: ``feeds`` (arc -> [k] token stream).

    Robustness fields (DESIGN.md §11): ``tenant`` is the fairness key
    bounded admission round-robins across; ``deadline_blocks`` expires
    the request — queued or resident — once that many server blocks
    pass after submit; ``max_cycles`` overrides the engine's cycle cap
    for this request's slot only (smaller *or* larger).
    """
    uid: int
    prompt: np.ndarray | None = None    # [S] int32 token ids (LM)
    max_new_tokens: int = 16
    eos_id: int | None = None
    feeds: dict | None = None           # arc -> stream (dataflow)
    tenant: object = None               # admission fairness key
    deadline_blocks: int | None = None  # expire after N server blocks
    max_cycles: int | None = None       # per-slot engine-cap override


@dataclasses.dataclass
class RequestMetrics:
    """Per-request serving metrics, in deterministic block-clock units
    (one unit = one K-cycle block dispatch of the serving fabric)."""
    slot: int                 # slot the request rode
    queued_block: int         # server block clock at submit()
    admitted_block: int       # ... at slot admission
    finished_block: int       # ... at harvest
    queue_wait_blocks: int    # admitted - queued (time spent queued)
    residency_blocks: int     # block dispatches while resident
    residency_cycles: int     # fabric cycles the request ran
    tokens_out: int           # tokens drained across all output arcs
    truncated: bool = False   # hit its cycle cap (engine max_cycles or
    #                           Request.max_cycles) before quiescing —
    #                           the slot was force-harvested, results
    #                           are partial
    expired: bool = False     # Request.deadline_blocks elapsed before
    #                           quiescence; harvested exactly like
    #                           truncation (partial results), or never
    #                           admitted at all (slot == -1)
    wedged: bool = False      # the stall watchdog force-harvested the
    #                           slot: token/firing counts stopped
    #                           changing for wedge_timeout_blocks
    #                           without the quiescence signal arriving
    degraded: bool = False    # served on a fallback backend (or
    #                           restarted by a backend degradation)
    retries: int = 0          # dispatch retries ridden while resident
    backend: str = ""         # backend that produced the final result


@dataclasses.dataclass
class Result:
    """What a serving engine hands back for one request.

    LM path fields: ``tokens`` / ``prompt_len``.
    Dataflow path fields: ``engine`` (the full
    :class:`~repro.core.engine.EngineResult`, bit-identical to a solo
    run) and ``metrics``.
    """
    uid: int
    tokens: np.ndarray | None = None    # generated ids (LM)
    prompt_len: int = 0
    engine: EngineResult | None = None  # fabric result (dataflow)
    metrics: RequestMetrics | None = None
    error: Exception | None = None      # typed failure: the request was
    #                                     answered, not computed (queue
    #                                     drop, exhausted fallback
    #                                     chain, reference-path fault)

    @property
    def status(self) -> str:
        """One-word disposition: ``ok`` | ``truncated`` | ``expired`` |
        ``wedged`` | ``error`` — the exits of the slot lifecycle state
        machine (DESIGN.md §11)."""
        if self.error is not None:
            return "error"
        m = self.metrics
        if m is not None:
            if m.expired:
                return "expired"
            if m.wedged:
                return "wedged"
            if m.truncated:
                return "truncated"
        return "ok"
