"""Batched serving engine: prefill + KV-cache decode in request waves.

Requests are grouped into fixed-size *waves* (padded to a common prompt
length); each wave is prefilled once and decoded step-by-step until every
member hits EOS or its token budget.  The KV cache is wave-synchronous
(one shared length scalar) — the greedy-batching analogue of the paper's
static dataflow: a wave is one token occupying the fabric's arcs, and
back-pressure (the full/empty bit) is the wave boundary.  Per-slot
lengths/continuous batching would need a per-row cache clock — the
dataflow serving path implements exactly that slot lifecycle
(`repro.serve.dataflow_server`, DESIGN.md §7); porting it to the KV
cache here remains future work.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm
from repro.serve.types import Request, Result

__all__ = ["Request", "Result", "ServeEngine"]


class ServeEngine:
    def __init__(self, cfg, params, batch_size: int = 8,
                 max_len: int = 512, greedy: bool = True, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len
        self.greedy = greedy
        self.key = jax.random.key(seed)
        self._prefill = jax.jit(
            lambda p, b: tfm.prefill(cfg, p, b, max_len=max_len))
        self._decode = jax.jit(
            lambda p, t, c: tfm.decode_step(cfg, p, t, c))

    def _sample(self, logits):
        if self.greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(sub, logits).astype(jnp.int32)

    def run(self, requests: Sequence[Request]) -> list[Result]:
        out: list[Result] = []
        reqs = sorted(requests, key=lambda r: len(r.prompt))
        for i in range(0, len(reqs), self.batch_size):
            out.extend(self._run_wave(reqs[i:i + self.batch_size]))
        return sorted(out, key=lambda r: r.uid)

    def _run_wave(self, wave: Sequence[Request]) -> list[Result]:
        B = len(wave)
        S = max(len(r.prompt) for r in wave)
        S = max(S, 8)
        toks = np.zeros((B, S), np.int32)
        for j, r in enumerate(wave):
            toks[j, S - len(r.prompt):] = r.prompt   # left-pad
        batch = {"tokens": toks}
        if self.cfg.frontend == "patches":
            batch["patches"] = np.zeros(
                (B, self.cfg.n_patches, self.cfg.frontend_dim), np.float32)
        if self.cfg.frontend == "frames":
            batch["frames"] = np.zeros(
                (B, self.cfg.enc_seq, self.cfg.frontend_dim), np.float32)
        logits, cache = self._prefill(self.params, batch)
        budget = max(r.max_new_tokens for r in wave)
        done = np.zeros((B,), bool)
        gen: list[list[int]] = [[] for _ in range(B)]
        tok = self._sample(logits)[:, None]
        for _ in range(budget):
            t_np = np.asarray(tok[:, 0])
            for j, r in enumerate(wave):
                if not done[j]:
                    gen[j].append(int(t_np[j]))
                    if ((r.eos_id is not None and t_np[j] == r.eos_id)
                            or len(gen[j]) >= r.max_new_tokens):
                        done[j] = True
            if done.all():
                break
            logits, cache = self._decode(self.params, tok, cache)
            tok = self._sample(logits)[:, None]
        return [Result(r.uid, np.array(g, np.int32), len(r.prompt))
                for r, g in zip(wave, gen)]
