"""Deterministic fault injection for the serving stack (DESIGN.md §11).

The recovery paths PR 6 adds to :class:`DataflowServer` — dispatch
retry with backoff, backend degradation, the wedged-slot watchdog,
per-request error results — are exactly the code that never runs in a
healthy test environment.  :class:`FaultPlan` makes them testable the
same way the differential fuzzer pins value semantics: every injection
decision is a pure function of ``(seed, kind, key)``, so a soak test
replays the identical fault schedule on every run and a failing seed
reproduces exactly.

Injection points (all opt-in; a server without a plan has zero
fault-path overhead):

* **compile failures** — ``check_compile(backend)`` raises
  :class:`CompileFault` for planned backends, exercising the
  construction-time fallback chain (``pallas → xla → reference``);
* **dispatch exceptions** — ``dispatch_error(backend, block, attempt)``
  returns a :class:`DispatchFault` for planned blocks.  *Transient*
  faults clear after ``transient_attempts`` retries (the backoff path);
  backends in ``persistent_backends`` fail every attempt from
  ``persistent_from_block`` on (the degradation path);
* **slot wedges** — ``wedge(uid)`` marks requests whose quiescence
  signal the server suppresses, simulating a stream that stops making
  progress without terminating; only the stall watchdog can free the
  slot;
* **poisoned feeds** — ``poison(feeds, uid, dtype)`` overwrites the
  first/last token of every stream with dtype-extreme values (INT_MIN /
  INT_MAX, or NaN / inf for floats).  Poison corrupts *values*, never
  structure, and is idempotent — a poisoned request still computes
  deterministically (two's-complement wraparound is the ALU contract),
  so even faulted requests stay bit-identical to a solo run over the
  same poisoned feeds while their neighbours are untouched;
* **reference-path failures** — ``reference_error(uid)`` injects a
  per-request failure in the terminal fallback, exercising the
  ``Result(error=...)`` endpoint where the server answers with a typed
  error instead of a value.

``FaultPlan.scaled()`` honours the ``REPRO_FAULTS`` environment
variable (``off`` | default | ``full``) so CI's scheduled chaos job can
crank intensity without editing tests.
"""
from __future__ import annotations

import hashlib
import os

import numpy as np

__all__ = ["InjectedFault", "CompileFault", "DispatchFault", "FaultPlan"]


class InjectedFault(RuntimeError):
    """Base of every fault-plan-injected failure (lets recovery code and
    tests distinguish injected faults from genuine ones)."""


class CompileFault(InjectedFault):
    """Injected engine-construction failure for a planned backend."""


class DispatchFault(InjectedFault):
    """Injected device-dispatch failure for a planned block."""


class FaultPlan:
    """A seeded, deterministic schedule of injected faults.

    Rate-based decisions hash ``(seed, kind, key)`` — never a stateful
    RNG — so they are independent of call order and repeat exactly
    across processes.  Explicit sets (``wedge_uids`` etc.) pin faults to
    chosen requests/blocks for targeted tests; rates layer probabilistic
    faults on top for soak coverage.
    """

    def __init__(self, seed: int = 0, *,
                 compile_fail=(),            # backends whose compile raises
                 dispatch_fail_blocks=(),    # blocks with a transient fault
                 dispatch_fail_rate: float = 0.0,
                 transient_attempts: int = 1,  # retries a transient eats
                 persistent_backends=(),     # backends that fail forever...
                 persistent_from_block: int = 0,   # ...from this block on
                 wedge_uids=(), wedge_rate: float = 0.0,
                 poison_uids=(), poison_rate: float = 0.0,
                 reference_fail_uids=()):
        self.seed = int(seed)
        self.compile_fail = frozenset(compile_fail)
        self.dispatch_fail_blocks = frozenset(int(b) for b
                                              in dispatch_fail_blocks)
        self.dispatch_fail_rate = float(dispatch_fail_rate)
        self.transient_attempts = int(transient_attempts)
        self.persistent_backends = frozenset(persistent_backends)
        self.persistent_from_block = int(persistent_from_block)
        self.wedge_uids = frozenset(wedge_uids)
        self.wedge_rate = float(wedge_rate)
        self.poison_uids = frozenset(poison_uids)
        self.poison_rate = float(poison_rate)
        self.reference_fail_uids = frozenset(reference_fail_uids)
        self.log: list[tuple] = []      # (kind, *key) of every injection
        # observability hook: called as notify(kind, *key) on every
        # injection (after it lands in ``log``).  The server points this
        # at its TraceRecorder so injected faults show up on the trace
        # timeline next to the lifecycle events they cause.
        self.notify = None

    def _emit(self, kind: str, *key) -> None:
        self.log.append((kind, *key))
        if self.notify is not None:
            self.notify(kind, *key)

    # -- the deterministic coin ----------------------------------------
    def _u(self, *key) -> float:
        """Uniform [0, 1) from sha256(seed, key) — order-independent."""
        h = hashlib.sha256(repr((self.seed, *key)).encode()).digest()
        return int.from_bytes(h[:8], "big") / 2.0 ** 64

    # -- injection points ----------------------------------------------
    def check_compile(self, backend: str) -> None:
        if backend in self.compile_fail:
            self._emit("compile", backend)
            raise CompileFault(
                f"injected compile failure for backend {backend!r}")

    def dispatch_error(self, backend: str, block: int,
                       attempt: int) -> Exception | None:
        """Fault for dispatch ``attempt`` (0-based) of server ``block``,
        or None.  Transients clear after ``transient_attempts`` retries;
        persistent backends never clear (forcing degradation)."""
        if (backend in self.persistent_backends
                and block >= self.persistent_from_block):
            self._emit("dispatch-persistent", backend, block, attempt)
            return DispatchFault(
                f"injected persistent dispatch failure "
                f"(backend={backend}, block={block})")
        transient = block in self.dispatch_fail_blocks or (
            self.dispatch_fail_rate > 0.0
            and self._u("dispatch", backend, block)
            < self.dispatch_fail_rate)
        if transient and attempt < self.transient_attempts:
            self._emit("dispatch-transient", backend, block, attempt)
            return DispatchFault(
                f"injected transient dispatch failure "
                f"(backend={backend}, block={block}, attempt={attempt})")
        return None

    def wedge(self, uid: int) -> bool:
        """True if this request's quiescence signal is suppressed (the
        slot wedges and only the stall watchdog can harvest it)."""
        return uid in self.wedge_uids or (
            self.wedge_rate > 0.0 and self._u("wedge", uid) < self.wedge_rate)

    def poisoned(self, uid: int) -> bool:
        return uid in self.poison_uids or (
            self.poison_rate > 0.0
            and self._u("poison", uid) < self.poison_rate)

    def poison(self, feeds: dict, uid: int, dtype=np.int32) -> dict:
        """Feeds with dtype-extreme tokens for planned uids (idempotent:
        first element -> lowest representable / NaN, last -> highest /
        inf); unplanned uids get the feeds back unchanged."""
        if not feeds or not self.poisoned(uid):
            return feeds
        dtype = np.dtype(dtype)
        out = {}
        for a, v in feeds.items():
            arr = np.array(v, dtype=dtype, copy=True)
            if arr.size:
                if np.issubdtype(dtype, np.floating):
                    arr.flat[0] = np.nan
                    arr.flat[-1] = np.inf
                else:
                    info = np.iinfo(dtype)
                    arr.flat[0] = info.min
                    arr.flat[-1] = info.max
            out[a] = arr
        self._emit("poison", uid)
        return out

    def reference_error(self, uid: int) -> Exception | None:
        if uid in self.reference_fail_uids:
            self._emit("reference", uid)
            return InjectedFault(
                f"injected reference-backend failure for request {uid}")
        return None

    # -- environment scaling (CI chaos job) -----------------------------
    @classmethod
    def scaled(cls, seed: int = 0, **kw) -> "FaultPlan | None":
        """A plan whose rates follow ``REPRO_FAULTS``: ``off`` -> None
        (no injection), ``full`` -> rates doubled (capped at 1.0),
        anything else -> as given."""
        mode = os.environ.get("REPRO_FAULTS", "").lower()
        if mode == "off":
            return None
        if mode == "full":
            for k in ("dispatch_fail_rate", "wedge_rate", "poison_rate"):
                if k in kw:
                    kw[k] = min(1.0, 2.0 * kw[k])
        return cls(seed, **kw)
