"""Bounded admission control for the serving stack (DESIGN.md §11).

The PR-2 server grew its FIFO without bound: a chatty tenant could
queue thousands of requests and every later arrival — no matter whose —
waited behind all of them.  This module gives :class:`DataflowServer`
the two admission primitives a multi-tenant fabric front-end needs:

* **a bound with a policy** — ``max_queue`` caps the number of queued
  (not-yet-resident) requests, and the ``policy`` decides what happens
  at the cap:

  - ``"reject"``      — ``submit`` returns a typed :class:`Rejected`
    (never raises, never enqueues) so the caller can shed load;
  - ``"block"``       — ``submit`` runs server heartbeats until a
    queue slot frees (single-threaded backpressure: the submitting
    host *is* the event loop);
  - ``"drop-oldest"`` — the oldest queued request of the *most
    backlogged tenant* is evicted with a
    ``Result(error=DroppedError)`` and the new request takes its
    place.

* **per-tenant fairness** — :class:`FairQueue` buckets requests by
  ``Request.tenant`` and dequeues round-robin across tenants in
  first-seen order, so one tenant flooding the queue delays only its
  own backlog: another tenant's single request is at most one
  round-robin lap from admission.  (A ``tenant`` of ``None`` is just
  the shared anonymous bucket — untagged traffic behaves exactly like
  the PR-2 FIFO.)

Admission stays a *scheduling* concern: none of this touches what runs
on the fabric, so every admitted request's result remains bit-identical
to a solo ``DataflowEngine.run`` (the server's core property).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable

POLICIES = ("reject", "block", "drop-oldest")


@dataclasses.dataclass
class Rejected:
    """Typed admission rejection returned by ``submit`` under
    ``policy="reject"`` when the queue is at ``max_queue``.  The request
    was *not* enqueued and will receive no :class:`~repro.serve.types.Result`;
    the uid is returned so the caller can retry/re-submit it later."""
    uid: int
    reason: str
    queue_depth: int
    tenant: object = None

    def __bool__(self) -> bool:      # `if srv.submit(...)` reads naturally
        return False


class QueueFullError(RuntimeError):
    """The bounded queue could not make room (``policy="block"`` safety
    valve: the pump ran a pathological number of heartbeats without a
    slot freeing — only reachable if the server itself cannot make
    progress, which the degradation chain is designed to prevent)."""


class DroppedError(RuntimeError):
    """``policy="drop-oldest"`` evicted this queued request to admit a
    newer one; delivered as ``Result(error=DroppedError(...))``."""


class FairQueue:
    """Bounded-agnostic round-robin-across-tenants request queue.

    Requests land in per-tenant FIFO buckets; :meth:`pop` serves
    tenants cyclically in first-seen order (a tenant whose bucket
    empties leaves the ring and re-enters at the back on its next
    request).  All operations are deterministic in the sequence of
    push/pop calls — admission order, and therefore every request's
    result, is reproducible.
    """

    def __init__(self) -> None:
        self._buckets: dict[object, collections.deque] = {}
        self._ring: collections.deque = collections.deque()  # tenant keys
        self._n = 0

    def __len__(self) -> int:
        return self._n

    def __iter__(self):
        """Queued requests in bucket (first-seen tenant) order — for
        inspection; pop order interleaves tenants instead."""
        for q in self._buckets.values():
            yield from q

    def depths(self) -> dict:
        """Per-tenant queued-request counts (observability export —
        feeds the server's ``queue_depth{tenant=...}`` gauges)."""
        return {t: len(q) for t, q in self._buckets.items() if q}

    def _bucket(self, tenant) -> collections.deque:
        q = self._buckets.get(tenant)
        if q is None:
            q = self._buckets[tenant] = collections.deque()
            self._ring.append(tenant)
        return q

    def push(self, req) -> None:
        self._bucket(getattr(req, "tenant", None)).append(req)
        self._n += 1

    def push_front(self, req) -> None:
        """Re-queue at the front of the request's own bucket (used when
        backend degradation evicts resident requests: they resume ahead
        of their tenant's later arrivals)."""
        self._bucket(getattr(req, "tenant", None)).appendleft(req)
        self._n += 1

    def pop(self):
        """Next request, round-robin across tenants."""
        while self._ring:
            t = self._ring.popleft()
            q = self._buckets[t]
            if q:
                self._ring.append(t)       # tenant goes to the back
                self._n -= 1
                return q.popleft()
            del self._buckets[t]           # empty bucket leaves the ring
        raise IndexError("pop from an empty FairQueue")

    def drop_oldest(self):
        """Evict the oldest request of the most backlogged tenant (ties
        break toward the earliest-seen tenant) — the fairness-preserving
        victim for ``policy="drop-oldest"``: load shedding lands on the
        tenant causing the backlog."""
        if not self._n:
            raise IndexError("drop_oldest from an empty FairQueue")
        victim_t = max(self._buckets, key=lambda t: len(self._buckets[t]))
        self._n -= 1
        return self._buckets[victim_t].popleft()

    def remove_if(self, pred: Callable[[object], bool]) -> list:
        """Remove and return every queued request matching ``pred``
        (deadline expiry sweep), preserving bucket order."""
        out = []
        for t, q in self._buckets.items():
            kept = collections.deque()
            for r in q:
                (out if pred(r) else kept).append(r)
            self._buckets[t] = kept
        self._n -= len(out)
        return out
