"""Sharded, atomic, elastic checkpoints.

Layout:  <dir>/step_<N>/
            manifest.json        tree structure + shapes + dtypes
            arr_<k>.npy          one file per leaf (streamed, no pickle)
         <dir>/LATEST            text file naming the newest complete step

Atomicity: writes go to ``step_<N>.tmp`` and are renamed only after the
manifest lands, so a crash mid-save never corrupts the latest checkpoint
(restore always reads LATEST, which is updated last).

Elasticity: leaves are stored as *full* (unsharded) arrays; restore
re-shards onto whatever mesh the resuming job uses — a resume may change
device count or mesh shape freely.  On a real multi-host pod each host
would write its shard and the manifest would carry the global shape; the
single-process layout here keeps the same interface.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _flatten(tree)
    meta = {"step": step, "treedef": str(treedef), "n_leaves": len(leaves),
            "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        logical_dtype = str(leaf.dtype)
        if logical_dtype == "bfloat16":     # numpy has no bf16: store bits
            arr = arr.view(np.uint16)
        np.save(os.path.join(tmp, f"arr_{i}.npy"), arr)
        meta["leaves"].append({"shape": list(arr.shape),
                               "dtype": logical_dtype})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # LATEST updated last -> atomic publication
    with open(os.path.join(ckpt_dir, "LATEST.tmp"), "w") as f:
        f.write(os.path.basename(final))
    os.replace(os.path.join(ckpt_dir, "LATEST.tmp"),
               os.path.join(ckpt_dir, "LATEST"))
    return final


def latest_step(ckpt_dir: str) -> int | None:
    try:
        with open(os.path.join(ckpt_dir, "LATEST")) as f:
            name = f.read().strip()
        return int(name.split("_")[-1])
    except (FileNotFoundError, ValueError):
        return None


def restore(ckpt_dir: str, like, step: int | None = None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs); returns (step, tree) or (None, None) if absent.
    Arrays are re-sharded to match ``like``'s shardings if present."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            return None, None
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        meta = json.load(f)
    leaves, treedef = _flatten(like)
    assert meta["n_leaves"] == len(leaves), \
        f"checkpoint has {meta['n_leaves']} leaves, model has {len(leaves)}"
    out = []
    for i, leaf in enumerate(leaves):
        arr = np.load(os.path.join(path, f"arr_{i}.npy"))
        if meta["leaves"][i]["dtype"] == "bfloat16":
            arr = arr.view(jax.numpy.bfloat16.dtype)
        expect = tuple(leaf.shape)
        assert tuple(arr.shape) == expect, \
            f"leaf {i}: ckpt {arr.shape} != model {expect}"
        sharding = getattr(leaf, "sharding", None)
        if sharding is not None and hasattr(sharding, "mesh"):
            out.append(jax.device_put(arr, sharding))   # elastic re-shard
        else:
            out.append(jax.numpy.asarray(arr))
    return step, jax.tree.unflatten(treedef, out)


def cleanup(ckpt_dir: str, keep: int = 3) -> None:
    """Retain the newest ``keep`` checkpoints."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(d.split("_")[-1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
