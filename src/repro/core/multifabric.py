"""Multi-fabric sharded execution: one graph as P communicating fabrics.

A :class:`~repro.core.partition.Partition` splits the graph into P
regions; each region compiles to an independent fabric plan (the same
:func:`repro.core.engine._plan` layout the solo engine uses, including
the role-ordered arc permutation under ``optimize``) and every
inter-region arc becomes a *token channel* — a replicated
(full, value) register pair that both endpoint fabrics see.  Execution
runs SPMD over a ``"shards"`` axis: under ``shard_map`` on a device
mesh when the platform has >= P devices (CPU CI forces host devices via
``--xla_force_host_platform_device_count``), or under
``jax.vmap(axis_name="shards")`` on a single device — the two paths
trace the *same* per-shard program, so they are bit-identical.

Lockstep channel semantics (DESIGN.md §14).  A depth-1 arc couples its
endpoints in BOTH directions every cycle — the token moves forward and
the backpressure (full bit) moves backward — so regions cannot run
decoupled and stay bit-identical to the solo fabric.  Instead every
region executes the global cycle against a consistent snapshot:

1. mirror the replicated channel registers into the region's local arc
   slots (both endpoints now see the true global state);
2. run the solo engine's exact cycle body (feed -> fire -> drain) on
   the region's own nodes;
3. each endpoint owner reports its delta — the producer region's
   *push* (token + value), the consumer region's *consume* — and one
   ``lax.psum`` over the shards axis merges them:
   ``full' = (full & ~consumed) | pushed``, exactly the register
   update an internal arc performs in the solo engine.

The per-cycle merges are fused *inside* the compiled K-cycle block, so
the host still sees one device dispatch per block and the only
cross-device communication is the channel-register exchange.  The
K-deep ring the channels ride is the per-block history of those K
merged slots: depth K absorbs the whole block-fused skew window, which
is why block granularity never changes results (quiescence is detected
from the merged global progress bit, again identical to solo).

Bit-identity in every :class:`~repro.core.engine.EngineResult` field
(outputs, counts, cycles, fired, node_fires, merged profile) holds by
construction and is property-tested in ``tests/test_partition.py``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec

from repro.core.engine import (EngineResult, SlotState, _node_inputs_ready,
                               _alu_op, _plan, _truthy, pack_feeds)
from repro.core.graph import Graph, Op
from repro.core.partition import Partition

_MAX_IN = 3
_MAX_OUT = 2

# opcodes whose result comes from the ALU where-chain (COPY/BRANCH/SINK
# default to operand `a`; the merges pick operands by arrival/control)
_ALU_OPS = tuple(
    int(op) for op in Op
    if op not in (Op.COPY, Op.BRANCH, Op.SINK, Op.NDMERGE, Op.DMERGE))


def _shard_map(f, mesh, in_specs, out_specs):
    """jax.shard_map with the jax<=0.4.x experimental fallback (same
    compat shim as core/pipeline.py)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


@jax.jit
def _mf_slot_reset(fv, fl, full, val, ptr, out_last, out_count, chf, chv,
                   mask, fv_rows, fl_rows, full0, val0, chf0, chv0):
    """Masked fused admission reset over [P, B, ...] slot state (the
    multi-fabric mirror of engine._slot_reset; one dispatch per round)."""
    m4 = mask[None, :, None, None]
    m3 = mask[None, :, None]
    return (jnp.where(m4, fv_rows, fv),
            jnp.where(m3, fl_rows, fl),
            jnp.where(m3, full0[:, None, :], full),
            jnp.where(m3, val0[:, None, :], val),
            jnp.where(m3, 0, ptr),
            jnp.where(m3, jnp.zeros((), out_last.dtype), out_last),
            jnp.where(m3, 0, out_count),
            jnp.where(m3, chf0[None, None, :], chf),
            jnp.where(m3, chv0[None, None, :], chv))


@jax.jit
def _mf_prof_reset(prof, mask):
    """Zero the masked slots' [P, B, ...] profile counters."""
    return tuple(jnp.where(mask[None, :, None], 0, x) for x in prof)


class MultiFabric:
    """P cooperating fabric plans + replicated token channels.

    Owned by a :class:`~repro.core.engine.DataflowEngine` constructed
    with ``partition`` engaged (P > 1); the engine delegates
    ``run``/``run_batch`` and the whole resumable slot API here.  The
    host-side block loop and all cycle accounting mirror the engine's
    pallas host loop exactly, so reported cycles/dispatches follow the
    same rules as every other backend.
    """

    def __init__(self, graph: Graph, part: Partition, *,
                 dtype=jnp.int32, block_cycles: int = 16,
                 optimize: bool = False, profile: bool = False,
                 max_cycles: int = 100_000, placement: str = "auto"):
        self.graph = graph
        self.part = part
        self.P = part.P
        self.dtype = jnp.dtype(dtype)
        self._np_dtype = np.dtype(str(self.dtype))
        self.block_cycles = int(block_cycles)
        self.optimize = bool(optimize)
        self.profile = bool(profile)
        self.max_cycles = int(max_cycles)
        self._build_tables()
        n_dev = len(jax.devices())
        if placement == "shard_map" and n_dev < self.P:
            raise ValueError(
                f"placement='shard_map' needs >= {self.P} devices, "
                f"have {n_dev}")
        self.use_shard_map = (placement == "shard_map"
                              or (placement == "auto" and n_dev >= self.P))
        self._mesh = (Mesh(np.array(jax.devices()[:self.P]), ("shards",))
                      if self.use_shard_map else None)
        self._tabs = {k: jnp.asarray(v) for k, v in self.tables.items()}
        self._steps: dict[int, object] = {}

    # ------------------------------------------------------------ plan build
    def _build_tables(self):
        g, part = self.graph, self.part
        P, assign = self.P, part.assign
        g.validate()
        prod = {a: ns[0] for a, ns in g.producers().items()}
        cons = g.consumers()
        garc = {a: i for i, a in enumerate(g.arcs)}

        # inter-region arcs -> channels (const buses are replicated,
        # never cut; producer-less / consumer-less arcs stay local)
        self.channels = [
            a for a in g.arcs
            if a not in g.consts and a in prod and a in cons
            and assign[prod[a]] != assign[cons[a][0]]]
        ch_set = set(self.channels)
        self.C = len(self.channels)
        Cp = max(self.C, 1)

        region_nodes = part.regions()
        self.subs: list[Graph] = []
        for r in range(P):
            sub = Graph(name=f"{g.name}@r{r}of{P}")
            used: set[str] = set()
            for i in region_nodes[r]:
                sub.nodes.append(g.nodes[i])
                used.update(g.nodes[i].inputs)
                used.update(g.nodes[i].outputs)
            for a, v in g.consts.items():
                # replicate consumed const buses; a (degenerate)
                # consumer-less const drains from region 0 like solo
                if a in used or (r == 0 and a not in cons):
                    sub.consts[a] = v
            for a, v in g.inits.items():
                # a cut init arc's one-shot token lives in the channel
                # registers; local inits stay with their consumer region
                if a in ch_set:
                    continue
                if assign[cons[a][0]] == r:
                    sub.inits[a] = v
            self.subs.append(sub)
        self.plans = [_plan(sub, optimize=self.optimize)
                      for sub in self.subs]

        env_in_all = g.input_arcs()
        env_out_all = g.output_arcs()
        self.graph_inputs = env_in_all
        self.env_in = [[a for a in p["input_arcs"] if a not in ch_set]
                       for p in self.plans]
        env_out = [[a for a in p["output_arcs"] if a not in ch_set]
                   for p in self.plans]
        assert sorted(a for e in self.env_in for a in e) == sorted(env_in_all)
        assert sorted(a for e in env_out for a in e) == sorted(env_out_all)
        # global output arc -> (region, local env row), graph order
        row_of = {(r, a): k for r in range(P)
                  for k, a in enumerate(env_out[r])}
        owner_out = {a: r for r in range(P) for a in env_out[r]}
        self.out_rows = [(a, owner_out[a], row_of[(owner_out[a], a)])
                         for a in env_out_all]

        Nm = max(1, max(len(s.nodes) for s in self.subs))
        A2m = max(p["A"] + 2 for p in self.plans)
        n_in = max(1, max(len(e) for e in self.env_in))
        n_out = max(1, max(len(e) for e in env_out))
        self.Nm, self.A2m, self.n_in, self.n_out = Nm, A2m, n_in, n_out

        opcode = np.zeros((P, Nm), np.int32)
        in_idx = np.zeros((P, Nm, _MAX_IN), np.int32)
        out_idx = np.zeros((P, Nm, _MAX_OUT), np.int32)
        const_mask = np.zeros((P, A2m), bool)
        full0 = np.zeros((P, A2m), bool)
        val0 = np.zeros((P, A2m), self._np_dtype)
        in_arc_idx = np.zeros((P, n_in), np.int32)
        out_arc_idx = np.zeros((P, n_out), np.int32)
        full_pad = np.zeros((P,), np.int32)
        empty_pad = np.zeros((P,), np.int32)
        node_back = np.full((P, Nm), -1, np.int64)
        arc_back = np.full((P, A2m), -1, np.int64)
        ch_in_pos = np.zeros((P, Cp), np.int32)
        ch_out_pos = np.zeros((P, Cp), np.int32)
        ch_in_own = np.zeros((P, Cp), bool)
        ch_out_own = np.zeros((P, Cp), bool)

        for r, (sub, p) in enumerate(zip(self.subs, self.plans)):
            nr = len(sub.nodes)
            ep = p["EMPTY_PAD"]
            full_pad[r] = p["FULL_PAD"]
            empty_pad[r] = ep
            opcode[r, :nr] = p["opcode"]
            # pad node rows read EMPTY_PAD inputs -> never ready, never
            # fire (the engine's pad convention inverted on purpose)
            in_idx[r] = ep
            out_idx[r] = ep
            in_idx[r, :nr] = p["in_idx"]
            out_idx[r, :nr] = p["out_idx"]
            const_mask[r, :p["A"] + 2] = p["const_mask"]
            full0[r, p["FULL_PAD"]] = True
            for a, v in sub.consts.items():
                full0[r, p["aidx"][a]] = True
                val0[r, p["aidx"][a]] = v
            for a, v in sub.inits.items():
                full0[r, p["aidx"][a]] = True
                val0[r, p["aidx"][a]] = v
            in_arc_idx[r] = ep
            out_arc_idx[r] = ep
            for k, a in enumerate(self.env_in[r]):
                in_arc_idx[r, k] = p["aidx"][a]
            for k, a in enumerate(env_out[r]):
                out_arc_idx[r, k] = p["aidx"][a]
            node_back[r, :nr] = np.asarray(region_nodes[r])[p["node_perm"]]
            for a in p["arcs"]:
                if a not in ch_set:
                    arc_back[r, p["aidx"][a]] = garc[a]
            ch_in_pos[r] = ep
            ch_out_pos[r] = ep

        ch_full0 = np.zeros((Cp,), np.int32)
        ch_val0 = np.zeros((Cp,), self._np_dtype)
        self.ch_rows = np.zeros((self.C,), np.int64)
        for c, a in enumerate(self.channels):
            rU, rD = assign[prod[a]], assign[cons[a][0]]
            ch_out_pos[rU, c] = self.plans[rU]["aidx"][a]
            ch_out_own[rU, c] = True
            ch_in_pos[rD, c] = self.plans[rD]["aidx"][a]
            ch_in_own[rD, c] = True
            self.ch_rows[c] = garc[a]
            if a in g.inits:
                ch_full0[c] = 1
                ch_val0[c] = g.inits[a]

        self._present = tuple(
            op for op in _ALU_OPS
            if any(int(n.op) == op for n in g.nodes))
        self.tables = dict(
            opcode=opcode, in_idx=in_idx, out_idx=out_idx,
            const_mask=const_mask, in_arc_idx=in_arc_idx,
            out_arc_idx=out_arc_idx, full_pad=full_pad,
            empty_pad=empty_pad, ch_in_pos=ch_in_pos,
            ch_out_pos=ch_out_pos, ch_in_own=ch_in_own,
            ch_out_own=ch_out_own)
        self.full0, self.val0 = full0, val0
        self.ch_full0, self.ch_val0 = ch_full0, ch_val0
        self.node_back, self.arc_back = node_back, arc_back

    # --------------------------------------------------------- compiled step
    def _core_fn(self, nb: int):
        """Per-shard K-cycle block program over [B, ...] slot state.

        Positional layout (after `tabs`): fv, fl, full, val, ptr,
        out_last, out_count, chf, chv, act, then (profiled only) the 5
        node/arc counters and the 3 channel counters.  Returns the
        persistent state + per-block (fired, last_progress) per slot.
        """
        profiled = self.profile
        present = self._present
        dtype = self.dtype

        def core(tabs, fv, fl, full, val, ptr, out_last, out_count,
                 chf, chv, act, *prof):
            opcode = tabs["opcode"]
            in_idx = tabs["in_idx"]
            out_idx = tabs["out_idx"]
            const_mask = tabs["const_mask"]
            FULL_PAD = tabs["full_pad"]
            EMPTY_PAD = tabs["empty_pad"]
            in_arc_idx = tabs["in_arc_idx"]
            out_arc_idx = tabs["out_arc_idx"]
            cip, cop = tabs["ch_in_pos"], tabs["ch_out_pos"]
            cio, coo = tabs["ch_in_own"], tabs["ch_out_own"]
            ch_pos = jnp.concatenate([cip, cop])

            def fire(full, val):
                # the solo engine's generic fire rule, with the ALU
                # where-chain restricted to the opcodes present in the
                # graph (the SPMD-compatible share of DESIGN.md §8's
                # opcode specialization — per-region class slices would
                # need per-shard programs, which SPMD forbids)
                inf = full[in_idx]                    # [N,3]
                oute = ~full[out_idx]                 # [N,2]
                a = val[in_idx[:, 0]]
                b = val[in_idx[:, 1]]
                ctrl3 = _truthy(val[in_idx[:, 2]])
                ctrl2 = _truthy(b)
                all_in = inf.all(axis=1)
                all_out = oute.all(axis=1)
                is_nd = opcode == int(Op.NDMERGE)
                is_dm = opcode == int(Op.DMERGE)
                is_br = opcode == int(Op.BRANCH)
                dm_chosen = jnp.where(ctrl3, inf[:, 0], inf[:, 1])
                ready = all_in & all_out
                ready = jnp.where(is_nd, (inf[:, 0] | inf[:, 1]) & all_out,
                                  ready)
                ready = jnp.where(is_dm, inf[:, 2] & dm_chosen & all_out,
                                  ready)
                ready = jnp.where(
                    is_br,
                    inf[:, 0] & inf[:, 1]
                    & jnp.where(ctrl2, oute[:, 0], oute[:, 1]), ready)
                z = a
                for op in present:
                    z = jnp.where(opcode == op,
                                  _alu_op(Op(op), a, b, dtype), z)
                z = jnp.where(is_nd, jnp.where(inf[:, 0], a, b), z)
                z = jnp.where(is_dm, jnp.where(ctrl3, a, b), z)
                consume = ready[:, None] & jnp.ones((1, _MAX_IN), bool)
                nd_pick = jnp.stack([inf[:, 0], ~inf[:, 0],
                                     jnp.zeros_like(inf[:, 0])], axis=1)
                dm_pick = jnp.stack([ctrl3, ~ctrl3,
                                     jnp.ones_like(ctrl3)], axis=1)
                consume = jnp.where(is_nd[:, None],
                                    ready[:, None] & nd_pick, consume)
                consume = jnp.where(is_dm[:, None],
                                    ready[:, None] & dm_pick, consume)
                produce = ready[:, None] & jnp.ones((1, _MAX_OUT), bool)
                br_pick = jnp.stack([ctrl2, ~ctrl2], axis=1)
                produce = jnp.where(is_br[:, None],
                                    ready[:, None] & br_pick, produce)
                return ready, z, consume, produce

            def cycle1(cyc, fv1, fl1, full, val, ptr, out_last, out_count,
                       chf, chv, lp, fired, *profc):
                # 1. mirror the replicated channel registers into both
                #    endpoint regions' local arc slots (consistent
                #    global snapshot; non-owner rows write EMPTY_PAD,
                #    which is re-cleared right after)
                cf = chf > 0
                full = full.at[ch_pos].set(jnp.concatenate([cf, cf]))
                val = val.at[ch_pos].set(jnp.concatenate([chv, chv]))
                full = full.at[FULL_PAD].set(True).at[EMPTY_PAD].set(False)
                # 2. strobe environment input buses (engine cycle step 1)
                can_feed = (~full[in_arc_idx]) & (ptr < fl1)
                nxt = jnp.take_along_axis(fv1, ptr[:, None], axis=1)[:, 0]
                tgt = jnp.where(can_feed, in_arc_idx, EMPTY_PAD)
                val = val.at[tgt].set(jnp.where(can_feed, nxt, val[tgt]))
                full = full.at[tgt].set(can_feed | full[tgt])
                ptr = ptr + can_feed
                fed_any = jnp.any(can_feed)
                full = full.at[EMPTY_PAD].set(False)
                # 3. fire every ready node (engine cycle step 2)
                if profiled:
                    ir = _node_inputs_ready(opcode, in_idx, full, val)
                ready, z, consume, produce = fire(full, val)
                cidx = jnp.where(consume, in_idx, EMPTY_PAD).reshape(-1)
                full = full.at[cidx].set(False)
                pidx = jnp.where(produce, out_idx, EMPTY_PAD).reshape(-1)
                full = full.at[pidx].set(True)
                val = val.at[pidx].set(jnp.stack([z, z], 1).reshape(-1))
                full = full.at[FULL_PAD].set(True).at[EMPTY_PAD].set(False)
                full = jnp.where(const_mask, True, full)
                # 4. channel deltas: the producer owner pushes a fresh
                #    token, the consumer owner reports consumption
                push = coo & (~cf) & full[cop]
                consd = cio & cf & (~full[cip])
                if profiled:
                    # occupancy sample point: post-fire, pre-drain;
                    # channel arcs are sampled from the MERGED register
                    # below (the local copy of the far endpoint's slot
                    # is one cycle stale by construction)
                    occ = full.astype(jnp.int32)
                    occ = occ.at[jnp.where(cio, cip, EMPTY_PAD)].set(0)
                    occ = occ.at[jnp.where(coo, cop, EMPTY_PAD)].set(0)
                    occ = occ.at[FULL_PAD].set(0).at[EMPTY_PAD].set(0)
                # 5. environment drains output buses (engine cycle step 3)
                got = full[out_arc_idx]
                out_last = jnp.where(got, val[out_arc_idx], out_last)
                out_count = out_count + got
                full = full.at[out_arc_idx].set(False)
                drained_any = jnp.any(got)
                n_fired = jnp.sum(ready.astype(jnp.int32))
                prog_l = (fed_any | drained_any
                          | (n_fired > 0)).astype(jnp.int32)
                # 6. one all-reduce merges every cross-region effect:
                #    full' = (full & ~consumed) | pushed  (the solo
                #    register update), plus the global progress bit
                if jnp.issubdtype(dtype, jnp.integer):
                    pv = jnp.where(push, val[cop],
                                   jnp.zeros((), dtype))
                    pg, cg, prg, pvg = lax.psum(
                        (push.astype(jnp.int32), consd.astype(jnp.int32),
                         prog_l, pv), "shards")
                else:
                    # exactly one shard contributes: sum the BITS so
                    # float payloads (incl. -0.0 and NaN) survive intact
                    bits = jnp.dtype(f"int{dtype.itemsize * 8}")
                    pvb = jnp.where(
                        push, lax.bitcast_convert_type(val[cop], bits),
                        jnp.zeros((), bits))
                    pg, cg, prg, pvb = lax.psum(
                        (push.astype(jnp.int32), consd.astype(jnp.int32),
                         prog_l, pvb), "shards")
                    pvg = lax.bitcast_convert_type(pvb, dtype)
                cf2 = (cf & (cg == 0)) | (pg > 0)
                chf = cf2.astype(jnp.int32)
                chv = jnp.where(pg > 0, pvg, chv)
                lp = jnp.where(prg > 0, cyc + 1, lp)
                fired = fired + n_fired
                if profiled:
                    nf, si, so, ab, ahw, cb, chw, cpu = profc
                    c32 = cf2.astype(jnp.int32)
                    profc = (nf + ready, si + ~ir, so + (ir & ~ready),
                             ab + occ, jnp.maximum(ahw, occ),
                             cb + c32, jnp.maximum(chw, c32),
                             cpu + (pg > 0))
                return (full, val, ptr, out_last, out_count, chf, chv,
                        lp, fired, *profc)

            nprof = 8 if profiled else 0
            vcycle = jax.vmap(cycle1, in_axes=(None,) + (0,) * (11 + nprof))
            B = full.shape[0]
            z32 = jnp.zeros((B,), jnp.int32)
            carry = (full, val, ptr, out_last, out_count, chf, chv,
                     z32, z32, *prof)

            def body(i, c):
                return vcycle(i, fv, fl, c[0], c[1], c[2], c[3], c[4],
                              c[5], c[6], c[7], c[8], *c[9:])

            out = lax.fori_loop(0, nb, body, carry)
            # clock-gate: a free slot's block never happened — state,
            # channels and counters revert, fired/lp report 0 (the
            # kernels/ref.py masked-block contract)
            actb = act > 0

            def sel(new, old):
                m = actb.reshape((-1,) + (1,) * (new.ndim - 1))
                return jnp.where(m, new, old)

            keep = [sel(n, o) for n, o in zip(
                (out[0], out[1], out[2], out[3], out[4], out[5], out[6]),
                (full, val, ptr, out_last, out_count, chf, chv))]
            profk = [sel(n, o) for n, o in zip(out[9:], prof)]
            f = jnp.where(actb, out[8], 0)
            lp = jnp.where(actb, out[7], 0)
            return (*keep, f, lp, *profk)

        return core

    def _step(self, nb: int):
        step = self._steps.get(nb)
        if step is None:
            core = self._core_fn(nb)
            if self._mesh is not None:
                def stacked(tabs, *args):
                    sq = jax.tree.map(lambda x: x[0], (tabs, *args))
                    out = core(*sq)
                    return jax.tree.map(lambda x: x[None], out)
                spec = PartitionSpec("shards")
                step = jax.jit(_shard_map(stacked, self._mesh,
                                          in_specs=spec, out_specs=spec))
            else:
                step = jax.jit(jax.vmap(core, axis_name="shards"))
            self._steps[nb] = step
        return step

    # ----------------------------------------------------------- host state
    def _fresh_state(self, B: int):
        P, A2m = self.P, self.A2m
        full = np.broadcast_to(self.full0[:, None, :], (P, B, A2m)).copy()
        val = np.broadcast_to(self.val0[:, None, :], (P, B, A2m)).copy()
        chf = np.broadcast_to(self.ch_full0[None, None, :],
                              (P, B, self.ch_full0.shape[0])).copy()
        chv = np.broadcast_to(self.ch_val0[None, None, :],
                              (P, B, self.ch_val0.shape[0])).copy()
        return (jnp.asarray(full), jnp.asarray(val),
                jnp.zeros((P, B, self.n_in), jnp.int32),
                jnp.zeros((P, B, self.n_out), self.dtype),
                jnp.zeros((P, B, self.n_out), jnp.int32),
                jnp.asarray(chf), jnp.asarray(chv))

    def _prof0(self, B: int):
        z = lambda n: jnp.zeros((self.P, B, n), jnp.int32)
        return (z(self.Nm), z(self.Nm), z(self.Nm),
                z(self.A2m), z(self.A2m))

    def _chprof0(self, B: int):
        z = lambda: jnp.zeros((self.P, B, self.ch_full0.shape[0]),
                              jnp.int32)
        return (z(), z(), z())

    def _pack(self, feeds_batch, L_min=1):
        """[P, B, n_in, L] / [P, B, n_in] stacked region feed tables."""
        B = len(feeds_batch)
        L = max([L_min] + [np.shape(v)[0] for f in feeds_batch
                           for v in (f or {}).values()])
        fv = np.zeros((self.P, B, self.n_in, L), self._np_dtype)
        fl = np.zeros((self.P, B, self.n_in), np.int32)
        for b, f in enumerate(feeds_batch):
            f = dict(f or {})
            unknown = set(f) - set(self.graph_inputs)
            if unknown:
                raise ValueError(
                    f"feeds for non-input arcs: {sorted(unknown)}")
            for r in range(self.P):
                sub_f = {a: f[a] for a in self.env_in[r] if a in f}
                pfv, pfl = pack_feeds(self.env_in[r], sub_f, (),
                                      self._np_dtype,
                                      pad_rows=self.n_in, min_len=L)
                fv[r, b] = pfv
                fl[r, b] = pfl
        return fv, fl

    # ------------------------------------------------------------ run paths
    def run(self, feeds=None, max_cycles: int | None = None) -> EngineResult:
        return self.run_batch([feeds or {}], max_cycles)[0]

    def run_batch(self, feeds_batch, max_cycles: int | None = None
                  ) -> list[EngineResult]:
        max_cycles = max_cycles or self.max_cycles
        feeds_batch = list(feeds_batch)
        B = len(feeds_batch)
        fv, fl = self._pack(feeds_batch)
        fv, fl = jnp.asarray(fv), jnp.asarray(fl)
        state = self._fresh_state(B)
        act = jnp.ones((self.P, B), jnp.int32)
        prof = ((*self._prof0(B), *self._chprof0(B))
                if self.profile else ())
        base = dispatches = 0
        last = np.zeros((B,), np.int64)
        fired = np.zeros((B,), np.int64)
        # the engine's pallas host loop, verbatim accounting
        while True:
            nb = min(self.block_cycles, max_cycles - base)
            out = self._step(nb)(self._tabs, fv, fl, *state, act, *prof)
            state, f, lp = out[:7], out[7], out[8]
            prof = tuple(out[9:])
            dispatches += 1
            f, lp = jax.device_get((f, lp))
            fired += np.asarray(f).sum(axis=0)       # regions partition N
            lp = np.asarray(lp)[0]                   # replicated via psum
            last = np.where(lp > 0, base + lp, last)
            base += nb
            if (lp < nb).all() or base >= max_cycles:
                break
        out_last, out_count = jax.device_get((state[3], state[4]))
        hprof = jax.device_get(prof) if self.profile else None
        return [self._result(out_last, out_count,
                             int(min(last[b] + 1, max_cycles)),
                             int(fired[b]), dispatches, b, hprof,
                             prof_cycles=base)
                for b in range(B)]

    def _result(self, out_last, out_count, cycles, fired, dispatches, b,
                hprof, prof_cycles) -> EngineResult:
        outputs = {a: out_last[r][b][k] for a, r, k in self.out_rows}
        counts = {a: int(out_count[r][b][k]) for a, r, k in self.out_rows}
        profile = node_fires = None
        if hprof is not None:
            profile = self.merged_profile(
                [x[:, b] for x in hprof[:5]],
                [x[0, b, :self.C] for x in hprof[5:]],
                cycles=prof_cycles, dispatches=dispatches)
            node_fires = profile.node_fires
        return EngineResult(outputs=outputs, counts=counts, cycles=cycles,
                            fired=fired, dispatches=dispatches,
                            node_fires=node_fires, profile=profile)

    def merged_profile(self, prof, chprof, cycles: int, dispatches: int):
        """Graph-order FabricProfile from per-region [P, ...] counters
        plus the replicated per-channel counters."""
        from repro.obs.profile import FabricProfile
        nf, si, so, ab, ahw = [np.asarray(x, np.int64) for x in prof]
        cb, chw, cpu = [np.asarray(x, np.int64) for x in chprof]
        N, A = len(self.graph.nodes), len(self.graph.arcs)
        gnf, gsi, gso = (np.zeros((N,), np.int64) for _ in range(3))
        gab, gahw = (np.zeros((A,), np.int64) for _ in range(2))
        nv = self.node_back >= 0
        gnf[self.node_back[nv]] = nf[nv]
        gsi[self.node_back[nv]] = si[nv]
        gso[self.node_back[nv]] = so[nv]
        av = self.arc_back >= 0
        gab[self.arc_back[av]] = ab[av]
        gahw[self.arc_back[av]] = ahw[av]
        if self.C:
            gab[self.ch_rows] = cb
            gahw[self.ch_rows] = chw
        node_names, arc_names = FabricProfile.names_for(self.graph)
        return FabricProfile(
            node_names=node_names, arc_names=arc_names,
            node_fires=gnf, stall_in=gsi, stall_out=gso,
            arc_busy=gab, arc_hw=gahw, cycles=int(cycles),
            dispatches=int(dispatches),
            ch_names=list(self.channels),
            ch_busy=cb if self.C else None,
            ch_hw=chw if self.C else None,
            ch_pushes=cpu if self.C else None,
            ch_depth=self.block_cycles)

    # ---------------------------------------------------------- slot API
    def slot_init(self, slots: int) -> SlotState:
        B = int(slots)
        full, val, ptr, out_last, out_count, chf, chv = \
            self._fresh_state(B)
        z64 = lambda: np.zeros((B,), np.int64)
        return SlotState(
            fv=jnp.zeros((self.P, B, self.n_in, 1), self.dtype),
            fl=jnp.zeros((self.P, B, self.n_in), jnp.int32),
            full=full, val=val, ptr=ptr,
            out_last=out_last, out_count=out_count,
            active=np.zeros((B,), np.int32), base=z64(), last=z64(),
            fired=z64(), quiesced=np.zeros((B,), bool), dispatches=z64(),
            cap=np.full((B,), self.max_cycles, np.int64), stalled=z64(),
            active_dev=jnp.zeros((self.P, B), jnp.int32),
            prof=self._prof0(B) if self.profile else None,
            prof_cycles=z64() if self.profile else None,
            mf=dict(chf=chf, chv=chv,
                    chprof=self._chprof0(B) if self.profile else None))

    def slot_reset(self, state: SlotState, slot_ids, new_feeds,
                   caps=None) -> SlotState:
        slot_ids = list(slot_ids)
        new_feeds = list(new_feeds)
        if len(slot_ids) != len(new_feeds):
            raise ValueError(f"{len(slot_ids)} slot ids but "
                             f"{len(new_feeds)} feed dicts")
        if not slot_ids:
            return state
        busy = [b for b in slot_ids if state.active[b]]
        if busy:
            raise ValueError(f"slots {busy} still hold unharvested "
                             "requests (harvest before refilling)")
        B = state.slots
        L = state.fv.shape[-1]
        pfv, pfl = self._pack(new_feeds, L_min=1)
        need = pfv.shape[-1]
        if need > L:        # grow the stream buffer (pow2 bounds retraces)
            L = 1 << (int(need) - 1).bit_length()
            state = dataclasses.replace(
                state, fv=jnp.pad(
                    state.fv,
                    ((0, 0), (0, 0), (0, 0), (0, L - state.fv.shape[-1]))))
        mask = np.zeros((B,), bool)
        fv_rows = np.zeros((self.P, B, self.n_in, L), self._np_dtype)
        fl_rows = np.zeros((self.P, B, self.n_in), np.int32)
        for j, b in enumerate(slot_ids):
            mask[b] = True
            fv_rows[:, b, :, :pfv.shape[-1]] = pfv[:, j]
            fl_rows[:, b] = pfl[:, j]
        fv_, fl_, full, val, ptr, out_last, out_count, chf, chv = \
            _mf_slot_reset(state.fv, state.fl, state.full, state.val,
                           state.ptr, state.out_last, state.out_count,
                           state.mf["chf"], state.mf["chv"],
                           jnp.asarray(mask), jnp.asarray(fv_rows),
                           jnp.asarray(fl_rows), jnp.asarray(self.full0),
                           jnp.asarray(self.val0),
                           jnp.asarray(self.ch_full0),
                           jnp.asarray(self.ch_val0))
        if caps is None:
            caps = [None] * len(slot_ids)
        if len(caps) != len(slot_ids):
            raise ValueError(f"{len(slot_ids)} slot ids but "
                             f"{len(caps)} caps")
        active = state.active.copy()
        for host in (base := state.base.copy(), last := state.last.copy(),
                     fired := state.fired.copy(),
                     disp := state.dispatches.copy(),
                     stalled := state.stalled.copy()):
            host[slot_ids] = 0
        cap = state.cap.copy()
        for b, c in zip(slot_ids, caps):
            if c is not None and int(c) < 1:
                raise ValueError(f"slot {b}: cap must be >= 1, got {c}")
            cap[b] = self.max_cycles if c is None else int(c)
        quiesced = state.quiesced.copy()
        active[slot_ids] = 1
        quiesced[slot_ids] = False
        prof, prof_cycles = state.prof, state.prof_cycles
        chprof = state.mf["chprof"]
        if self.profile:
            m = jnp.asarray(mask)
            prof = _mf_prof_reset(prof, m)
            chprof = _mf_prof_reset(chprof, m)
            prof_cycles = prof_cycles.copy()
            prof_cycles[slot_ids] = 0
        return SlotState(
            fv_, fl_, full, val, ptr, out_last, out_count,
            active, base, last, fired, quiesced, disp,
            cap=cap, stalled=stalled,
            active_dev=jnp.asarray(
                np.broadcast_to(active[None], (self.P, B)).copy()),
            prof=prof, prof_cycles=prof_cycles,
            mf=dict(chf=chf, chv=chv, chprof=chprof))

    def slot_step(self, state: SlotState, nb: int) -> SlotState:
        prof_args = ((*state.prof, *state.mf["chprof"])
                     if self.profile else ())
        out = self._step(nb)(self._tabs, state.fv, state.fl, state.full,
                             state.val, state.ptr, state.out_last,
                             state.out_count, state.mf["chf"],
                             state.mf["chv"], state.active_dev,
                             *prof_args)
        full, val, ptr, out_last, out_count, chf, chv, f, lp = out[:9]
        prof = tuple(out[9:14]) if self.profile else None
        chprof = tuple(out[14:17]) if self.profile else None
        f, lp = jax.device_get((f, lp))
        f = np.asarray(f).sum(axis=0)
        lp = np.asarray(lp)[0]
        fired = state.fired + f
        last = np.where(lp > 0, state.base + lp, state.last)
        base = state.base + np.where(state.active > 0, nb, 0)
        quiesced = np.where(state.active > 0, lp < nb, state.quiesced)
        disp = state.dispatches + (state.active > 0)
        stalled = np.where(state.active > 0,
                           np.where(lp > 0, 0, state.stalled + 1),
                           state.stalled)
        prof_cycles = state.prof_cycles
        if self.profile and prof_cycles is not None:
            prof_cycles = prof_cycles + np.where(state.active > 0, nb, 0)
        return SlotState(state.fv, state.fl, full, val, ptr, out_last,
                         out_count, state.active.copy(), base, last,
                         fired, quiesced, disp, cap=state.cap,
                         stalled=stalled, active_dev=state.active_dev,
                         prof=prof, prof_cycles=prof_cycles,
                         mf=dict(chf=chf, chv=chv, chprof=chprof))

    def slot_harvest(self, state: SlotState, slot_ids
                     ) -> tuple[SlotState, list[EngineResult]]:
        slot_ids = list(slot_ids)
        idle = [b for b in slot_ids if not state.active[b]]
        if idle:
            raise ValueError(f"slots {idle} are free — nothing to harvest")
        out_last, out_count = jax.device_get((state.out_last,
                                              state.out_count))
        hprof = hch = None
        if self.profile and state.prof is not None:
            hprof = jax.device_get(state.prof)
            hch = jax.device_get(state.mf["chprof"])
        results = []
        for b in slot_ids:
            pr = nfires = None
            if hprof is not None:
                pr = self.merged_profile(
                    [x[:, b] for x in hprof],
                    [x[0, b, :self.C] for x in hch],
                    cycles=int(state.prof_cycles[b]),
                    dispatches=int(state.dispatches[b]))
                nfires = pr.node_fires
            results.append(EngineResult(
                outputs={a: out_last[r][b][k]
                         for a, r, k in self.out_rows},
                counts={a: int(out_count[r][b][k])
                        for a, r, k in self.out_rows},
                cycles=int(min(state.last[b] + 1, state.cap[b])),
                fired=int(state.fired[b]),
                dispatches=int(state.dispatches[b]),
                node_fires=nfires, profile=pr))
        active = state.active.copy()
        quiesced = state.quiesced.copy()
        active[slot_ids] = 0
        quiesced[slot_ids] = False
        return dataclasses.replace(
            state, active=active, quiesced=quiesced,
            active_dev=jnp.asarray(
                np.broadcast_to(active[None],
                                (self.P, state.slots)).copy())), results
