"""Pipeline parallelism scheduled BY the paper's dataflow engine.

The mapping (DESIGN.md §4): pipeline stages are dataflow operator nodes,
microbatches are tokens, the inter-stage activation transfer is the arc
(str/ack handshake -> ``lax.ppermute``), and the schedule is obtained by
*simulating the stage chain on the static dataflow engine itself* —
each stage fires when its input arc holds a token and its output arc is
empty.

Two schedules:

* ``dataflow`` (paper-faithful): the engine's one-token-per-arc handshake
  sustains one token per TWO cycles per arc (paper §3.1), giving a
  2M+S-1-step schedule — stages alternate work/idle exactly like the
  str/ack exchange in paper Fig. 3.
* ``dense`` (beyond-paper): double-buffered arcs (the clocked pipeline of
  Teifel's Fig. 1c, which the paper cites as its synchronous model)
  recover the classic M+S-1 GPipe wavefront.  The measured step-count
  ratio between the two is reported in §Perf.

Both schedules drive the same executor: a ``shard_map`` over the "pp"
mesh axis, ``lax.scan`` over schedule steps, ``ppermute`` stage-to-stage
handshakes.  Backward (autodiff through ppermute/scan) yields the reverse
pipeline automatically.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.graph import Graph, Op
from repro.core.engine import run_reference


def _shard_map(f, mesh, in_specs, out_specs):
    """jax.shard_map with a fallback for jax<=0.4.x, where it still
    lives in jax.experimental.shard_map (and the no-replication-check
    kwarg is spelled check_rep, not check_vma)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


# ---------------------------------------------------------------------------
# schedule generation
# ---------------------------------------------------------------------------
def stage_chain_graph(n_stages: int) -> Graph:
    """The pipeline as a dataflow fabric: a chain of operator nodes."""
    g = Graph(name=f"pipeline_{n_stages}")
    g.const("zero", 0)
    arcs = ["mb_in"] + [f"a{i}" for i in range(1, n_stages)] + ["mb_out"]
    for s in range(n_stages):
        # identity operator (OR with 0) so the traced token value is the
        # microbatch id itself
        g.add(Op.OR, [arcs[s], "zero"], [arcs[s + 1]], name=f"stage{s}")
    return g


def dataflow_schedule(n_stages: int, n_micro: int) -> np.ndarray:
    """Schedule table [T, S] (microbatch index or -1) simulated on the
    static dataflow engine (paper-faithful one-token-per-arc)."""
    g = stage_chain_graph(n_stages)
    events = []
    run_reference(g, {"mb_in": np.arange(n_micro)},
                  trace=events.append)
    # events: (cycle, node_index, microbatch_value)
    T = max(c for c, _, _ in events)
    table = np.full((T, n_stages), -1, np.int32)
    for cycle, node, val in events:
        table[cycle - 1, node] = val
    return table


def dense_schedule(n_stages: int, n_micro: int) -> np.ndarray:
    """Double-buffered-arc schedule: classic M+S-1 wavefront."""
    T = n_micro + n_stages - 1
    table = np.full((T, n_stages), -1, np.int32)
    for t in range(T):
        for s in range(n_stages):
            m = t - s
            if 0 <= m < n_micro:
                table[t, s] = m
    return table


# ---------------------------------------------------------------------------
# executor
# ---------------------------------------------------------------------------
def pipeline_apply(mesh: Mesh, stage_fn, stage_params, x_micro,
                   schedule: np.ndarray):
    """Run the pipelined stack.

    stage_fn: (local_params, x [mb, ...]) -> y [mb, ...]
    stage_params: pytree with leading layer axis, sharded over "pp"
    x_micro: [M, mb, ...] microbatched input (replicated)
    schedule: [T, S] static table.
    Returns y_micro [M, mb, ...].
    """
    S = mesh.shape["pp"]
    T, S2 = schedule.shape
    assert S2 == S, (schedule.shape, S)
    M = x_micro.shape[0]
    sched = jnp.asarray(schedule)
    perm = [(i, i + 1) for i in range(S - 1)]

    def per_stage(params_local, x_all):
        stage = jax.lax.axis_index("pp")
        mb_shape = x_all.shape[1:]
        recv = jnp.zeros(mb_shape, x_all.dtype)
        out = jnp.zeros_like(x_all)

        def step(carry, sched_row):
            recv, out = carry
            mb = sched_row[stage]
            active = mb >= 0
            inp = jnp.where(stage == 0,
                            x_all[jnp.clip(mb, 0, M - 1)], recv)

            def work(x):
                return stage_fn(params_local, x)

            y = jax.lax.cond(active, work, lambda x: x, inp)
            # last stage deposits its finished microbatch
            out = jnp.where(
                (stage == S - 1) & active,
                jax.lax.dynamic_update_index_in_dim(
                    out, y, jnp.clip(mb, 0, M - 1), 0),
                out)
            # handshake: send to the right neighbour
            send = jax.lax.ppermute(y, "pp", perm)
            return (send, out), None

        (_, out), _ = jax.lax.scan(step, (recv, out), sched)
        # only the last stage's `out` is real; broadcast it to all stages
        # (masked psum) so the out_spec can be replicated
        out = jax.lax.psum(
            jnp.where(stage == S - 1, out, jnp.zeros_like(out)), "pp")
        return out

    fn = _shard_map(
        per_stage, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P("pp"), stage_params), P()),
        out_specs=P())
    return fn(stage_params, x_micro)


def make_stage_fn(cfg, n_local_layers: int):
    """Default stage: scan of dense transformer layers (repro.models)."""
    from repro.models.transformer import _dense_body

    def stage_fn(params_local, x):
        pos = jnp.broadcast_to(
            jnp.arange(x.shape[1], dtype=jnp.int32), x.shape[:2])

        def body(x, lp):
            x, _ = _dense_body(cfg, lp, x, pos)
            return x, None

        y, _ = jax.lax.scan(body, x, params_local)
        return y

    return stage_fn
