"""The paper's benchmark suite as static dataflow graphs.

Fibonacci, Max (vector), Dot product, Vector sum, Bubble sort, Pop count
(paper §4).  Fibonacci uses the paper's cyclic loop schema (Listing 1 /
Fig. 7): ndmerge initializes loop registers, a `gtdecider` (IFGT) produces
the loop condition, `branch` nodes gate the feedback arcs, `dmerge`-style
control distribution is realized with copy fanout.  The printed Listing 1
in the source PDF is corrupted (duplicated/garbled lines 12–16), so the
graph here is a clean reconstruction of the same schema; it round-trips
through the Listing-1 assembler syntax via :mod:`repro.core.asm`.

The vector benchmarks are *unrolled spatial fabrics* — trees of primitive
operators — which is how a dataflow FPGA extracts the parallelism the
paper's conclusion calls for.  They are DAGs, so both the cycle-accurate
engine (latency/throughput in cycles) and the compiled stream backend
(vmap over the token stream) run them.

Every builder returns ``Bench(graph, make_feeds, reference, out_arc)``.

The ``*_traced`` / ``horner`` / ``saxpy`` / ``relu_chain`` entries are
*synthesized* fabrics: ordinary Python expressions lowered through the
:mod:`repro.front` tracing frontend (the paper's algorithm-to-graph
toolchain step) instead of hand-assembled node tables.  Three of them
regenerate hand-built benches above — property tests pin the traced
fabric to the hand-built reference — and three are traced-only
workloads.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.graph import Graph, Op


@dataclasses.dataclass
class Bench:
    graph: Graph
    make_feeds: Callable[..., dict]
    reference: Callable[..., np.ndarray]
    out_arc: str
    streaming: bool = True  # DAG fabrics accept token streams
    out_arcs: list | None = None  # multi-output fabrics (bubble sort)
    dtype: object = np.int32  # execution dtype (newton_sqrt is float32;
    #                           pallas + the slot API are int32-only)


def _fanout(g: Graph, src: str, k: int, prefix: str) -> list[str]:
    """Copy tree: one arc -> k arcs (COPY duplicates to exactly two)."""
    if k == 1:
        return [src]
    outs = [f"{prefix}_l", f"{prefix}_r"]
    g.add(Op.COPY, [src], outs)
    left = _fanout(g, outs[0], (k + 1) // 2, prefix + "l")
    right = _fanout(g, outs[1], k // 2, prefix + "r")
    return left + right


def _reduce_tree(g: Graph, arcs: list[str], op: Op, prefix: str,
                 final: str | None = None) -> str:
    """Binary tree of 2-in primitives over the given arcs."""
    level = 0
    while len(arcs) > 1:
        nxt = []
        for i in range(0, len(arcs) - 1, 2):
            last = len(arcs) <= 2 and final is not None
            out = final if last else f"{prefix}_{level}_{i // 2}"
            g.add(op, [arcs[i], arcs[i + 1]], [out])
            nxt.append(out)
        if len(arcs) % 2:
            nxt.append(arcs[-1])
        arcs = nxt
        level += 1
    return arcs[0]


# ---------------------------------------------------------------------------
# Fibonacci (cyclic — the paper's flagship example)
# ---------------------------------------------------------------------------
def fibonacci_graph() -> Bench:
    """Paper Algorithm 1: n iterations of (first, second) <- (second,
    first+second) from (0, 1); `fibo` is the exit value of the running sum
    and `pf` the final loop index (as in Listing 1's two outputs)."""
    g = Graph(name="fibonacci")
    g.const("one", 1)            # the paper's sticky increment bus (dadoe)
    # --- loop counter (left half of Fig. 7) ---
    g.add(Op.NDMERGE, ["i_fb", "i_init"], ["i"])
    g.add(Op.COPY, ["i"], ["i_c", "i_d"])
    g.add(Op.IFGT, ["n_in", "i_c"], ["cond"])      # gtdecider: n > i
    g.add(Op.COPY, ["cond"], ["cond_i", "cond_fib"])
    g.add(Op.COPY, ["cond_fib"], ["cond_f", "cond_s"])
    g.add(Op.BRANCH, ["i_d", "cond_i"], ["i_live", "pf"])
    g.add(Op.ADD, ["i_live", "one"], ["i_fb"])
    # --- fibonacci registers (right half of Fig. 7) ---
    g.add(Op.NDMERGE, ["f_fb", "f_init"], ["first"])
    g.add(Op.NDMERGE, ["s_fb", "s_init"], ["second"])
    g.add(Op.COPY, ["second"], ["sec_a", "sec_b"])
    g.add(Op.ADD, ["first", "sec_a"], ["tmp"])
    g.add(Op.BRANCH, ["sec_b", "cond_f"], ["f_fb", "sec_exit"])
    g.add(Op.BRANCH, ["tmp", "cond_s"], ["s_fb", "fibo"])
    g.add(Op.SINK, ["sec_exit"], [])
    g.validate()
    # `n_in` needs a token every iteration -> environment presents it
    # persistently, like the paper's dadoa bus.  We model that by feeding
    # a stream of n+1 copies (one per decider firing); a const would also
    # work but n is a runtime argument.

    def make_feeds(n: int) -> dict:
        return {
            "n_in": np.full((n + 1,), n, np.int32),
            "i_init": np.array([0]),
            "f_init": np.array([0]),
            "s_init": np.array([1]),
        }

    def reference(n: int):
        first, second = 0, 1
        for _ in range(n):
            first, second = second, first + second
        return np.asarray(first + second)   # tmp routed out on exit

    return Bench(g, make_feeds, reference, "fibo", streaming=False)


FIBONACCI_ASM = """\
# Fibonacci dataflow fabric (Listing-1 syntax; clean reconstruction)
const one = 1;
1.  ndmerge i_fb, i_init, i;
2.  copy i, i_c, i_d;
3.  gtdecider n_in, i_c, cond;
4.  copy cond, cond_i, cond_fib;
5.  copy cond_fib, cond_f, cond_s;
6.  branch i_d, cond_i, i_live, pf;
7.  add i_live, one, i_fb;
8.  ndmerge f_fb, f_init, first;
9.  ndmerge s_fb, s_init, second;
10. copy second, sec_a, sec_b;
11. add first, sec_a, tmp;
12. branch sec_b, cond_f, f_fb, sec_exit;
13. branch tmp, cond_s, s_fb, fibo;
14. sink sec_exit;
"""


# ---------------------------------------------------------------------------
# Vector fabrics (DAGs)
# ---------------------------------------------------------------------------
def vector_sum_graph(n: int = 32) -> Bench:
    g = Graph(name=f"vector_sum_{n}")
    ins = [f"v{i}" for i in range(n)]
    _reduce_tree(g, list(ins), Op.ADD, "s", final="vsum")
    g.validate()

    def make_feeds(v):  # v: [k, n] stream of k vectors
        v = np.atleast_2d(np.asarray(v))
        return {f"v{i}": v[:, i] for i in range(n)}

    return Bench(g, make_feeds,
                 lambda v: np.atleast_2d(np.asarray(v)).sum(axis=1),
                 "vsum")


def max_vector_graph(n: int = 32) -> Bench:
    g = Graph(name=f"max_{n}")
    ins = [f"v{i}" for i in range(n)]
    _reduce_tree(g, list(ins), Op.MAX, "m", final="vmax")
    g.validate()

    def make_feeds(v):
        v = np.atleast_2d(np.asarray(v))
        return {f"v{i}": v[:, i] for i in range(n)}

    return Bench(g, make_feeds,
                 lambda v: np.atleast_2d(np.asarray(v)).max(axis=1),
                 "vmax")


def dot_product_graph(n: int = 32) -> Bench:
    g = Graph(name=f"dot_prod_{n}")
    prods = []
    for i in range(n):
        g.add(Op.MUL, [f"a{i}", f"b{i}"], [f"p{i}"])
        prods.append(f"p{i}")
    _reduce_tree(g, prods, Op.ADD, "d", final="dot")
    g.validate()

    def make_feeds(a, b):
        a, b = np.atleast_2d(np.asarray(a)), np.atleast_2d(np.asarray(b))
        f = {f"a{i}": a[:, i] for i in range(n)}
        f.update({f"b{i}": b[:, i] for i in range(n)})
        return f

    return Bench(g, make_feeds,
                 lambda a, b: (np.atleast_2d(a) * np.atleast_2d(b))
                 .sum(axis=1), "dot")


def bubble_sort_graph(n: int = 8) -> Bench:
    """Bubble-sort compare-exchange network (the spatially-unrolled form
    of the paper's bubble sort: each CE = copy×2 + min + max)."""
    g = Graph(name=f"bubble_sort_{n}")
    cur = [f"x{i}" for i in range(n)]
    step = 0
    for i in range(n):
        for j in range(n - 1 - i):
            x, y = cur[j], cur[j + 1]
            xa, xb = f"ce{step}_xa", f"ce{step}_xb"
            ya, yb = f"ce{step}_ya", f"ce{step}_yb"
            g.add(Op.COPY, [x], [xa, xb])
            g.add(Op.COPY, [y], [ya, yb])
            lo, hi = f"ce{step}_lo", f"ce{step}_hi"
            g.add(Op.MIN, [xa, ya], [lo])
            g.add(Op.MAX, [xb, yb], [hi])
            cur[j], cur[j + 1] = lo, hi
            step += 1
    g.validate()

    def make_feeds(v):
        v = np.atleast_2d(np.asarray(v))
        return {f"x{i}": v[:, i] for i in range(n)}

    def reference(v):
        return np.sort(np.atleast_2d(np.asarray(v)), axis=1)

    return Bench(g, make_feeds, reference, cur[0], out_arcs=list(cur))


def popcount_graph(bits: int = 16) -> Bench:
    """Population count of a `bits`-wide word: shift/mask/add fabric."""
    g = Graph(name=f"pop_count_{bits}")
    g.const("c_one", 1)
    xs = _fanout(g, "x", bits, "px")
    terms = []
    for k in range(bits):
        g.const(f"sh{k}", k)
        g.add(Op.SHR, [xs[k], f"sh{k}"], [f"sr{k}"])
        g.add(Op.AND, [f"sr{k}", "c_one"], [f"bit{k}"])
        terms.append(f"bit{k}")
    out = _reduce_tree(g, terms, Op.ADD, "pc", final="popc")
    g.validate()

    def make_feeds(x):
        x = np.atleast_1d(np.asarray(x))
        return {"x": x}

    def reference(x):
        x = np.atleast_1d(np.asarray(x)).astype(np.int32)
        return np.array([bin(int(v) & ((1 << bits) - 1)).count("1")
                         for v in x])

    return Bench(g, make_feeds, reference, "popc")


def fir_filter_graph(taps: int = 8) -> Bench:
    """Paper-style constant-coefficient FIR filter
    ``y[t] = sum_k c_k * x[t-k]``: one MUL-by-const per tap feeding an
    ADD reduce tree — the classic DSP pipeline a dataflow FPGA unrolls
    spatially.  The host supplies the tapped delay line (``make_feeds``
    windows the signal, one stream per tap), so the fabric is a pure
    streaming DAG like the other vector benches.  ``c0`` is 1 on
    purpose: its MUL is a no-op the identity-elimination pass
    (core/passes.py) splices out."""
    coeffs = [((3 * k) % 7) + 1 for k in range(taps)]   # 1..7, c0 == 1
    g = Graph(name=f"fir_{taps}")
    terms = []
    for k in range(taps):
        g.const(f"c{k}", coeffs[k])
        g.add(Op.MUL, [f"x{k}", f"c{k}"], [f"t{k}"])
        terms.append(f"t{k}")
    _reduce_tree(g, terms, Op.ADD, "y", final="fir")
    g.validate()

    def make_feeds(x):
        """x: raw signal of length T >= taps; emits T - taps + 1 output
        tokens (tap k sees the signal delayed by k)."""
        x = np.atleast_1d(np.asarray(x))
        if x.shape[0] < taps:
            raise ValueError(
                f"fir_{taps} needs a signal of at least {taps} samples, "
                f"got {x.shape[0]}")
        T = x.shape[0] - taps + 1
        return {f"x{k}": x[taps - 1 - k: taps - 1 - k + T]
                for k in range(taps)}

    def reference(x):
        x = np.atleast_1d(np.asarray(x)).astype(np.int64)
        return np.convolve(x, np.asarray(coeffs), "valid").astype(np.int64)

    return Bench(g, make_feeds, reference, "fir")


# ---------------------------------------------------------------------------
# Traced fabrics (synthesized by the repro.front expression frontend)
# ---------------------------------------------------------------------------
# Three regenerate hand-assembled benches above from plain Python (the
# paper's algorithm->graph toolchain step), three are traced-only
# workloads no one hand-assembled.  `from repro.front import trace` is
# deferred into each builder: front depends on this module's fan-out /
# reduce-tree helpers.

def traced_dot_product_graph(n: int = 32) -> Bench:
    """dot_product_graph regenerated from traced Python: the same
    multiply-accumulate math written as an ordinary expression (a
    left-fold chain rather than the hand-built reduce tree — same
    values bit-for-bit in integer arithmetic)."""
    from repro.front import trace

    def dot(*ab):
        a, b = ab[:n], ab[n:]
        acc = a[0] * b[0]
        for i in range(1, n):
            acc = acc + a[i] * b[i]
        return acc

    prog = trace(dot, *([np.int32] * (2 * n)),
                 name=f"dot_prod_traced_{n}")

    def make_feeds(a, b):
        a = np.atleast_2d(np.asarray(a))
        b = np.atleast_2d(np.asarray(b))
        return prog.make_feeds(*(a[:, i] for i in range(n)),
                               *(b[:, i] for i in range(n)))

    return Bench(prog, make_feeds,
                 lambda a, b: (np.atleast_2d(a) * np.atleast_2d(b))
                 .sum(axis=1), prog.out_arc)


def traced_popcount_graph(bits: int = 16) -> Bench:
    """popcount_graph regenerated from traced Python: shift/mask/add
    over the word's bits, exactly the paper's pop-count fabric but
    synthesized from the expression (the ``x >> 0`` tap is a no-op the
    identity-elimination pass splices out, like fir's c0)."""
    from repro.front import trace

    def popc(x):
        acc = (x >> 0) & 1
        for k in range(1, bits):
            acc = acc + ((x >> k) & 1)
        return acc

    prog = trace(popc, np.int32, name=f"pop_count_traced_{bits}")

    def make_feeds(x):
        return prog.make_feeds(np.atleast_1d(np.asarray(x)))

    def reference(x):
        x = np.atleast_1d(np.asarray(x)).astype(np.int32)
        return np.array([bin(int(v) & ((1 << bits) - 1)).count("1")
                         for v in x])

    return Bench(prog, make_feeds, reference, prog.out_arc)


def traced_fir_graph(taps: int = 8) -> Bench:
    """fir_filter_graph regenerated from traced Python with the
    coefficients bound as sticky const buses (``trace(const_args=...)``
    — the paper's persistently-presented input buses), so the fabric
    carries the same MUL-by-const taps as the hand-built bench."""
    from repro.front import trace
    coeffs = [((3 * k) % 7) + 1 for k in range(taps)]   # same as fir

    def fir(*args):
        xs, cs = args[:taps], args[taps:]
        acc = xs[0] * cs[0]
        for k in range(1, taps):
            acc = acc + xs[k] * cs[k]
        return acc

    prog = trace(fir, *([np.int32] * (2 * taps)),
                 name=f"fir_traced_{taps}",
                 const_args={taps + k: c for k, c in enumerate(coeffs)})

    def make_feeds(x):
        x = np.atleast_1d(np.asarray(x))
        if x.shape[0] < taps:
            raise ValueError(
                f"fir_traced_{taps} needs a signal of at least {taps} "
                f"samples, got {x.shape[0]}")
        T = x.shape[0] - taps + 1
        return prog.make_feeds(*(x[taps - 1 - k: taps - 1 - k + T]
                                 for k in range(taps)))

    def reference(x):
        x = np.atleast_1d(np.asarray(x)).astype(np.int64)
        return np.convolve(x, np.asarray(coeffs), "valid").astype(np.int64)

    return Bench(prog, make_feeds, reference, prog.out_arc)


def horner_graph(degree: int = 5) -> Bench:
    """Traced-only bench: Horner evaluation of a fixed int polynomial,
    ``(((c0 x + c1) x + c2) ...)`` — a deep multiply-add chain that
    pipelines through the fabric one token per wave."""
    from repro.front import trace
    coeffs = [((2 * k + 1) % 9) - 4 for k in range(degree + 1)]

    def horner(x):
        acc = coeffs[0] * x + coeffs[1]
        for c in coeffs[2:]:
            acc = acc * x + c
        return acc

    prog = trace(horner, np.int32, name=f"horner_{degree}")

    def make_feeds(x):
        return prog.make_feeds(np.atleast_1d(np.asarray(x)))

    def reference(x):
        x = np.atleast_1d(np.asarray(x)).astype(np.int32)
        acc = np.full_like(x, coeffs[0]) * x + np.int32(coeffs[1])
        for c in coeffs[2:]:
            acc = acc * x + np.int32(c)     # int32 wrap, like the fabric
        return acc

    return Bench(prog, make_feeds, reference, prog.out_arc)


def saxpy_graph(a: int = 3) -> Bench:
    """Traced-only bench: ``a*x + y`` over two token streams."""
    from repro.front import trace

    prog = trace(lambda x, y: a * x + y, np.int32, np.int32,
                 name=f"saxpy_{a}")

    def make_feeds(x, y):
        return prog.make_feeds(np.atleast_1d(np.asarray(x)),
                               np.atleast_1d(np.asarray(y)))

    def reference(x, y):
        return (np.int32(a) * np.atleast_1d(np.asarray(x)).astype(np.int32)
                + np.atleast_1d(np.asarray(y)).astype(np.int32))

    return Bench(prog, make_feeds, reference, prog.out_arc)


def relu_chain_graph() -> Bench:
    """Traced-only bench: clamp/relu chain with a data-dependent
    ``jnp.where`` — the select lowering (BRANCH pair + DMERGE) running
    on every backend, including the Pallas block kernels."""
    from repro.front import trace
    import jax.numpy as jnp

    def relu_chain(x, y):
        h = jnp.maximum(x - y, 0)               # relu
        h = jnp.minimum(h * 2 + 1, 100)         # clamp
        return jnp.where(h > 50, h - 50, h)

    prog = trace(relu_chain, np.int32, np.int32, name="relu_chain")

    def make_feeds(x, y):
        return prog.make_feeds(np.atleast_1d(np.asarray(x)),
                               np.atleast_1d(np.asarray(y)))

    def reference(x, y):
        x = np.atleast_1d(np.asarray(x)).astype(np.int32)
        y = np.atleast_1d(np.asarray(y)).astype(np.int32)
        h = np.minimum(np.maximum(x - y, 0) * 2 + 1, 100)
        return np.where(h > 50, h - 50, h)

    return Bench(prog, make_feeds, reference, prog.out_arc)


# ---------------------------------------------------------------------------
# Iterative loop fabrics (traced cyclic programs, DESIGN.md §10)
# ---------------------------------------------------------------------------
# The frontend lowers lax control flow onto the paper's loop schema —
# NDMERGE entry per carry, predicate cone, BRANCH-steered back edges —
# so these benches are CYCLIC fabrics with data-dependent (gcd, fib) or
# static (newton_sqrt, horner_loop) trip counts.  Loop fabrics initiate
# once per run: make_feeds takes scalar arguments, one result token out.

def gcd_graph() -> Bench:
    """Subtractive Euclid: while a != b, replace the larger by the
    difference — a ``lax.while_loop`` with a data-dependent trip count,
    the acceptance workload of the loop frontend."""
    import jax.numpy as jnp
    from jax import lax
    from repro.front import trace

    def gcd(a, b):
        def body(c):
            x, y = c
            return (jnp.where(x > y, x - y, x),
                    jnp.where(x > y, y, y - x))
        return lax.while_loop(lambda c: c[0] != c[1], body, (a, b))[0]

    prog = trace(gcd, np.int32, np.int32, name="gcd")

    def make_feeds(a, b):
        return prog.make_feeds([int(a)], [int(b)])

    def reference(a, b):
        import math
        return np.asarray(math.gcd(int(a), int(b)), np.int32)

    return Bench(prog, make_feeds, reference, prog.out_arc,
                 streaming=False)


def fib_loop_graph() -> Bench:
    """fibonacci_graph regenerated from traced Python: ``fori_loop``
    with a *traced* bound lowers to a while loop whose bound rides a
    synthetic pass-through carry (it is loop-invariant but streamy)."""
    import jax.numpy as jnp
    from jax import lax
    from repro.front import trace

    def fib(n):
        r = lax.fori_loop(0, n, lambda i, c: (c[1], c[0] + c[1]),
                          (jnp.int32(0), jnp.int32(1)))
        return r[0]

    prog = trace(fib, np.int32, name="fib")

    def make_feeds(n):
        return prog.make_feeds([int(n)])

    def reference(n):
        a, b = np.int32(0), np.int32(1)
        with np.errstate(over="ignore"):
            for _ in range(int(n)):
                a, b = b, np.int32(a + b)   # int32 wrap, like the fabric
        return np.asarray(a, np.int32)

    return Bench(prog, make_feeds, reference, prog.out_arc,
                 streaming=False)


def newton_sqrt_graph(iters: int = 8) -> Bench:
    """Float Newton iteration ``x <- (x + n/x) / 2`` over a static
    ``fori_loop`` (a carry-only scan): a float32 cyclic fabric whose
    loop-invariant ``n`` rides a synthetic carry and whose body uses
    the float DIV the DAG benches never exercise."""
    from jax import lax
    from repro.front import trace

    def newton_sqrt(n):
        return lax.fori_loop(0, iters, lambda i, x: 0.5 * (x + n / x),
                             n * 0.5 + 0.5)

    prog = trace(newton_sqrt, np.float32, name=f"newton_sqrt_{iters}")

    def make_feeds(n):
        return prog.make_feeds([float(n)])

    def reference(n):
        n = np.float32(n)
        x = np.float32(n * np.float32(0.5) + np.float32(0.5))
        with np.errstate(all="ignore"):
            for _ in range(iters):
                x = np.float32(0.5) * (x + n / x)
        return np.asarray(x, np.float32)

    return Bench(prog, make_feeds, reference, prog.out_arc,
                 streaming=False, dtype=np.float32)


def horner_loop_graph(degree: int = 8) -> Bench:
    """horner's rule as an actual LOOP (the spatially-unrolled `horner`
    bench re-rolled): ``acc <- acc*x + 1`` for ``degree`` iterations of
    a static ``fori_loop`` — a carry-only scan whose carries are
    (acc, x), the x carry a pure pass-through."""
    import jax.numpy as jnp
    from jax import lax
    from repro.front import trace

    def horner_loop(x):
        r = lax.fori_loop(
            0, degree, lambda i, c: (c[0] * c[1] + 1, c[1]),
            (jnp.int32(1), x))
        return r[0]

    prog = trace(horner_loop, np.int32, name=f"horner_loop_{degree}")

    def make_feeds(x):
        return prog.make_feeds([int(x)])

    def reference(x):
        acc, x = np.int32(1), np.int32(x)
        with np.errstate(over="ignore"):
            for _ in range(degree):
                acc = np.int32(acc * x + 1)  # int32 wrap, like the fabric
        return np.asarray(acc, np.int32)

    return Bench(prog, make_feeds, reference, prog.out_arc,
                 streaming=False)


BENCHES: dict[str, Callable[[], Bench]] = {
    "fibonacci": fibonacci_graph,
    "vector_sum": vector_sum_graph,
    "max_vector": max_vector_graph,
    "dot_prod": dot_product_graph,
    "bubble_sort": bubble_sort_graph,
    "pop_count": popcount_graph,
    "fir": fir_filter_graph,
    # synthesized by the repro.front tracing frontend
    "dot_prod_traced": traced_dot_product_graph,
    "pop_count_traced": traced_popcount_graph,
    "fir_traced": traced_fir_graph,
    "horner": horner_graph,
    "saxpy": saxpy_graph,
    "relu_chain": relu_chain_graph,
    # traced CYCLIC programs (loop frontend, DESIGN.md §10)
    "gcd": gcd_graph,
    "fib": fib_loop_graph,
    "newton_sqrt": newton_sqrt_graph,
    "horner_loop": horner_loop_graph,
}

# single-shot fabrics: one initiation -> one result token, and `k` in
# random_feeds scales the LOOP TRIP COUNT, not a stream length
SINGLE_SHOT = ("fibonacci", "gcd", "fib", "newton_sqrt", "horner_loop")


def random_feeds(name: str, bench: Bench, k: int, rng=None) -> dict:
    """A k-token random feed-stream dict for any bench (for the
    single-shot loop benches, k scales the trip count).  One place for
    the per-bench input-shape logic the drivers and tests used to each
    duplicate."""
    rng = np.random.default_rng(rng) if not hasattr(rng, "integers") \
        else rng
    n = len(bench.graph.input_arcs())
    if name in ("fibonacci", "fib"):    # k = loop iteration count
        return bench.make_feeds(int(k))
    if name == "gcd":
        # subtractive gcd of (k+1, b<=k+1) runs O(k) iterations
        return bench.make_feeds(int(k) + 1,
                                int(rng.integers(1, int(k) + 2)))
    if name.startswith("newton_sqrt"):
        return bench.make_feeds(float(rng.uniform(0.25, 100.0)))
    if name.startswith("horner_loop"):
        return bench.make_feeds(int(rng.integers(-4, 5)))
    if name.startswith("dot_prod"):
        return bench.make_feeds(rng.integers(0, 9, (k, n // 2)),
                                rng.integers(0, 9, (k, n // 2)))
    if name.startswith("pop_count"):
        return bench.make_feeds(rng.integers(0, 2 ** 16, (k,)))
    if name.startswith("fir"):
        return bench.make_feeds(rng.integers(0, 99, (k + n - 1,)))
    if name.startswith("horner"):
        return bench.make_feeds(rng.integers(0, 10, (k,)))
    if name.startswith(("saxpy", "relu_chain")):
        return bench.make_feeds(rng.integers(0, 99, (k,)),
                                rng.integers(0, 99, (k,)))
    return bench.make_feeds(rng.integers(0, 99, (k, n)))


def tokens_out(name: str, k: int) -> int:
    """Result tokens a run of `random_feeds(name, ..., k)` produces: one
    per stream element for DAG fabrics, one exit result per run for the
    single-shot loop fabrics (whatever their trip count)."""
    return 1 if name in SINGLE_SHOT else k
