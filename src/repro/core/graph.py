"""Static dataflow graph IR.

Faithful to the paper's model (Silva et al. 2011): a graph of operator
*nodes* connected by *arcs*; each arc is a register holding at most one
token (static dataflow).  Arc = 16-bit data bus + str/ack control wires on
the FPGA; here an arc is a (full: bool, value: dtype[token_shape]) register
pair, which generalizes the 16-bit bus to tensor tokens.

Operator vocabulary is Veen's classical set, as used by the paper:
copy, primitive (arithmetic/logic/relational), dmerge, ndmerge, branch.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Sequence


class Op(enum.IntEnum):
    """Opcodes. Values are stable — the engine dispatches on them."""

    # 1-in / 2-out
    COPY = 0
    # 2-in / 1-out primitives (paper: "add, sub, multiply, divide, and, or,
    # not, if, etc." — MAX/MIN/SHL/SHR/XOR live under the paper's "etc.")
    ADD = 1
    SUB = 2
    MUL = 3
    DIV = 4
    AND = 5
    OR = 6
    XOR = 7
    MAX = 8
    MIN = 9
    SHL = 10
    SHR = 11
    # 1-in / 1-out
    NOT = 12
    # relational deciders, 2-in / 1-out boolean token
    IFGT = 13   # a > b   (paper's `gtdecider`)
    IFGE = 14
    IFLT = 15
    IFLE = 16
    IFEQ = 17
    IFDF = 18   # a != b
    # control operators
    DMERGE = 19   # (a, b, ctrl) -> z : deterministic, ctrl selects a (true) or b
    NDMERGE = 20  # (a, b) -> z : first token to arrive wins (tie: a)
    BRANCH = 21   # (a, ctrl) -> (t, f) : routes a onto t (ctrl true) or f
    # sink: consumes a token (used to discard loop exhaust values)
    SINK = 22


# opcode -> (n_inputs, n_outputs)
ARITY: dict[Op, tuple[int, int]] = {
    Op.COPY: (1, 2),
    Op.ADD: (2, 1), Op.SUB: (2, 1), Op.MUL: (2, 1), Op.DIV: (2, 1),
    Op.AND: (2, 1), Op.OR: (2, 1), Op.XOR: (2, 1),
    Op.MAX: (2, 1), Op.MIN: (2, 1), Op.SHL: (2, 1), Op.SHR: (2, 1),
    Op.NOT: (1, 1),
    Op.IFGT: (2, 1), Op.IFGE: (2, 1), Op.IFLT: (2, 1), Op.IFLE: (2, 1),
    Op.IFEQ: (2, 1), Op.IFDF: (2, 1),
    Op.DMERGE: (3, 1),
    Op.NDMERGE: (2, 1),
    Op.BRANCH: (2, 2),
    Op.SINK: (1, 0),
}

PRIMITIVE_OPS = (
    Op.ADD, Op.SUB, Op.MUL, Op.DIV, Op.AND, Op.OR, Op.XOR, Op.MAX, Op.MIN,
    Op.SHL, Op.SHR,
)
DECIDER_OPS = (Op.IFGT, Op.IFGE, Op.IFLT, Op.IFLE, Op.IFEQ, Op.IFDF)

# LUT-complexity weights for the Table-1 resource analogue (relative logic
# cost of each operator's combinational datapath).
LUT_WEIGHT: dict[Op, int] = {
    Op.COPY: 1, Op.ADD: 16, Op.SUB: 16, Op.MUL: 64, Op.DIV: 128,
    Op.AND: 4, Op.OR: 4, Op.XOR: 4, Op.MAX: 20, Op.MIN: 20,
    Op.SHL: 12, Op.SHR: 12, Op.NOT: 2,
    Op.IFGT: 12, Op.IFGE: 12, Op.IFLT: 12, Op.IFLE: 12, Op.IFEQ: 8,
    Op.IFDF: 8, Op.DMERGE: 8, Op.NDMERGE: 8, Op.BRANCH: 8, Op.SINK: 1,
}


@dataclasses.dataclass(frozen=True)
class Node:
    op: Op
    inputs: tuple[str, ...]
    outputs: tuple[str, ...]
    name: str = ""

    def __post_init__(self):
        n_in, n_out = ARITY[self.op]
        if len(self.inputs) != n_in:
            raise ValueError(
                f"{self.op.name} expects {n_in} inputs, got {self.inputs}")
        if len(self.outputs) != n_out:
            raise ValueError(
                f"{self.op.name} expects {n_out} outputs, got {self.outputs}")


@dataclasses.dataclass
class Graph:
    """A static dataflow graph.

    Arc classes (derived, except consts and inits):
      * input arcs  — no producer node; fed by the environment. The paper's
        `dado*` labels. Each is fed a *stream* of tokens (strobed one at a
        time as the arc drains), or is a sticky ``const`` (the bus always
        presents the value — e.g. the loop increment `dadoe` in Listing 1).
      * output arcs — no consumer node; drained by the environment each
        cycle (the paper's result buses, e.g. `fibo`, `pf`).
      * internal arcs — exactly one producer and one consumer (the paper:
        "each channel is allowed only one sender and one receiver").

    ``inits`` are *initial-token annotations* (DESIGN.md §10): an init
    arc starts full, holding the given value — the classical
    synchronous-dataflow "delay" marking on a loop's back-edge register.
    Unlike a const bus the token is ONE-SHOT: once consumed, the arc
    refills only from its producer (if any).  A producer-less init arc
    (a compile-time loop initial value) is never refilled at all, and is
    *not* an environment input — the feed strobe skips it.
    """

    nodes: list[Node] = dataclasses.field(default_factory=list)
    consts: dict[str, object] = dataclasses.field(default_factory=dict)
    name: str = "graph"
    inits: dict[str, object] = dataclasses.field(default_factory=dict)

    # -- construction -------------------------------------------------
    def add(self, op: Op, inputs: Sequence[str], outputs: Sequence[str],
            name: str = "") -> Node:
        node = Node(op, tuple(inputs), tuple(outputs), name)
        self.nodes.append(node)
        return node

    def const(self, arc: str, value) -> str:
        self.consts[arc] = value
        return arc

    def init(self, arc: str, value) -> str:
        """Annotate ``arc`` with an initial token (see class docstring)."""
        self.inits[arc] = value
        return arc

    # -- derived structure --------------------------------------------
    @property
    def arcs(self) -> list[str]:
        seen: dict[str, None] = {}
        for n in self.nodes:
            for a in (*n.inputs, *n.outputs):
                seen.setdefault(a, None)
        for a in self.consts:
            seen.setdefault(a, None)
        for a in self.inits:
            seen.setdefault(a, None)
        return list(seen)

    def producers(self) -> dict[str, list[int]]:
        p: dict[str, list[int]] = {}
        for i, n in enumerate(self.nodes):
            for a in n.outputs:
                p.setdefault(a, []).append(i)
        return p

    def consumers(self) -> dict[str, list[int]]:
        c: dict[str, list[int]] = {}
        for i, n in enumerate(self.nodes):
            for a in n.inputs:
                c.setdefault(a, []).append(i)
        return c

    def input_arcs(self) -> list[str]:
        prod = self.producers()
        return [a for a in self.arcs
                if a not in prod and a not in self.consts
                and a not in self.inits]

    def output_arcs(self) -> list[str]:
        cons = self.consumers()
        return [a for a in self.arcs if a not in cons]

    # -- validation -----------------------------------------------------
    def validate(self) -> None:
        prod, cons = self.producers(), self.consumers()
        for a in self.arcs:
            if len(prod.get(a, [])) > 1:
                raise ValueError(f"arc {a!r} has multiple producers "
                                 f"{prod[a]} (one sender per channel)")
            # const arcs are sticky environment buses: always full, never
            # drained, so fanning them out to several receivers is safe.
            if a not in self.consts and len(cons.get(a, [])) > 1:
                raise ValueError(f"arc {a!r} has multiple consumers "
                                 f"{cons[a]} (one receiver per channel)")
            if a in self.consts and a in prod:
                raise ValueError(f"const arc {a!r} also has a producer")
        for a in self.inits:
            if a in self.consts:
                raise ValueError(f"init arc {a!r} is also a const bus "
                                 "(a sticky bus needs no initial token)")
            if not cons.get(a):
                raise ValueError(f"init arc {a!r} has no consumer — the "
                                 "initial token could never be used")

    def is_cyclic(self) -> bool:
        order = self.try_topo_order()
        return order is None

    def try_topo_order(self) -> list[int] | None:
        """Topological order of node indices, or None if cyclic."""
        prod = self.producers()
        indeg = []
        dep: list[list[int]] = [[] for _ in self.nodes]
        for i, n in enumerate(self.nodes):
            cnt = 0
            for a in n.inputs:
                for p in prod.get(a, []):
                    dep[p].append(i)
                    cnt += 1
            indeg.append(cnt)
        ready = [i for i, d in enumerate(indeg) if d == 0]
        order: list[int] = []
        while ready:
            i = ready.pop()
            order.append(i)
            for j in dep[i]:
                indeg[j] -= 1
                if indeg[j] == 0:
                    ready.append(j)
        return order if len(order) == len(self.nodes) else None

    # -- Table-1 resource analogue --------------------------------------
    def resources(self) -> dict[str, int]:
        """FPGA-resource analogue of the compiled fabric.

        FF  ≈ one (data + status-bit) register per arc  (paper Fig. 5:
              dadoa/bita etc.), counted in bits for a 16-bit datapath.
        LUT ≈ summed combinational complexity of operator datapaths.
        SLICE ≈ node count (each operator = one placed FSM+datapath block).
        """
        n_arcs = len(self.arcs)
        return {
            "nodes": len(self.nodes),
            "arcs": n_arcs,
            "ff_bits": n_arcs * 17,  # 16-bit data reg + 1-bit status
            "lut_weight": int(sum(LUT_WEIGHT[n.op] for n in self.nodes)),
        }

    def summary(self) -> str:
        r = self.resources()
        kind = "cyclic" if self.is_cyclic() else "dag"
        return (f"{self.name}: {r['nodes']} nodes, {r['arcs']} arcs "
                f"({kind}), ff_bits={r['ff_bits']} lut={r['lut_weight']}")
