"""Static dataflow token engine.

Cycle-accurate, vectorized reproduction of the paper's fabric:

* every arc is a register pair ``(full: bool, value)`` — the 16-bit data
  register + 1-bit status register of paper Fig. 5 (dadoa/bita, ...);
* a node *fires* when all its input arcs are full and all its output arcs
  are empty (static dataflow: one token per arc);
* one engine cycle = every ready node fires simultaneously.  Because a
  producer may only write an arc that was already empty at the start of
  the cycle, an arc sustains one token per two cycles — the same cadence
  as the paper's str/ack handshake;
* environment buses: *input* arcs are strobed with the next token of their
  feed stream as soon as they drain; *const* arcs always present their
  value (paper: input buses that hold data persistently, e.g. the loop
  increment `dadoe`); *output* arcs are drained by the environment every
  cycle, with the last value and a token count recorded.

The firing step is expressed over flat arrays (opcode[N], in_idx[N,3],
out_idx[N,2]) so that one cycle is a single fused vector computation —
this is what the ``dataflow_fire`` Pallas kernel implements on TPU, and on
the FPGA it is the physically-concurrent operator array.

Non-determinism note: ``ndmerge`` resolves same-cycle arrivals with a
fixed priority (input ``a`` wins).  See DESIGN.md §2.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import hashlib
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph, Op

_MAX_IN = 3
_MAX_OUT = 2

# -- _plan memoization ------------------------------------------------------
# Plan construction walks the whole graph in python and dominates engine
# construction cost (ROADMAP item 3); the result depends only on the
# graph's asm signature and the optimize flag (schedule state is built
# separately and never alters the plan), so one process-wide LRU serves
# every engine/backend/reference run of the same fabric.  The cached
# dict's numpy arrays are frozen read-only: sharing is safe because no
# consumer mutates a plan, and the flag turns any future mutation into
# an immediate error instead of silent cross-engine corruption.
_PLAN_CACHE: collections.OrderedDict = collections.OrderedDict()
_PLAN_CACHE_MAX = 256
PLAN_CACHE_STATS = {"hits": 0, "misses": 0, "evictions": 0}


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()
    for k in PLAN_CACHE_STATS:
        PLAN_CACHE_STATS[k] = 0


def _plan(graph: Graph, optimize: bool = False):
    """Memoized :func:`_plan_build` keyed on (asm signature, optimize).

    The signature is the full textual serialization (nodes, consts,
    inits), so a mutated Graph re-keys automatically; hits skip both
    validation and array construction."""
    from repro.core import asm
    sig = hashlib.sha256(asm.emit(graph).encode()).hexdigest()
    key = (sig, bool(optimize))
    hit = _PLAN_CACHE.get(key)
    if hit is not None:
        PLAN_CACHE_STATS["hits"] += 1
        _PLAN_CACHE.move_to_end(key)
        return hit
    PLAN_CACHE_STATS["misses"] += 1
    p = _plan_build(graph, optimize)
    for v in p.values():
        if isinstance(v, np.ndarray):
            v.flags.writeable = False
    _PLAN_CACHE[key] = p
    if len(_PLAN_CACHE) > _PLAN_CACHE_MAX:
        _PLAN_CACHE.popitem(last=False)
        PLAN_CACHE_STATS["evictions"] += 1
    return p


def _plan_build(graph: Graph, optimize: bool = False):
    """Static (numpy) arrays describing the fabric.

    With ``optimize=True`` the plan is *opcode-class specialized*
    (DESIGN.md §8): arcs are permuted into role order (inputs, outputs,
    internal, consts) and nodes are permuted so that equal opcodes are
    contiguous, with the per-class slice ranges recorded in
    ``class_slices`` — the fire body can then unroll a static loop over
    only the opcode classes present instead of evaluating the full ALU
    ``where``-chain for every node.  The permutation is pure layout:
    every node still fires against the same snapshot, so results are
    bit-identical to the unoptimized plan.  ``node_perm``/``arc_perm``
    map plan row -> original index and ``node_inv``/``arc_inv`` are the
    inverses (original index -> plan row).
    """
    graph.validate()
    arcs = graph.arcs
    input_arcs = graph.input_arcs()
    output_arcs = graph.output_arcs()
    if optimize:
        # arc permutation: environment buses first (inputs, then
        # outputs), then internal arcs, then consts — role-contiguous
        # so environment gathers walk compact index ranges
        ordered: dict[str, None] = {}
        for a in (*input_arcs, *output_arcs):
            ordered.setdefault(a, None)
        for a in arcs:
            if a not in graph.consts:
                ordered.setdefault(a, None)
        for a in arcs:
            ordered.setdefault(a, None)
        old_pos = {a: i for i, a in enumerate(arcs)}
        arcs = list(ordered)
        arc_perm = np.asarray([old_pos[a] for a in arcs], np.int32)
    else:
        arc_perm = np.arange(len(arcs), dtype=np.int32)
    arc_inv = np.empty_like(arc_perm)
    arc_inv[arc_perm] = np.arange(len(arcs), dtype=np.int32)
    aidx = {a: i for i, a in enumerate(arcs)}
    A = len(arcs)
    FULL_PAD = A        # dummy slot, always full (pads missing inputs)
    EMPTY_PAD = A + 1   # dummy slot, always empty (pads missing outputs)

    N = len(graph.nodes)
    opcode = np.zeros((N,), np.int32)
    in_idx = np.full((N, _MAX_IN), FULL_PAD, np.int32)
    out_idx = np.full((N, _MAX_OUT), EMPTY_PAD, np.int32)
    for i, n in enumerate(graph.nodes):
        opcode[i] = int(n.op)
        for k, a in enumerate(n.inputs):
            in_idx[i, k] = aidx[a]
        for k, a in enumerate(n.outputs):
            out_idx[i, k] = aidx[a]

    if optimize:
        node_perm = np.argsort(opcode, kind="stable").astype(np.int32)
        opcode = opcode[node_perm]
        in_idx = in_idx[node_perm]
        out_idx = out_idx[node_perm]
        class_slices = []
        s = 0
        while s < N:
            e = s
            while e < N and opcode[e] == opcode[s]:
                e += 1
            class_slices.append((int(opcode[s]), s, e))
            s = e
        class_slices = tuple(class_slices) or None
    else:
        node_perm = np.arange(N, dtype=np.int32)
        class_slices = None
    node_inv = np.empty_like(node_perm)
    node_inv[node_perm] = np.arange(N, dtype=np.int32)

    const_mask = np.zeros((A + 2,), bool)
    for a in graph.consts:
        const_mask[aidx[a]] = True

    return dict(
        arcs=arcs, aidx=aidx, A=A, FULL_PAD=FULL_PAD, EMPTY_PAD=EMPTY_PAD,
        opcode=opcode, in_idx=in_idx, out_idx=out_idx,
        const_mask=const_mask, input_arcs=input_arcs,
        output_arcs=output_arcs, class_slices=class_slices,
        node_perm=node_perm, node_inv=node_inv,
        arc_perm=arc_perm, arc_inv=arc_inv,
    )


def _alu(op, a, b, dtype):
    """All primitive results for operands a, b; select by opcode later."""
    is_int = jnp.issubdtype(dtype, jnp.integer)
    if is_int:
        bs = jnp.clip(b, 0, 31)
        safe_b = jnp.where(b == 0, 1, b)
        res = {
            Op.ADD: a + b, Op.SUB: a - b, Op.MUL: a * b,
            Op.DIV: jnp.where(b == 0, 0, a // safe_b),
            Op.AND: a & b, Op.OR: a | b, Op.XOR: a ^ b,
            Op.MAX: jnp.maximum(a, b), Op.MIN: jnp.minimum(a, b),
            Op.SHL: a << bs, Op.SHR: a >> bs,
            Op.NOT: (a == 0).astype(dtype),
        }
    else:
        safe_b = jnp.where(b == 0, 1.0, b)
        two_b = jnp.exp2(b)
        res = {
            Op.ADD: a + b, Op.SUB: a - b, Op.MUL: a * b,
            Op.DIV: jnp.where(b == 0, 0.0, a / safe_b),
            Op.AND: ((a != 0) & (b != 0)).astype(dtype),
            Op.OR: ((a != 0) | (b != 0)).astype(dtype),
            Op.XOR: ((a != 0) ^ (b != 0)).astype(dtype),
            Op.MAX: jnp.maximum(a, b), Op.MIN: jnp.minimum(a, b),
            Op.SHL: a * two_b, Op.SHR: a / jnp.where(two_b == 0, 1, two_b),
            Op.NOT: (a == 0).astype(dtype),
        }
    res.update({
        Op.IFGT: (a > b).astype(dtype), Op.IFGE: (a >= b).astype(dtype),
        Op.IFLT: (a < b).astype(dtype), Op.IFLE: (a <= b).astype(dtype),
        Op.IFEQ: (a == b).astype(dtype), Op.IFDF: (a != b).astype(dtype),
    })
    return res


def _alu_op(op, a, b, dtype):
    """Single-opcode ALU result — the specialized fire body's per-bucket
    kernel.  Formula-identical to the matching :func:`_alu` entry, but
    only the requested opcode is traced, so the ``b == 0`` / shift-clamp
    guards materialize solely for DIV/SHL/SHR buckets."""
    is_int = jnp.issubdtype(dtype, jnp.integer)
    if op in (Op.COPY, Op.BRANCH, Op.SINK):
        return a
    if op == Op.ADD:
        return a + b
    if op == Op.SUB:
        return a - b
    if op == Op.MUL:
        return a * b
    if op == Op.DIV:
        if is_int:
            return jnp.where(b == 0, 0, a // jnp.where(b == 0, 1, b))
        return jnp.where(b == 0, 0.0, a / jnp.where(b == 0, 1.0, b))
    if op == Op.AND:
        return (a & b) if is_int else ((a != 0) & (b != 0)).astype(dtype)
    if op == Op.OR:
        return (a | b) if is_int else ((a != 0) | (b != 0)).astype(dtype)
    if op == Op.XOR:
        return (a ^ b) if is_int else ((a != 0) ^ (b != 0)).astype(dtype)
    if op == Op.MAX:
        return jnp.maximum(a, b)
    if op == Op.MIN:
        return jnp.minimum(a, b)
    if op == Op.SHL:
        return (a << jnp.clip(b, 0, 31)) if is_int else a * jnp.exp2(b)
    if op == Op.SHR:
        if is_int:
            return a >> jnp.clip(b, 0, 31)
        two_b = jnp.exp2(b)
        return a / jnp.where(two_b == 0, 1, two_b)
    if op == Op.NOT:
        return (a == 0).astype(dtype)
    if op == Op.IFGT:
        return (a > b).astype(dtype)
    if op == Op.IFGE:
        return (a >= b).astype(dtype)
    if op == Op.IFLT:
        return (a < b).astype(dtype)
    if op == Op.IFLE:
        return (a <= b).astype(dtype)
    if op == Op.IFEQ:
        return (a == b).astype(dtype)
    if op == Op.IFDF:
        return (a != b).astype(dtype)
    raise AssertionError(op)


def _truthy(v):
    """Scalar truth of a (possibly tensor) control token: element 0."""
    flat = v.reshape(v.shape[0], -1)
    return flat[:, 0] != 0


def _node_inputs_ready(opcode, in_idx, full, val):
    """Per-node "all (selected) inputs present" on the post-feed
    registers — the stall-attribution predicate (DESIGN.md §12).

    ``ready`` (the fire rule) implies inputs-ready, so a profiled cycle
    partitions every node into exactly one of fired / blocked-on-input
    (``~inputs_ready``) / blocked-on-output (``inputs_ready & ~ready``).
    Shared by the xla cycle body and the pallas block kernels (``full``
    may be bool or int32; pads make the generic all-inputs reduction
    correct for BRANCH)."""
    inf = full[in_idx].astype(bool)              # [N,3]
    ir = inf.all(axis=1)
    is_nd = opcode == int(Op.NDMERGE)
    is_dm = opcode == int(Op.DMERGE)
    ir = jnp.where(is_nd, inf[:, 0] | inf[:, 1], ir)
    ctrl3 = _truthy(val[in_idx[:, 2]])
    ir = jnp.where(is_dm,
                   inf[:, 2] & jnp.where(ctrl3, inf[:, 0], inf[:, 1]), ir)
    return ir


def _prof_zeros(n_nodes: int, n_arcs: int, batch: int | None = None):
    """Fresh profile accumulators (nf, si, so, ab, ahw) — int32 device
    arrays; node axis may include the pallas tables' dummy row."""
    shp = (batch,) if batch is not None else ()
    z = lambda n: jnp.zeros((*shp, n), jnp.int32)
    return (z(n_nodes), z(n_nodes), z(n_nodes), z(n_arcs), z(n_arcs))


@dataclasses.dataclass
class EngineResult:
    outputs: dict       # arc -> last token value (jnp array)
    counts: dict        # arc -> number of tokens drained
    cycles: int
    fired: int          # total node firings
    dispatches: int | None = None   # device dispatches used (if tracked)
    node_fires: np.ndarray | None = None  # int64[N] per-node firings in
                                          # graph order (profile=on; sums
                                          # exactly to `fired`)
    profile: object | None = None   # FabricProfile (profile=on)


@dataclasses.dataclass
class SlotState:
    """Resumable state of B fabric *slots* (continuous batching).

    A slot is one stream's worth of arc registers, feed pointers, and
    output accumulators riding the shared fabric.  Unlike
    :meth:`DataflowEngine.run_batch` (wave batching: all B streams start
    and finish together), slots have independent lifecycles: a quiesced
    slot can be harvested and refilled with a new request's feed stream
    while the other slots keep running — see
    :class:`repro.serve.dataflow_server.DataflowServer`.

    Device arrays (jnp, int32; leading axis = B slots):
      fv[B, n_in, L], fl[B, n_in]   packed feed streams (L grows on
                                    demand, power-of-two, to bound
                                    recompiles)
      full/val[B, A2]               arc registers
      ptr[B, n_in]                  per-arc feed pointers
      out_last/out_count[B, n_out]  output-bus accumulators

    Host arrays (numpy; the per-slot clock):
      active[B]     1 while a request occupies the slot (gates the
                    kernel's feed/fire/drain — inactive slots are
                    skipped, not stepped)
      base[B]       slot-local cycles simulated so far
      last[B]       slot-local cycle of last progress
      fired[B]      node firings of the resident request
      quiesced[B]   latest block had an idle tail (idle is absorbing,
                    so the resident request is finished)
      dispatches[B] block dispatches the resident request has ridden
      cap[B]        per-slot cycle cap (engine max_cycles unless the
                    admission overrode it via ``reset_slots(caps=)``) —
                    the budget a scheduler shortens blocks against and
                    ``harvest`` clamps the cycle count to
      stalled[B]    consecutive blocks with zero progress (no feed, no
                    firing, no drain) while the slot stayed active —
                    the progress counter a wedged-slot watchdog reads;
                    reset to 0 by any progress and on (re)admission

    Profiling (engine profile=on only; None otherwise):
      prof          tuple of 5 device counter arrays (node_fires,
                    stall_in, stall_out, arc_busy, arc_hw — leading B
                    axis, plan order) accumulated IN-KERNEL alongside
                    the block step, so profiling adds no extra
                    dispatches per block
      prof_cycles[B] host tally of cycles the resident request's slot
                    was simulated for (its profiled-cycle denominator)
    """
    fv: object
    fl: object
    full: object
    val: object
    ptr: object
    out_last: object
    out_count: object
    active: np.ndarray
    base: np.ndarray
    last: np.ndarray
    fired: np.ndarray
    quiesced: np.ndarray
    dispatches: np.ndarray
    cap: np.ndarray = None
    stalled: np.ndarray = None
    active_dev: object = None   # device mirror of `active` (refreshed on
                                # admission/harvest, not per block)
    prof: tuple | None = None
    prof_cycles: np.ndarray = None
    sched: object = None        # scheduled engines: repro.core.schedule
                                # .SlotSched (per-slot plan refs +
                                # schedule positions + host-side §12
                                # counters); None on dynamic engines
    mf: object = None           # partitioned engines: dict with the
                                # replicated channel registers (chf/chv,
                                # [P,B,C]) and channel counters; device
                                # arrays then carry a leading P regions
                                # axis (see core/multifabric.py)

    @property
    def slots(self) -> int:
        return int(self.active.shape[0])

    def free_slots(self) -> list[int]:
        return [b for b in range(self.slots) if not self.active[b]]

    def quiesced_slots(self) -> list[int]:
        return [b for b in range(self.slots)
                if self.active[b] and self.quiesced[b]]


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3, 4, 5, 6))
def _slot_reset(fv, fl, full, val, ptr, out_last, out_count, mask,
                fv_rows, fl_rows, full0, val0):
    """Reset the masked slots to fresh initial state + new feed streams
    in ONE fused dispatch (an admission round, not one call per slot)."""
    m1 = mask[:, None]
    return (jnp.where(mask[:, None, None], fv_rows, fv),
            jnp.where(m1, fl_rows, fl),
            jnp.where(m1, full0[None], full),
            jnp.where(m1, val0[None], val),
            jnp.where(m1, 0, ptr),
            jnp.where(m1, 0, out_last),
            jnp.where(m1, 0, out_count))


@functools.partial(jax.jit, donate_argnums=(0,))
def _prof_reset(prof, mask):
    """Zero the masked slots' profile counters (one fused dispatch per
    admission round; only exists on profiled engines — kept out of
    :func:`_slot_reset` so the unprofiled path's dispatch signature and
    count are untouched)."""
    return tuple(
        jnp.where(mask.reshape((-1,) + (1,) * (x.ndim - 1)), 0, x)
        for x in prof)


def pack_feeds(input_arcs, feeds, token_shape=(), dtype=np.int32,
               pad_rows: int | None = None, min_len: int = 1):
    """Dense (feed_vals[n_in, L, *ts], feed_len[n_in]) from an arc->stream
    mapping.  Shared by every backend and by compile_cyclic.  pad_rows
    forces at least that many stream rows (the Pallas block kernel wants
    n_in >= 1); min_len floors L (so a stream axis always exists)."""
    feeds = dict(feeds or {})
    unknown = set(feeds) - set(input_arcs)
    if unknown:
        raise ValueError(f"feeds for non-input arcs: {sorted(unknown)}")
    ts = tuple(token_shape)
    n_in = max(len(input_arcs), pad_rows or 0)
    max_len = max((np.shape(v)[0] for v in feeds.values()), default=0)
    max_len = max(max_len, min_len)
    feed_vals = np.zeros((n_in, max_len, *ts), dtype)
    feed_len = np.zeros((n_in,), np.int32)
    for k, a in enumerate(input_arcs):
        if a in feeds:
            v = np.asarray(feeds[a], dtype)
            if v.shape[1:] != ts:
                v = np.broadcast_to(
                    v.reshape(v.shape[0], *([1] * len(ts))),
                    (v.shape[0], *ts)).astype(dtype)
            feed_vals[k, :v.shape[0]] = v
            feed_len[k] = v.shape[0]
    return feed_vals, feed_len


BACKENDS = ("xla", "pallas", "reference")


class DataflowEngine:
    """Cycle-accurate executor for a static dataflow :class:`Graph`.

    backend:
      * ``"xla"``       — vectorized jnp cycle body, ``lax.while_loop``
        over *blocks* of ``block_cycles`` fused cycles (one XLA dispatch
        per run).  Supports tensor tokens and any dtype.  Batched runs
        vmap the whole block loop.
      * ``"pallas"``    — the fused ``fire_block_pallas`` kernel: K
        cycles + environment feed/drain per device dispatch, arc state
        VMEM-resident within a block.  Scalar int32 tokens.  Batched
        runs use the explicit batch grid in the kernel (one dispatch
        for all B streams per block).
      * ``"reference"`` — the pure-numpy oracle (`run_reference`).

    All backends share one :func:`_plan` arc/state layout and report
    bit-identical outputs/counts/fired; ``cycles`` is reconstructed from
    the last progress cycle, so block-granular quiescence detection does
    not change the reported cycle count.
    """

    def __init__(self, graph: Graph, token_shape: tuple[int, ...] = (),
                 dtype=jnp.int32, max_cycles: int = 100_000,
                 backend: str = "xla", block_cycles: int = 1,
                 optimize: bool = False, profile: bool = False,
                 schedule: bool | str = False, partition=None):
        if backend not in BACKENDS:
            raise ValueError(f"backend {backend!r} not in {BACKENDS}")
        if block_cycles < 1:
            raise ValueError("block_cycles must be >= 1")
        self.graph = graph
        self.token_shape = tuple(token_shape)
        self.dtype = jnp.dtype(dtype)
        self.max_cycles = max_cycles
        self.backend = backend
        self.block_cycles = int(block_cycles)
        # optimize=True builds the opcode-class-specialized plan
        # (DESIGN.md §8): permuted node/arc tables + bucketed fire
        # bodies.  Pure layout change — results stay bit-identical.
        # (The reference backend is the oracle and always runs the
        # graph as authored.)
        self.optimize = bool(optimize)
        # profile=True accumulates the DESIGN.md §12 fabric counters in
        # device state alongside every run/block step.  Results stay
        # bit-identical; with profile=False the traced computations are
        # byte-for-byte the pre-observability ones (zero overhead, zero
        # extra dispatches).
        self.profile = bool(profile)
        # schedule: False/None = dynamic interpreter; "auto" = compile
        # the static firing schedule when the fabric is control-free
        # (DESIGN.md §13), dynamic otherwise; True = require the
        # schedule (raise naming the blockers if the fabric can't be
        # scheduled).  Scheduled execution stays bit-identical to the
        # dynamic engine in every reported field; a plan that fails to
        # lock onto a period in budget silently falls back to the
        # dynamic run path (a perf decision, never a semantic one).
        if schedule not in (False, None, True, "auto"):
            raise ValueError("schedule must be False, True, or 'auto', "
                             f"got {schedule!r}")
        self.schedule = schedule
        self._sched = None
        self._sched_on = False
        if schedule:
            from repro.core.schedule import schedule_blockers
            blockers = schedule_blockers(graph)
            if blockers and schedule is True:
                raise ValueError(
                    "schedule=True needs a statically schedulable "
                    f"fabric, but this one has: {', '.join(blockers)} "
                    "(use schedule='auto' to fall back dynamically)")
            self._sched_on = not blockers
        # partition: None/1 = solo fabric; int P / "auto" / Partition =
        # shard the graph into P regions (DESIGN.md §14) and run them as
        # communicating fabrics under shard_map (or a vmap'd shards axis
        # on a single device).  Every run/slot entry point delegates to
        # core/multifabric.py when engaged; results stay bit-identical
        # to the solo fabric in every field.
        self.partition = None
        self._mf = None
        if partition is not None:
            from repro.core.partition import resolve_partition
            self.partition = resolve_partition(graph, partition)
        self._part_on = (self.partition is not None
                         and self.partition.P > 1)
        if self._part_on:
            if backend == "reference":
                raise ValueError(
                    "partitioned execution needs a device backend "
                    "(xla or pallas), not 'reference' — the reference "
                    "oracle IS the solo fabric the shards are checked "
                    "against")
            if self.token_shape != ():
                raise ValueError(
                    "partitioned execution supports scalar tokens only")
            if schedule is True:
                raise ValueError(
                    "schedule=True cannot compose with partition > 1 "
                    "(regions run the dynamic cycle body; use "
                    "schedule='auto' to let partition win)")
            # regions execute the fused SPMD cycle body; the static
            # firing schedule is a whole-fabric single-device program
            self._sched_on = False
        self.p = _plan(graph, optimize=self.optimize)
        self._slot_steps: dict[int, object] = {}
        self._tables = None
        if self._part_on:
            pass    # multifabric builds its own per-region tables lazily
        elif backend == "pallas":
            if self.token_shape != () or self.dtype != jnp.int32:
                raise ValueError(
                    "pallas backend supports scalar int32 tokens only")
            self._tables = self._block_tables()
            self._steps: dict[tuple[int, bool], object] = {}
        else:
            self._run = jax.jit(self._run_impl,
                                static_argnames=("max_cycles",))
            self._vruns: dict[int, object] = {}

    def _mf_ctx(self):
        """Lazy per-engine multi-fabric runtime (DESIGN.md §14)."""
        if self._mf is None:
            from repro.core.multifabric import MultiFabric
            self._mf = MultiFabric(
                self.graph, self.partition, dtype=self.dtype,
                block_cycles=self.block_cycles, optimize=self.optimize,
                profile=self.profile, max_cycles=self.max_cycles)
        return self._mf

    def _block_tables(self):
        """Gather-layout node/arc/environment tables (built lazily for
        the xla backend, eagerly for pallas)."""
        if self._tables is None:
            from repro.kernels.dataflow_fire import block_plan_arrays
            self._tables = block_plan_arrays(self.graph,
                                             optimize=self.optimize)
        return self._tables

    def _sched_ctx(self):
        """Lazy per-engine schedule state (DESIGN.md §13)."""
        if self._sched is None:
            from repro.core.schedule import ScheduleContext
            self._sched = ScheduleContext(self.p, self.graph,
                                          self.token_shape, self.dtype)
        return self._sched

    # -- public ---------------------------------------------------------
    def run(self, feeds: Mapping[str, object] | None = None,
            max_cycles: int | None = None) -> EngineResult:
        """feeds: arc -> [k, *token_shape] stream of tokens (k may vary)."""
        max_cycles = max_cycles or self.max_cycles
        if self._part_on:
            return self._mf_ctx().run(feeds, max_cycles)
        if self._sched_on:
            from repro.core import schedule as _sched
            try:
                return _sched.run_scheduled(self, feeds, max_cycles)
            except _sched.ScheduleBail:
                pass        # pathological period: dynamic path below
        if self.backend == "reference":
            return run_reference(self.graph, feeds, self.token_shape,
                                 np.dtype(str(self.dtype)), max_cycles,
                                 profile=self.profile)
        if self.backend == "pallas":
            return self._run_pallas(feeds, max_cycles)
        p = self.p
        feed_vals, feed_len = pack_feeds(
            p["input_arcs"], feeds, self.token_shape, self.dtype)
        res = self._run(jnp.asarray(feed_vals), jnp.asarray(feed_len),
                        max_cycles=max_cycles)
        outs, counts, cycles, fired = res[:4]
        prof = None
        if self.profile:
            prof = (*jax.device_get(res[4:9]), int(res[9]), 1)
        return self._result_from_state(outs, counts, int(cycles),
                                       int(fired), dispatches=1,
                                       prof=prof)

    def run_batch(self, feeds_batch, max_cycles: int | None = None
                  ) -> list[EngineResult]:
        """Execute B independent token streams through one fabric.

        feeds_batch: sequence of B feed dicts (streams may have unequal
        lengths — shorter streams quiesce early and idle harmlessly).
        Returns one EngineResult per stream, bit-identical to running
        each stream alone."""
        max_cycles = max_cycles or self.max_cycles
        feeds_batch = list(feeds_batch)
        if not feeds_batch:
            raise ValueError(
                "run_batch: feeds_batch is empty — pass at least one "
                "feed dict (use run() for a single stream)")
        if self._part_on:
            return self._mf_ctx().run_batch(feeds_batch, max_cycles)
        if self._sched_on:
            from repro.core import schedule as _sched
            try:
                res = _sched.run_batch_scheduled(self, feeds_batch,
                                                 max_cycles)
            except _sched.ScheduleBail:
                res = None
            if res is not None:     # None: mixed feed lengths — the
                return res          # schedule is per-length; dynamic
                                    # path handles the ragged batch
        if self.backend == "reference":
            return [run_reference(self.graph, f, self.token_shape,
                                  np.dtype(str(self.dtype)), max_cycles,
                                  profile=self.profile)
                    for f in feeds_batch]
        p = self.p
        L = max((max((np.shape(v)[0] for v in (f or {}).values()),
                     default=0) for f in feeds_batch), default=0)
        L = max(L, 1)
        pad = 1 if self.backend == "pallas" else None
        packed = [pack_feeds(p["input_arcs"], f, self.token_shape,
                             self.dtype, pad_rows=pad, min_len=L)
                  for f in feeds_batch]
        feed_vals = np.stack([fv for fv, _ in packed])
        feed_len = np.stack([fl for _, fl in packed])
        if self.backend == "pallas":
            return self._run_pallas_batch(feed_vals, feed_len, max_cycles)
        vrun = self._vruns.get(max_cycles)
        if vrun is None:
            mc = max_cycles
            vrun = jax.jit(jax.vmap(
                lambda fv, fl: self._run_impl(fv, fl, max_cycles=mc)))
            self._vruns[max_cycles] = vrun
        res = vrun(jnp.asarray(feed_vals), jnp.asarray(feed_len))
        outs, counts, cycles, fired = res[:4]
        prof = jax.device_get(res[4:10]) if self.profile else None
        return [self._result_from_state(
            outs[b], counts[b], int(cycles[b]), int(fired[b]), dispatches=1,
            prof=None if prof is None else
            (*(x[b] for x in prof[:5]), int(prof[5][b]), 1))
            for b in range(len(feeds_batch))]

    def _result_from_state(self, out_last, out_count, cycles, fired,
                           dispatches, prof=None):
        """Per-arc result dicts from flat accumulators (all backends).

        prof: optional (nf, si, so, ab, ahw, profiled_cycles,
        dispatches) plan-order counter tuple — converted to a
        graph-order :class:`repro.obs.FabricProfile`."""
        out_arcs = self.p["output_arcs"]
        profile = node_fires = None
        if prof is not None:
            from repro.obs.profile import FabricProfile
            profile = FabricProfile.from_plan(self.graph, self.p,
                                              *prof[:5], cycles=prof[5],
                                              dispatches=prof[6])
            node_fires = profile.node_fires
        return EngineResult(
            outputs={a: out_last[i] for i, a in enumerate(out_arcs)},
            counts={a: int(out_count[i]) for i, a in enumerate(out_arcs)},
            cycles=cycles, fired=fired, dispatches=dispatches,
            node_fires=node_fires, profile=profile)

    # -- resumable slot API (continuous batching) ------------------------
    #
    # Lifecycle: init_state(B) -> all slots free; reset_slots() admits
    # requests into free slots; step_block() advances every *active*
    # slot by exactly block_cycles fabric cycles in one dispatch
    # (inactive slots are clock-gated out of feed/fire/drain);
    # harvest() extracts finished results and frees the slots.  Because
    # admissions happen only at block boundaries and each slot carries
    # its own cycle clock, a request's result is bit-identical to
    # running it alone via run() — see DESIGN.md §7.
    def _check_slot_api(self):
        if self.backend == "reference":
            raise ValueError("the resumable slot API needs a device "
                             "backend (xla or pallas), not 'reference'")
        if self.token_shape != () or self.dtype != jnp.int32:
            raise ValueError("the resumable slot API supports scalar "
                             "int32 tokens only")

    def _state0_rows(self):
        """(full0[A2], val0[A2]) int32 rows of a freshly-reset slot."""
        p = self.p
        full = np.zeros((p["A"] + 2,), np.int32)
        val = np.zeros((p["A"] + 2,), np.int32)
        full[p["FULL_PAD"]] = 1
        for a, v in self.graph.consts.items():
            full[p["aidx"][a]] = 1
            val[p["aidx"][a]] = int(v)
        for a, v in self.graph.inits.items():    # one-shot initial tokens
            full[p["aidx"][a]] = 1
            val[p["aidx"][a]] = int(v)
        return full, val

    def init_state(self, slots: int) -> SlotState:
        """Fresh B-slot state, every slot free (active == 0)."""
        self._check_slot_api()
        if slots < 1:
            raise ValueError("slots must be >= 1")
        if self._part_on:
            return self._mf_ctx().slot_init(int(slots))
        p = self.p
        B = int(slots)
        n_in = max(len(p["input_arcs"]), 1)
        n_out = max(len(p["output_arcs"]), 1)
        full0, val0 = self._state0_rows()
        z64 = lambda: np.zeros((B,), np.int64)
        return SlotState(
            fv=jnp.zeros((B, n_in, 1), jnp.int32),
            fl=jnp.zeros((B, n_in), jnp.int32),
            full=jnp.asarray(np.broadcast_to(full0, (B, full0.shape[0]))
                             .copy()),
            val=jnp.asarray(np.broadcast_to(val0, (B, val0.shape[0]))
                            .copy()),
            ptr=jnp.zeros((B, n_in), jnp.int32),
            out_last=jnp.zeros((B, n_out), jnp.int32),
            out_count=jnp.zeros((B, n_out), jnp.int32),
            active=np.zeros((B,), np.int32), base=z64(), last=z64(),
            fired=z64(), quiesced=np.zeros((B,), bool), dispatches=z64(),
            cap=np.full((B,), self.max_cycles, np.int64), stalled=z64(),
            active_dev=jnp.zeros((B,), jnp.int32),
            # profiled engines ride the counters in device state; the
            # slot steppers run on the kernel tables (N+1 node rows).
            # Scheduled engines reconstruct profiles on the host from
            # the plan instead (closed form — no device counters).
            prof=_prof_zeros(len(self.graph.nodes) + 1, p["A"] + 2,
                             batch=B)
            if self.profile and not self._sched_on else None,
            prof_cycles=z64() if self.profile else None,
            sched=self._make_slot_sched(B) if self._sched_on else None)

    def _make_slot_sched(self, slots: int):
        from repro.core.schedule import SlotSched
        return SlotSched(self._sched_ctx(), slots, self.profile)

    def _slot_step(self, n_cycles: int):
        """Jitted masked batched block step (backend-appropriate)."""
        if self.backend == "pallas":
            return self._pallas_step(n_cycles, True)
        step = self._slot_steps.get(n_cycles)
        if step is None:
            from repro.kernels import ref as _kref
            tables = self._block_tables()
            fn = functools.partial(
                _kref.fire_block_masked_prof_ref if self.profile
                else _kref.fire_block_masked_ref,
                tables, n_cycles=n_cycles)
            step = jax.jit(jax.vmap(fn))
            self._slot_steps[n_cycles] = step
        return step

    def reset_slots(self, state: SlotState, slot_ids,
                    new_feeds, caps=None) -> SlotState:
        """Admit one request per slot id: fresh arc registers + the new
        feed stream, in one fused dispatch for the whole round.  Slots
        must be free (never-used or harvested); everything else keeps
        its state untouched.

        caps: optional per-admission cycle caps (one entry per slot id;
        ``None`` entries fall back to the engine's ``max_cycles``) — a
        request-level budget the scheduler enforces by shortening
        blocks and ``harvest`` clamps cycle accounting to.

        MOVE semantics: the input state's device buffers are donated to
        the fused reset dispatch, so ``state`` (and any older SlotState
        sharing its buffers) must not be used again on backends that
        honor donation — always continue from the returned state."""
        self._check_slot_api()
        if self._part_on:
            return self._mf_ctx().slot_reset(state, slot_ids, new_feeds,
                                             caps)
        slot_ids = list(slot_ids)
        new_feeds = list(new_feeds)
        if len(slot_ids) != len(new_feeds):
            raise ValueError(f"{len(slot_ids)} slot ids but "
                             f"{len(new_feeds)} feed dicts")
        if not slot_ids:
            return state
        busy = [b for b in slot_ids if state.active[b]]
        if busy:
            raise ValueError(f"slots {busy} still hold unharvested "
                             "requests (harvest before refilling)")
        p = self.p
        B = state.slots
        packed = [pack_feeds(p["input_arcs"], f, (), np.int32, pad_rows=1)
                  for f in new_feeds]
        L = state.fv.shape[2]
        need = max((fv.shape[1] for fv, _ in packed), default=1)
        if need > L:        # grow the stream buffer (pow2 bounds retraces)
            L = 1 << (int(need) - 1).bit_length()
            state = dataclasses.replace(
                state, fv=jnp.pad(state.fv,
                                  ((0, 0), (0, 0), (0, L - state.fv.shape[2]))))
        n_in = state.fv.shape[1]
        mask = np.zeros((B,), bool)
        fv_rows = np.zeros((B, n_in, L), np.int32)
        fl_rows = np.zeros((B, n_in), np.int32)
        for b, (fv, fl) in zip(slot_ids, packed):
            mask[b] = True
            fv_rows[b, :, :fv.shape[1]] = fv
            fl_rows[b] = fl
        full0, val0 = self._state0_rows()
        fv_, fl_, full, val, ptr, out_last, out_count = _slot_reset(
            state.fv, state.fl, state.full, state.val, state.ptr,
            state.out_last, state.out_count, jnp.asarray(mask),
            jnp.asarray(fv_rows), jnp.asarray(fl_rows),
            jnp.asarray(full0), jnp.asarray(val0))
        if caps is None:
            caps = [None] * len(slot_ids)
        if len(caps) != len(slot_ids):
            raise ValueError(f"{len(slot_ids)} slot ids but "
                             f"{len(caps)} caps")
        active = state.active.copy()
        for host in (base := state.base.copy(), last := state.last.copy(),
                     fired := state.fired.copy(),
                     disp := state.dispatches.copy(),
                     stalled := state.stalled.copy()):
            host[slot_ids] = 0
        cap = state.cap.copy()
        for b, c in zip(slot_ids, caps):
            if c is not None and int(c) < 1:
                raise ValueError(f"slot {b}: cap must be >= 1, got {c}")
            cap[b] = self.max_cycles if c is None else int(c)
        quiesced = state.quiesced.copy()
        active[slot_ids] = 1
        quiesced[slot_ids] = False
        prof, prof_cycles = state.prof, state.prof_cycles
        if self.profile and prof is not None:
            prof = _prof_reset(prof, jnp.asarray(mask))
        if self.profile:
            prof_cycles = prof_cycles.copy()
            prof_cycles[slot_ids] = 0
        sched = state.sched
        if self._sched_on:
            if sched is None:
                sched = self._make_slot_sched(B)
            ctx = self._sched_ctx()
            n_real = len(p["input_arcs"])
            for b, (_, fl) in zip(slot_ids, packed):
                flen = tuple(int(x) for x in fl[:n_real])
                sched.reset(b, ctx.plan_for(flen))
        return SlotState(fv_, fl_, full, val, ptr, out_last, out_count,
                         active, base, last, fired, quiesced, disp,
                         cap=cap, stalled=stalled,
                         active_dev=jnp.asarray(active),
                         prof=prof, prof_cycles=prof_cycles,
                         sched=sched)

    def step_block(self, state: SlotState,
                   n_cycles: int | None = None) -> SlotState:
        """Advance every active slot by ``n_cycles`` (default
        ``block_cycles``) fabric cycles in ONE device dispatch; free
        slots are clock-gated out.  Per-slot clocks (base/last/fired)
        advance on the host; a slot whose block had an idle tail is
        marked ``quiesced`` (idle is absorbing — the request is done)."""
        self._check_slot_api()
        nb = self.block_cycles if n_cycles is None else int(n_cycles)
        if nb < 1:
            raise ValueError("n_cycles must be >= 1")
        if not state.active.any():
            return state
        if self._part_on:
            return self._mf_ctx().slot_step(state, nb)
        if self._sched_on:
            from repro.core import schedule as _sched
            return _sched.step_block_sched(self, state, nb)
        step = self._slot_step(nb)
        active_dev = state.active_dev if state.active_dev is not None \
            else jnp.asarray(state.active)
        if self.profile:
            res = step(state.fv, state.fl, state.full, state.val,
                       state.ptr, state.out_last, state.out_count,
                       active_dev, *state.prof)
            dev, f, lp, prof = res[:5], res[5], res[6], tuple(res[7:12])
        else:
            *dev, f, lp = step(state.fv, state.fl, state.full, state.val,
                               state.ptr, state.out_last, state.out_count,
                               active_dev)
            prof = state.prof
        f, lp = jax.device_get((f, lp))      # one host sync per block
        f = np.asarray(f).reshape(state.slots)
        lp = np.asarray(lp).reshape(state.slots)
        fired = state.fired + f
        last = np.where(lp > 0, state.base + lp, state.last)
        base = state.base + np.where(state.active > 0, nb, 0)
        quiesced = np.where(state.active > 0, lp < nb, state.quiesced)
        disp = state.dispatches + (state.active > 0)
        # progress counter: an active slot whose whole block was idle
        # stalls by one more block; any progress resets it.  A healthy
        # idle slot is harvested as quiesced the same heartbeat, so a
        # *growing* stall count means the quiescence signal is being
        # withheld — the watchdog's trigger (DESIGN.md §11).
        stalled = np.where(state.active > 0,
                           np.where(lp > 0, 0, state.stalled + 1),
                           state.stalled)
        prof_cycles = state.prof_cycles
        if self.profile and prof_cycles is not None:
            prof_cycles = prof_cycles + np.where(state.active > 0, nb, 0)
        return SlotState(state.fv, state.fl, *dev, state.active.copy(),
                         base, last, fired, quiesced, disp,
                         cap=state.cap, stalled=stalled,
                         active_dev=active_dev,
                         prof=prof, prof_cycles=prof_cycles,
                         sched=state.sched)

    def harvest(self, state: SlotState, slot_ids
                ) -> tuple[SlotState, list[EngineResult]]:
        """Extract the resident requests' EngineResults from the given
        (active) slots and free them.  Results follow the same
        accounting as run(): cycles = last progress cycle + 1 trailing
        idle cycle, capped at the slot's cycle cap (per-request if the
        admission set one); dispatches = blocks the request rode."""
        self._check_slot_api()
        if self._part_on:
            return self._mf_ctx().slot_harvest(state, slot_ids)
        slot_ids = list(slot_ids)
        idle = [b for b in slot_ids if not state.active[b]]
        if idle:
            raise ValueError(f"slots {idle} are free — nothing to harvest")
        out_last, out_count = jax.device_get((state.out_last,
                                              state.out_count))
        prof = jax.device_get(state.prof) if self.profile \
            and state.prof is not None else None

        def _prof_row(b):
            # scheduled engines accrue §12 counters on the host from the
            # plan (closed form); dynamic engines read the device rows
            if self.profile and self._sched_on and state.sched is not None:
                return (*state.sched.prof_row(b),
                        int(state.prof_cycles[b]),
                        int(state.dispatches[b]))
            if prof is None:
                return None
            return (*(x[b] for x in prof), int(state.prof_cycles[b]),
                    int(state.dispatches[b]))
        results = [self._result_from_state(
            out_last[b], out_count[b],
            int(min(state.last[b] + 1, state.cap[b])),
            int(state.fired[b]), int(state.dispatches[b]),
            prof=_prof_row(b))
            for b in slot_ids]
        active = state.active.copy()
        quiesced = state.quiesced.copy()
        active[slot_ids] = 0
        quiesced[slot_ids] = False
        return dataclasses.replace(state, active=active, quiesced=quiesced,
                                   active_dev=jnp.asarray(active)), results

    # -- pallas backend (host loop over fused blocks) --------------------
    def _pallas_step(self, n_cycles: int, batched: bool):
        """Jitted block step for a given size, compiled lazily and cached
        (the plan tables are built once in __init__ and shared).  Only
        two sizes ever occur per run: block_cycles and the final
        max_cycles remainder."""
        key = (n_cycles, batched)
        step = self._steps.get(key)
        if step is None:
            from repro.kernels import ops as _kops
            _, step = _kops.make_block_step(
                self.graph, n_cycles, batched=batched, tables=self._tables,
                profile=self.profile)
            self._steps[key] = step
        return step

    def _pallas_state0(self, batch: int | None = None):
        p = self.p
        n_in = max(len(p["input_arcs"]), 1)
        n_out = max(len(p["output_arcs"]), 1)
        full, val = self._state0_rows()
        state = (full, val, np.zeros((n_in,), np.int32),
                 np.zeros((n_out,), np.int32), np.zeros((n_out,), np.int32))
        if batch is not None:
            state = tuple(np.broadcast_to(x, (batch, *x.shape)).copy()
                          for x in state)
        return tuple(jnp.asarray(x) for x in state)

    def _run_pallas(self, feeds, max_cycles: int) -> EngineResult:
        p = self.p
        K = self.block_cycles
        fv, fl = pack_feeds(p["input_arcs"], feeds, (), np.int32,
                            pad_rows=1)
        fv, fl = jnp.asarray(fv), jnp.asarray(fl)
        state = self._pallas_state0()
        prof = _prof_zeros(len(self.graph.nodes) + 1, p["A"] + 2) \
            if self.profile else None
        base = last = fired = dispatches = 0
        while True:
            nb = min(K, max_cycles - base)  # never simulate past the cap
            if self.profile:
                res = self._pallas_step(nb, False)(fv, fl, *state, *prof)
                state, f, lp = res[:5], res[5], res[6]
                prof = tuple(res[7:12])
            else:
                *state, f, lp = self._pallas_step(nb, False)(fv, fl, *state)
                state = tuple(state)
            dispatches += 1
            fired += int(f[0])
            lp = int(lp[0])
            if lp > 0:
                last = base + lp
            base += nb
            if lp < nb or base >= max_cycles:
                break   # idle block tail => quiescent (idle is absorbing)
        cycles = min(last + 1, max_cycles)
        return self._result_from_state(
            state[3], state[4], cycles, fired, dispatches,
            prof=None if prof is None else
            (*jax.device_get(prof), base, dispatches))

    def _run_pallas_batch(self, feed_vals, feed_len,
                          max_cycles: int) -> list[EngineResult]:
        K = self.block_cycles
        B = feed_vals.shape[0]
        fv, fl = jnp.asarray(feed_vals), jnp.asarray(feed_len)
        state = self._pallas_state0(batch=B)
        prof = _prof_zeros(len(self.graph.nodes) + 1, self.p["A"] + 2,
                           batch=B) if self.profile else None
        base = dispatches = 0
        last = np.zeros((B,), np.int64)
        fired = np.zeros((B,), np.int64)
        ones = jnp.ones((B,), jnp.int32)
        while True:
            nb = min(K, max_cycles - base)  # never simulate past the cap
            if self.profile:
                res = self._pallas_step(nb, True)(fv, fl, *state, ones,
                                                  *prof)
                state, f, lp = res[:5], res[5], res[6]
                prof = tuple(res[7:12])
            else:
                *state, f, lp = self._pallas_step(nb, True)(fv, fl, *state,
                                                            ones)
                state = tuple(state)
            dispatches += 1
            fired += np.asarray(f)[:, 0]
            lp = np.asarray(lp)[:, 0]
            last = np.where(lp > 0, base + lp, last)
            base += nb
            if (lp < nb).all() or base >= max_cycles:
                break
        hprof = jax.device_get(prof) if prof is not None else None
        return [self._result_from_state(
            state[3][b], state[4][b],
            int(min(last[b] + 1, max_cycles)), int(fired[b]), dispatches,
            prof=None if hprof is None else
            (*(x[b] for x in hprof), base, dispatches))
            for b in range(B)]

    # -- implementation ---------------------------------------------------
    def _run_impl(self, feed_vals, feed_len, *, max_cycles):
        p = self.p
        A, ts, dtype = p["A"], self.token_shape, self.dtype
        opcode = jnp.asarray(p["opcode"])
        in_idx = jnp.asarray(p["in_idx"])
        out_idx = jnp.asarray(p["out_idx"])
        const_mask = jnp.asarray(p["const_mask"])
        in_arc_idx = jnp.asarray(
            [p["aidx"][a] for a in p["input_arcs"]], jnp.int32).reshape(-1)
        out_arc_idx = jnp.asarray(
            [p["aidx"][a] for a in p["output_arcs"]], jnp.int32).reshape(-1)

        full0 = jnp.zeros((A + 2,), bool).at[p["FULL_PAD"]].set(True)
        full0 = jnp.where(const_mask, True, full0)
        val0 = jnp.zeros((A + 2, *ts), dtype)
        for a, v in self.graph.consts.items():
            val0 = val0.at[p["aidx"][a]].set(jnp.asarray(v, dtype))
        # initial-token annotations: the arc starts full, one shot (not
        # re-asserted by const_mask, so a consumer drains it for good)
        for a, v in self.graph.inits.items():
            full0 = full0.at[p["aidx"][a]].set(True)
            val0 = val0.at[p["aidx"][a]].set(jnp.asarray(v, dtype))

        n_out = max(len(p["output_arcs"]), 1)
        state0 = dict(
            full=full0, val=val0,
            ptr=jnp.zeros((max(len(p["input_arcs"]), 1),), jnp.int32),
            out_last=jnp.zeros((n_out, *ts), dtype),
            out_count=jnp.zeros((n_out,), jnp.int32),
            cycles=jnp.int32(0), fired=jnp.int32(0),
            last_prog=jnp.int32(0),
            progress=jnp.bool_(True),
        )
        profile = self.profile
        if profile:
            nf0, si0, so0, ab0, ahw0 = _prof_zeros(len(self.graph.nodes),
                                                   A + 2)
            state0.update(nf=nf0, si=si0, so=so0, ab=ab0, ahw=ahw0)

        EMPTY_PAD = p["EMPTY_PAD"]
        FULL_PAD = p["FULL_PAD"]
        cs = p["class_slices"]

        def fire_rule_generic(full, val):
            """Dense fire rule: every opcode's ALU result for every node,
            selected by a ~20-way where-chain."""
            inf = full[in_idx]                       # [N,3]
            oute = ~full[out_idx]                    # [N,2]
            a = val[in_idx[:, 0]]
            b = val[in_idx[:, 1]]
            ctrl3 = _truthy(val[in_idx[:, 2]])       # dmerge control
            ctrl2 = _truthy(b)                       # branch control
            all_in = inf.all(axis=1)
            all_out = oute.all(axis=1)

            is_nd = opcode == int(Op.NDMERGE)
            is_dm = opcode == int(Op.DMERGE)
            is_br = opcode == int(Op.BRANCH)

            dm_chosen_full = jnp.where(ctrl3, inf[:, 0], inf[:, 1])
            ready = all_in & all_out
            ready = jnp.where(is_nd, (inf[:, 0] | inf[:, 1]) & all_out,
                              ready)
            ready = jnp.where(is_dm, inf[:, 2] & dm_chosen_full & all_out,
                              ready)
            ready = jnp.where(
                is_br,
                inf[:, 0] & inf[:, 1]
                & jnp.where(ctrl2, oute[:, 0], oute[:, 1]),
                ready)

            # operand/result values
            nd_val = jnp.where(_expand(inf[:, 0], ts), a, b)
            dm_val = jnp.where(_expand(ctrl3, ts), a, b)
            alu = _alu(Op, a, b, dtype)
            z = a  # default (COPY / BRANCH route a; SINK ignores)
            for op, r in alu.items():
                z = jnp.where(_expand(opcode == int(op), ts), r, z)
            z = jnp.where(_expand(is_nd, ts), nd_val, z)
            z = jnp.where(_expand(is_dm, ts), dm_val, z)

            # consumption mask [N,3]
            consume = ready[:, None] & jnp.ones((1, _MAX_IN), bool)
            nd_pick = jnp.stack([inf[:, 0], ~inf[:, 0],
                                 jnp.zeros_like(inf[:, 0])], axis=1)
            dm_pick = jnp.stack([ctrl3, ~ctrl3,
                                 jnp.ones_like(ctrl3)], axis=1)
            consume = jnp.where(is_nd[:, None], ready[:, None] & nd_pick,
                                consume)
            consume = jnp.where(is_dm[:, None], ready[:, None] & dm_pick,
                                consume)

            # production mask [N,2]
            produce = ready[:, None] & jnp.ones((1, _MAX_OUT), bool)
            br_pick = jnp.stack([ctrl2, ~ctrl2], axis=1)
            produce = jnp.where(is_br[:, None], ready[:, None] & br_pick,
                                produce)
            return ready, z, consume, produce

        _CTRL = (int(Op.NDMERGE), int(Op.DMERGE), int(Op.BRANCH))
        has_ctrl = cs is not None and any(op in _CTRL for op, _, _ in cs)

        def fire_rule_spec(full, val):
            """Opcode-class-specialized fire rule (DESIGN.md §8): nodes
            are bucketed by opcode in the plan, so a static Python loop
            over only the classes present computes each bucket's exact
            ALU result on its contiguous slice — no dense where-chain,
            and the shift/div guards exist only if SHL/SHR/DIV do.
            Control-free fabrics (every DAG bench) additionally keep
            the uniform ready/consume/produce masks as single whole-
            array ops: only the ALU result is bucketed."""
            inf = full[in_idx]                       # [N,3]
            oute = ~full[out_idx]                    # [N,2]
            a = val[in_idx[:, 0]]
            b = val[in_idx[:, 1]]
            all_in = inf.all(axis=1)
            all_out = oute.all(axis=1)
            base = all_in & all_out
            ones_i = jnp.ones((1, _MAX_IN), bool)
            ones_o = jnp.ones((1, _MAX_OUT), bool)
            if not has_ctrl:
                z_p = [_alu_op(Op(op), a[lo:hi], b[lo:hi], dtype)
                       for op, lo, hi in cs]
                z = z_p[0] if len(z_p) == 1 else jnp.concatenate(z_p)
                return (base, z, base[:, None] & ones_i,
                        base[:, None] & ones_o)
            r_p, z_p, c_p, p_p = [], [], [], []
            for opi, lo, hi in cs:
                op = Op(opi)
                ak, bk = a[lo:hi], b[lo:hi]
                infk, outek = inf[lo:hi], oute[lo:hi]
                if op == Op.NDMERGE:
                    rk = (infk[:, 0] | infk[:, 1]) & all_out[lo:hi]
                    zk = jnp.where(_expand(infk[:, 0], ts), ak, bk)
                    ck = rk[:, None] & jnp.stack(
                        [infk[:, 0], ~infk[:, 0],
                         jnp.zeros_like(infk[:, 0])], axis=1)
                    pk = rk[:, None] & ones_o
                elif op == Op.DMERGE:
                    c3 = _truthy(val[in_idx[lo:hi, 2]])
                    rk = (infk[:, 2]
                          & jnp.where(c3, infk[:, 0], infk[:, 1])
                          & all_out[lo:hi])
                    zk = jnp.where(_expand(c3, ts), ak, bk)
                    ck = rk[:, None] & jnp.stack(
                        [c3, ~c3, jnp.ones_like(c3)], axis=1)
                    pk = rk[:, None] & ones_o
                elif op == Op.BRANCH:
                    c2 = _truthy(bk)
                    rk = (infk[:, 0] & infk[:, 1]
                          & jnp.where(c2, outek[:, 0], outek[:, 1]))
                    zk = ak
                    ck = rk[:, None] & ones_i
                    pk = rk[:, None] & jnp.stack([c2, ~c2], axis=1)
                else:
                    rk = base[lo:hi]
                    zk = _alu_op(op, ak, bk, dtype)
                    ck = rk[:, None] & ones_i
                    pk = rk[:, None] & ones_o
                r_p.append(rk)
                z_p.append(zk)
                c_p.append(ck)
                p_p.append(pk)
            return (jnp.concatenate(r_p), jnp.concatenate(z_p),
                    jnp.concatenate(c_p), jnp.concatenate(p_p))

        fire_rule = fire_rule_spec if cs else fire_rule_generic

        def cycle(s):
            full, val = s["full"], s["val"]
            # --- 1. strobe environment input buses -----------------------
            if len(p["input_arcs"]):
                can_feed = (~full[in_arc_idx]) & (s["ptr"] < feed_len)
                nxt = jnp.take_along_axis(
                    feed_vals, s["ptr"].reshape(-1, 1, *([1] * len(ts))),
                    axis=1)[:, 0]
                tgt = jnp.where(can_feed, in_arc_idx, EMPTY_PAD)
                val = val.at[tgt].set(
                    jnp.where(can_feed.reshape(-1, *([1] * len(ts))),
                              nxt, val[tgt]))
                full = full.at[tgt].set(can_feed | full[tgt])
                ptr = s["ptr"] + can_feed
                fed_any = jnp.any(can_feed)
                full = full.at[EMPTY_PAD].set(False)
            else:
                ptr, fed_any = s["ptr"], jnp.bool_(False)

            # --- 2. fire every ready node --------------------------------
            if profile:
                # stall attribution reads the post-feed registers the
                # fire rule is about to see (ready ⊆ inputs_ready)
                ir = _node_inputs_ready(opcode, in_idx, full, val)
            ready, z, consume, produce = fire_rule(full, val)
            pvals = jnp.stack([z, z], axis=1)        # [N,2,*ts]

            # scatter: consume, then produce (see module docstring)
            cidx = jnp.where(consume, in_idx, EMPTY_PAD).reshape(-1)
            full = full.at[cidx].set(False)
            pidx = jnp.where(produce, out_idx, EMPTY_PAD).reshape(-1)
            full = full.at[pidx].set(True)
            val = val.at[pidx].set(pvals.reshape(-1, *ts))
            # restore dummy slots
            full = full.at[FULL_PAD].set(True)
            full = full.at[EMPTY_PAD].set(False)
            full = jnp.where(const_mask, True, full)

            if profile:
                # occupancy sample point: post-fire, pre-drain — a
                # produced output token counts busy the cycle it exists
                occ = full.astype(jnp.int32).at[FULL_PAD].set(0) \
                          .at[EMPTY_PAD].set(0)
                prof_upd = dict(
                    nf=s["nf"] + ready,
                    si=s["si"] + ~ir,
                    so=s["so"] + (ir & ~ready),
                    ab=s["ab"] + occ,
                    ahw=jnp.maximum(s["ahw"], occ))
            else:
                prof_upd = {}

            # --- 3. environment drains output buses ----------------------
            if len(p["output_arcs"]):
                got = full[out_arc_idx]
                out_last = jnp.where(_expand(got, ts), val[out_arc_idx],
                                     s["out_last"])
                out_count = s["out_count"] + got
                full = full.at[out_arc_idx].set(False)
                drained_any = jnp.any(got)
            else:
                out_last, out_count = s["out_last"], s["out_count"]
                drained_any = jnp.bool_(False)

            n_fired = jnp.sum(ready.astype(jnp.int32))
            prog = fed_any | drained_any | (n_fired > 0)
            return dict(
                full=full, val=val, ptr=ptr, out_last=out_last,
                out_count=out_count, cycles=s["cycles"] + 1,
                fired=s["fired"] + n_fired,
                last_prog=jnp.where(prog, s["cycles"] + 1, s["last_prog"]),
                progress=prog, **prof_upd)

        def block(s):
            # K fused cycles per while_loop iteration; quiescence is only
            # inspected at block granularity.  `progress` of the block's
            # LAST cycle decides continuation: an idle cycle is absorbing
            # (no feed/fire/drain can re-arm without one of the others),
            # so tail-idle == quiescent.
            return jax.lax.fori_loop(0, self.block_cycles,
                                     lambda i, s: cycle(s), s)

        def cond(s):
            # only admit blocks that fit entirely under the cap; the
            # max_cycles % K remainder runs below, so a cutoff simulates
            # EXACTLY max_cycles cycles (bit-identical fired/counts to
            # the per-cycle reference even mid-activity).
            return s["progress"] & (s["cycles"] + self.block_cycles
                                    <= max_cycles)

        s = jax.lax.while_loop(cond, block, state0)
        s = jax.lax.fori_loop(0, max_cycles % self.block_cycles,
                              lambda i, s: cycle(s), s)
        # reported cycles = last progress cycle + 1 trailing idle cycle,
        # exactly the per-cycle reference count, regardless of block
        # overrun past quiescence.
        cycles = jnp.minimum(s["last_prog"] + 1, max_cycles)
        if profile:
            # counters cover every SIMULATED cycle (s["cycles"]): block
            # overrun past quiescence adds idle cycles that fire nothing
            return (s["out_last"], s["out_count"], cycles, s["fired"],
                    s["nf"], s["si"], s["so"], s["ab"], s["ahw"],
                    s["cycles"])
        return s["out_last"], s["out_count"], cycles, s["fired"]


def _expand(mask, ts):
    return mask.reshape(*mask.shape, *([1] * len(ts)))


# ---------------------------------------------------------------------------
# Pure-numpy reference engine (oracle for property tests + Pallas kernel ref)
# ---------------------------------------------------------------------------
def alu_numpy(op, a, b, dtype):
    """Numpy mirror of the engine ALU — the reference engine's fire math
    and the constant-folding pass's compile-time evaluator (sharing one
    implementation keeps folded values bit-identical to fired ones).

    Integer overflow wraps two's-complement and float specials follow
    IEEE, exactly like the jax ALUs — numpy's over/invalid warnings are
    suppressed because that wrapping IS the contract (the fuzz harness
    feeds INT_MIN/INT_MAX operands on purpose).  Hot loops
    (:func:`run_reference`'s fire step) enter one errstate around the
    whole run and call :func:`_alu_numpy` directly instead of paying
    the context-manager round-trip per firing."""
    with np.errstate(all="ignore"):
        return _alu_numpy(op, a, b, dtype)


def _alu_numpy(op, a, b, dtype):
    is_int = np.issubdtype(dtype, np.integer)
    if op in (Op.COPY, Op.BRANCH, Op.SINK):
        return a
    if op == Op.ADD: return a + b
    if op == Op.SUB: return a - b
    if op == Op.MUL: return a * b
    if op == Op.DIV:
        return np.where(b == 0, 0, a // np.where(b == 0, 1, b)) if is_int \
            else np.where(b == 0, 0.0, a / np.where(b == 0, 1.0, b))
    if op == Op.AND:
        return (a & b) if is_int else ((a != 0) & (b != 0)).astype(dtype)
    if op == Op.OR:
        return (a | b) if is_int else ((a != 0) | (b != 0)).astype(dtype)
    if op == Op.XOR:
        return (a ^ b) if is_int else ((a != 0) ^ (b != 0)).astype(dtype)
    if op == Op.MAX:
        if is_int:
            return np.maximum(a, b)
        # match the jax ALUs' signed-zero tie: max(+0., -0.) is +0. in
        # either order, where np.maximum keeps b's zero
        return np.where((a == 0) & (b == 0), a + b, np.maximum(a, b))
    if op == Op.MIN:
        if is_int:
            return np.minimum(a, b)
        # dually min(+0., -0.) is -0. in either order
        return np.where((a == 0) & (b == 0), -(-a + -b), np.minimum(a, b))
    if op == Op.SHL:
        return (a << np.clip(b, 0, 31)) if is_int else a * np.exp2(b)
    if op == Op.SHR:
        if is_int:
            return a >> np.clip(b, 0, 31)
        two_b = np.exp2(b)
        return a / np.where(two_b == 0, 1, two_b)
    if op == Op.NOT: return (a == 0).astype(dtype)
    if op == Op.IFGT: return (a > b).astype(dtype)
    if op == Op.IFGE: return (a >= b).astype(dtype)
    if op == Op.IFLT: return (a < b).astype(dtype)
    if op == Op.IFLE: return (a <= b).astype(dtype)
    if op == Op.IFEQ: return (a == b).astype(dtype)
    if op == Op.IFDF: return (a != b).astype(dtype)
    raise AssertionError(op)



def run_reference(graph: Graph, feeds=None, token_shape=(), dtype=np.int32,
                  max_cycles: int = 100_000, trace=None,
                  profile: bool = False) -> EngineResult:
    """Slow, obviously-correct mirror of :class:`DataflowEngine`.

    trace: optional callback receiving (cycle, node_index, value) for
    every firing — used e.g. to extract pipeline schedules
    (core/pipeline.py).  profile=True additionally accumulates the
    DESIGN.md §12 fabric counters (the oracle for the device backends'
    profiled runs).  One errstate for the whole run: integer
    wraparound / float specials are the ALU contract (see
    :func:`alu_numpy`), and entering a context manager per firing
    would tax the per-node python loop."""
    with np.errstate(all="ignore"):
        return _run_reference(graph, feeds, token_shape, dtype,
                              max_cycles, trace, profile)


def _run_reference(graph, feeds, token_shape, dtype, max_cycles,
                   trace, profile=False) -> EngineResult:
    p = _plan(graph)
    feeds = {a: np.asarray(v, dtype).reshape(-1, *token_shape)
             if np.asarray(v).ndim == 1 and token_shape == ()
             else np.broadcast_to(
                 np.asarray(v, dtype).reshape(np.shape(v)[0],
                                              *([1] * len(token_shape))),
                 (np.shape(v)[0], *token_shape))
             if np.asarray(v).ndim == 1
             else np.asarray(v, dtype)
             for a, v in (feeds or {}).items()}
    full = {a: False for a in p["arcs"]}
    val = {a: np.zeros(token_shape, dtype) for a in p["arcs"]}
    for a, v in graph.consts.items():
        full[a] = True
        val[a] = np.full(token_shape, v, dtype)
    for a, v in graph.inits.items():    # one-shot initial tokens
        full[a] = True
        val[a] = np.full(token_shape, v, dtype)
    ptr = {a: 0 for a in p["input_arcs"]}
    out_last = {a: np.zeros(token_shape, dtype) for a in p["output_arcs"]}
    out_count = {a: 0 for a in p["output_arcs"]}

    def compute(op, a, b):
        return _alu_numpy(op, a, b, dtype)   # caller holds the errstate

    def truthy(v):
        return np.asarray(v).ravel()[0] != 0

    N = len(graph.nodes)
    if profile:
        nf = np.zeros((N,), np.int64)
        si = np.zeros((N,), np.int64)
        so = np.zeros((N,), np.int64)
        ab = np.zeros((len(p["arcs"]),), np.int64)
        ahw = np.zeros((len(p["arcs"]),), np.int64)

    def inputs_ready(n, sfull, sval):
        """Mirror of :func:`_node_inputs_ready` on the dict registers."""
        i = n.inputs
        if n.op == Op.NDMERGE:
            return sfull[i[0]] or sfull[i[1]]
        if n.op == Op.DMERGE:
            if not sfull[i[2]]:
                return False
            return sfull[i[0]] if truthy(sval[i[2]]) else sfull[i[1]]
        return all(sfull[x] for x in i)

    cycles = fired = 0
    progress = True
    while progress and cycles < max_cycles:
        progress = False
        # 1. feed
        for a in p["input_arcs"]:
            if not full[a] and a in feeds and ptr[a] < len(feeds[a]):
                val[a] = feeds[a][ptr[a]]
                full[a] = True
                ptr[a] += 1
                progress = True
        # 2. fire (simultaneous: snapshot)
        sfull = dict(full)
        sval = dict(val)
        plans = []
        for n_idx, n in enumerate(graph.nodes):
            i = n.inputs
            o = n.outputs
            if n.op == Op.NDMERGE:
                rdy = (sfull[i[0]] or sfull[i[1]]) and not sfull[o[0]]
                if rdy:
                    src = i[0] if sfull[i[0]] else i[1]
                    plans.append((n_idx, [src], [(o[0], sval[src])]))
            elif n.op == Op.DMERGE:
                if sfull[i[2]]:
                    src = i[0] if truthy(sval[i[2]]) else i[1]
                    if sfull[src] and not sfull[o[0]]:
                        plans.append((n_idx, [src, i[2]],
                                      [(o[0], sval[src])]))
            elif n.op == Op.BRANCH:
                if sfull[i[0]] and sfull[i[1]]:
                    dst = o[0] if truthy(sval[i[1]]) else o[1]
                    if not sfull[dst]:
                        plans.append((n_idx, list(i), [(dst, sval[i[0]])]))
            else:
                if all(sfull[x] for x in i) and not any(sfull[x] for x in o):
                    aop = sval[i[0]]
                    bop = sval[i[1]] if len(i) > 1 else aop
                    z = compute(n.op, aop, bop)
                    plans.append((n_idx, list(i), [(x, z) for x in o]))
        for n_idx, cons, prods in plans:
            for x in cons:
                full[x] = False
            for x, v in prods:
                full[x] = True
                val[x] = v
            if trace is not None:
                tv = prods[0][1] if prods else val.get(cons[0], 0)
                trace((cycles + 1, n_idx, int(np.asarray(tv).ravel()[0])))
            fired += 1
            progress = True
        for a in graph.consts:
            full[a] = True
        if profile:
            fired_set = {n_idx for n_idx, _, _ in plans}
            for n_idx, n in enumerate(graph.nodes):
                if n_idx in fired_set:
                    nf[n_idx] += 1
                elif inputs_ready(n, sfull, sval):
                    so[n_idx] += 1
                else:
                    si[n_idx] += 1
            # occupancy sample point: post-fire, pre-drain
            for k, a in enumerate(p["arcs"]):
                if full[a]:
                    ab[k] += 1
                    ahw[k] = 1
        # 3. drain
        for a in p["output_arcs"]:
            if full[a]:
                out_last[a] = val[a]
                out_count[a] += 1
                full[a] = False
                progress = True
        cycles += 1
    prof_obj = node_fires = None
    if profile:
        from repro.obs.profile import FabricProfile
        node_names, arc_names = FabricProfile.names_for(graph)
        prof_obj = FabricProfile(
            node_names=node_names, arc_names=arc_names,
            node_fires=nf, stall_in=si, stall_out=so,
            arc_busy=ab, arc_hw=ahw, cycles=cycles, dispatches=0)
        node_fires = nf
    return EngineResult(outputs=out_last, counts=out_count, cycles=cycles,
                        fired=fired, node_fires=node_fires,
                        profile=prof_obj)
