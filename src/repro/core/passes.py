"""Graph-optimizing compiler passes (DESIGN.md §8).

The paper's toolchain synthesizes a fabric containing *only* the
operators the graph actually uses; this module is the software half of
that specialization.  It rewrites a :class:`~repro.core.graph.Graph`
before plan construction, the way synchronous-dataflow compilers fold
static structure out of the runtime schedule:

* **constant folding** — a pure single-output value node (primitive /
  decider / NOT) whose inputs are all sticky const buses always produces
  the same token, so its output arc *becomes* a const bus and the
  operator is dropped from the fabric (evaluated with the engine's own numpy ALU,
  :func:`repro.core.engine.alu_numpy`, so folded values are bit-identical
  to fired ones at the target dtype);
* **identity elimination** — ``x op c`` where the const ``c`` makes the
  op a no-op at the target dtype (``+0 -0 |0 ^0 <<0 >>0 *1 /1`` for
  integer dtypes; only ``*1 /1`` for floats, where ``+0.0``/``-0.0``
  forms are not bit-exact on signed zeros) is spliced out of the wire;
* **dead-node/dead-arc elimination** — a *closed* region of nodes that
  cannot reach any output arc, and whose inputs come only from const
  buses or other dead nodes, is deleted along with its now-unreferenced
  arcs.  Regions fed by live producers are kept (removing the consumer
  would strand the producer's arc as a new environment-drained output),
  and so are regions fed by environment input arcs (deleting the arc
  would make the authored feed interface start rejecting valid feeds).

Contract (property-tested in tests/test_passes.py): for a fabric that
quiesces within ``max_cycles``, the rewritten graph drains bit-identical
last values *and token counts* on every surviving output arc.  ``cycles``
and ``fired`` may shrink — that is the point: the optimized fabric does
less work.  For full-field bit-identity (cycles/fired included) use the
*plan-level* opcode-class specialization alone
(``DataflowEngine(optimize=True)`` / ``compile_graph(optimize="spec")``),
which is a pure layout permutation.

**NDMERGE makes rewrites timing-sensitive — legality is REGION-SCOPED.**
NDMERGE arbitration picks whichever input token *arrives first* (tie:
a), so the winner depends on arc refill cadence, not just on values.
Folding replaces a periodically-refilled arc with an always-full const
bus, and an identity splice removes a one-token pipeline register
(tokens arrive a cycle earlier and the wire's capacity drops from two
tokens to one) — either can flip which input wins a race.  Backpressure
couples timing globally (a COPY whose outputs straddle two cones
propagates a stall from one into the other), so for a graph containing
a *racy* NDMERGE no cone-local guard is sound and the fold/identity
passes bail out entirely — the PR 3 position, unchanged.

The paper's **loop-entry** NDMERGE is different (DESIGN.md §10): its
non-cycle input delivers exactly one initiation token per run (an
initial-token annotation, or the single-shot feed contract that
``TracedProgram.make_feeds`` enforces on loop fabrics) and every later
token arrives on the back edge, *serialized by the cycle itself* — so
its output value sequence is arrival-timing-independent, and the Kahn
determinism argument that justified PR 3's rewrites extends to the
whole graph.  ``_loop_analysis`` classifies each NDMERGE structurally:
**loop-entry** iff the node lies on a directed cycle through exactly
one of its inputs; anything else (acyclic NDMERGE, or a merge with two
back edges) is **racy** and keeps the blanket bail-out.  When every
NDMERGE is a loop entry, fold/splice run *region-scoped*:

* nodes on directed cycles are never folded (impossible anyway — a
  cycle input is never const) and never spliced (the removed register
  is loop token capacity: blocking behavior would change);
* a node whose output arc feeds an NDMERGE input is never folded —
  turning the one-shot/periodic arc into an always-full const bus
  would re-fire the merge every refill window;
* arcs carrying initial-token annotations are never spliced away, and
  a fold never targets them (their producers sit on the back-edge
  cycle);
* everything else — the acyclic, merge-free cones before, after, and
  feeding the loop — folds/splices as in PR 3, because timing shifts
  on a loop's *initiation* path cannot flip its entry merge (there is
  no back-edge token to race until the initiation has happened).

DCE is unchanged — a removable region is disconnected from the live
fabric by construction, so deleting it cannot perturb a live merge
(and once a dead NDMERGE is deleted, later fixpoint rounds fold/splice
the now merge-free remainder).

The passes run to a joint fixpoint: folding a node can turn its
consumer into an identity, and splicing an identity can strand a dead
region.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.engine import alu_numpy
from repro.core.graph import (DECIDER_OPS, Graph, Node, Op,
                              PRIMITIVE_OPS)

# ops a constant-folder may evaluate at compile time: pure SINGLE-OUTPUT
# functions of their input values.  Control ops route/merge tokens and
# SINK is a drain whose presence affects quiescence, so they never fold.
# COPY is pure but has two outputs whose refill cadences are COUPLED by
# its firing rule (both must be empty): folding it to two independent
# always-full const buses removes that backpressure coupling and can
# even flip a quiescing fabric into a free-running one — so it stays.
_FOLDABLE = frozenset((*PRIMITIVE_OPS, *DECIDER_OPS, Op.NOT))

PASS_NAMES = ("fold", "identity", "dce")


@dataclasses.dataclass
class PassReport:
    """What the pipeline did to one graph."""
    nodes_before: int = 0
    nodes_after: int = 0
    arcs_before: int = 0
    arcs_after: int = 0
    folded: int = 0         # nodes evaluated at compile time
    identities: int = 0     # no-op nodes spliced out of the wire
    dead: int = 0           # unreachable nodes removed
    iterations: int = 0     # fixpoint rounds

    @property
    def changed(self) -> bool:
        return bool(self.folded or self.identities or self.dead)

    def summary(self) -> str:
        return (f"nodes {self.nodes_before}->{self.nodes_after}, "
                f"arcs {self.arcs_before}->{self.arcs_after} "
                f"(folded={self.folded}, identities={self.identities}, "
                f"dead={self.dead}, rounds={self.iterations})")


def _rebuild(graph: Graph, nodes: list[Node], consts: dict) -> Graph:
    g = Graph(name=graph.name)
    g.nodes = list(nodes)
    # drop consts/inits no longer referenced by any node: a const arc
    # with no consumer would otherwise surface as a new environment-
    # drained output bus (free-running token source), and an orphaned
    # initial-token annotation would fail validation
    used = {a for n in nodes for a in (*n.inputs, *n.outputs)}
    orig_out = set(graph.output_arcs())
    g.consts = {a: v for a, v in consts.items()
                if a in used or a in orig_out}
    g.inits = {a: v for a, v in graph.inits.items() if a in used}
    return g


def _const_value(consts, arc, dtype):
    return np.asarray(consts[arc], dtype).reshape(())


def _loop_analysis(graph: Graph) -> tuple[set[int], bool]:
    """-> (nodes on directed cycles, any RACY ndmerge present).

    An NDMERGE is a *loop entry* (race-free under the single-initiation
    contract, see module docstring) iff it lies on a directed cycle
    through exactly one of its inputs; every other NDMERGE — acyclic,
    or merged by two back edges — is racy."""
    cons = graph.consumers()
    N = len(graph.nodes)
    adj: list[list[int]] = [
        sorted({j for a in n.outputs for j in cons.get(a, [])})
        for n in graph.nodes]
    # iterative Tarjan SCC
    scc_id = [-1] * N
    low = [0] * N
    num = [-1] * N
    count = 0
    n_sccs = 0
    stack: list[int] = []
    on_stack = [False] * N
    for root in range(N):
        if num[root] != -1:
            continue
        work = [(root, 0)]
        while work:
            i, pi = work[-1]
            if pi == 0:
                num[i] = low[i] = count
                count += 1
                stack.append(i)
                on_stack[i] = True
            recursed = False
            for k in range(pi, len(adj[i])):
                j = adj[i][k]
                if num[j] == -1:
                    work[-1] = (i, k + 1)
                    work.append((j, 0))
                    recursed = True
                    break
                if on_stack[j]:
                    low[i] = min(low[i], num[j])
            if recursed:
                continue
            work.pop()
            if work:
                low[work[-1][0]] = min(low[work[-1][0]], low[i])
            if low[i] == num[i]:
                while True:
                    j = stack.pop()
                    on_stack[j] = False
                    scc_id[j] = n_sccs
                    if j == i:
                        break
                n_sccs += 1
    size = [0] * n_sccs
    for s in scc_id:
        size[s] += 1
    cyclic = {i for i in range(N)
              if size[scc_id[i]] > 1 or i in adj[i]}
    prod = graph.producers()
    racy = False
    for i, n in enumerate(graph.nodes):
        if n.op != Op.NDMERGE:
            continue
        if i not in cyclic:
            racy = True
            continue
        back_edges = sum(
            1 for a in n.inputs
            if any(scc_id[p] == scc_id[i] for p in prod.get(a, [])))
        if back_edges != 1:
            racy = True
    return cyclic, racy


def constant_fold(graph: Graph, dtype=np.int32) -> tuple[Graph, int]:
    """Fold every pure value node whose inputs are all const arcs; its
    output arcs become const buses carrying the compile-time result.
    Iterates so chains of constants collapse completely.

    Region-scoped legality (module docstring): bails out entirely when
    the graph contains a *racy* NDMERGE (a const bus is always full
    while the folded node refilled its arc periodically, and that
    cadence change can flip which input wins the arbitration race);
    with only loop-entry NDMERGEs it folds everywhere except nodes
    whose output arc feeds an NDMERGE input or carries an initial-token
    annotation — those arcs' token cadence IS the loop semantics."""
    _, racy = _loop_analysis(graph)
    if racy:
        return graph, 0
    merge_fed = {a for n in graph.nodes if n.op == Op.NDMERGE
                 for a in n.inputs}
    dtype = np.dtype(dtype)
    nodes = list(graph.nodes)
    consts = dict(graph.consts)
    folded = 0
    changed = True
    while changed:
        changed = False
        keep = []
        for n in nodes:
            if (n.op in _FOLDABLE
                    and all(a in consts for a in n.inputs)
                    and n.outputs[0] not in merge_fed
                    and n.outputs[0] not in graph.inits):
                a = _const_value(consts, n.inputs[0], dtype)
                b = (_const_value(consts, n.inputs[1], dtype)
                     if len(n.inputs) > 1 else a)
                z = alu_numpy(n.op, a, b, dtype)
                # store as the dtype's Python scalar: ints stay exact,
                # float32 round-trips bit-exactly through Python float
                consts[n.outputs[0]] = np.asarray(z, dtype).reshape(()).item()
                folded += 1
                changed = True
            else:
                keep.append(n)
        nodes = keep
    return _rebuild(graph, nodes, consts), folded


# op -> const operand value that makes `a op const` the identity on a.
# Only MUL/DIV hold for float dtypes: OR/XOR booleanize, SHL/SHR rescale
# through exp2's rounding, and ADD/SUB are not BIT-exact identities for
# signed zeros (-0.0 + 0.0 is +0.0, and the `== 0` match also accepts a
# -0.0 const, for which x - (-0.0) flips -0.0 to +0.0) — splicing them
# would break the bit-identical-last-values contract.
_IDENTITY_B = {
    Op.ADD: 0, Op.SUB: 0, Op.MUL: 1, Op.DIV: 1,
    Op.OR: 0, Op.XOR: 0, Op.SHL: 0, Op.SHR: 0,
}
_INT_ONLY_IDENTITIES = frozenset(
    (Op.ADD, Op.SUB, Op.OR, Op.XOR, Op.SHL, Op.SHR))


def eliminate_identities(graph: Graph, dtype=np.int32
                         ) -> tuple[Graph, int]:
    """Splice out ``z = a op c`` nodes where the const ``c`` makes the
    op a no-op, rewiring ``a``'s producer straight onto ``z`` (or ``z``'s
    consumer straight onto ``a`` when ``a`` is an environment input).
    Skips the splice when it would fuse an environment input directly to
    an environment output (both interface arcs must keep existing).

    Region-scoped legality (module docstring): bails out entirely when
    the graph contains a *racy* NDMERGE (the spliced node was a
    one-token pipeline register; removing it shifts arrival timing a
    cycle earlier and can flip the race).  With only loop-entry
    NDMERGEs it splices everywhere except nodes on directed cycles
    (the lost register is loop token capacity — blocking behavior
    would change) and wires carrying initial-token annotations."""
    cyclic_nodes, racy = _loop_analysis(graph)
    if racy:
        return graph, 0
    dtype = np.dtype(dtype)
    is_int = np.issubdtype(dtype, np.integer)
    producers = graph.producers()
    consumers = graph.consumers()
    nodes = list(graph.nodes)
    consts = dict(graph.consts)
    removed = 0
    for i, n in enumerate(nodes):
        if n is None or n.op not in _IDENTITY_B:
            continue
        if i in cyclic_nodes:
            continue
        if n.inputs[0] in graph.inits or n.outputs[0] in graph.inits:
            continue
        if not is_int and n.op in _INT_ONLY_IDENTITIES:
            continue
        b_arc = n.inputs[1]
        if b_arc not in consts:
            continue
        want = _IDENTITY_B[n.op]
        # compare at the execution dtype, no int() truncation: 0.5 is
        # NOT the additive identity even though int(0.5) == 0
        if not bool(_const_value(consts, b_arc, dtype)
                    == np.asarray(want, dtype)):
            continue
        x, o = n.inputs[0], n.outputs[0]
        if x in consts:
            continue            # all-const case belongs to the folder
        prod = producers.get(x, [])
        if prod:
            # internal wire: x's producer writes o directly
            j = prod[0]
            m = nodes[j]
            nodes[j] = Node(m.op, m.inputs,
                            tuple(o if a == x else a for a in m.outputs),
                            m.name)
            producers[o] = [j]
        else:
            # x is an environment input: o's consumer reads x directly
            cons = consumers.get(o, [])
            if not cons:
                continue        # input->output splice would drop an arc
            j = cons[0]
            m = nodes[j]
            nodes[j] = Node(m.op,
                            tuple(x if a == o else a for a in m.inputs),
                            m.outputs, m.name)
            consumers[x] = [j]
        nodes[i] = None
        removed += 1
    return _rebuild(graph, [n for n in nodes if n is not None],
                    consts), removed


def eliminate_dead(graph: Graph) -> tuple[Graph, int]:
    """Remove closed dead regions: nodes with no path to any output arc
    whose every input is a const or another dead node.  (A dead node
    can never feed a live one — feeding a live node is a path to an
    output — so only incoming crossings matter.)

    Two kinds of dead nodes are deliberately KEPT: nodes fed by a live
    producer (removing the consumer would strand the producer's arc as
    a new environment-drained output), and nodes fed by an environment
    *input* arc (removing them would delete the input arc, so feeds
    that were valid for the authored graph would start raising in
    ``pack_feeds`` — the optimized fabric must accept the authored
    feed interface unchanged)."""
    consumers = graph.consumers()
    out_arcs = set(graph.output_arcs())
    input_arcs = set(graph.input_arcs())
    # liveness: reverse reachability from the output arcs
    live = [any(o in out_arcs for o in n.outputs) for n in graph.nodes]
    changed = True
    while changed:
        changed = False
        for i, n in enumerate(graph.nodes):
            if not live[i]:
                if any(live[c] for o in n.outputs
                       for c in consumers.get(o, [])):
                    live[i] = True
                    changed = True
    # closed region: drop dead nodes not fed by a live producer and not
    # fed by an environment input arc
    producers = graph.producers()
    removable = [not lv and not any(a in input_arcs for a in n.inputs)
                 for lv, n in zip(live, graph.nodes)]
    changed = True
    while changed:
        changed = False
        for i, n in enumerate(graph.nodes):
            if removable[i] and any(
                    not removable[p] for a in n.inputs
                    for p in producers.get(a, [])):
                removable[i] = False
                changed = True
    kept = [n for i, n in enumerate(graph.nodes) if not removable[i]]
    dead = len(graph.nodes) - len(kept)
    return _rebuild(graph, kept, graph.consts), dead


def optimize_graph(graph: Graph, dtype=np.int32,
                   passes=PASS_NAMES) -> tuple[Graph, PassReport]:
    """Run the rewrite pipeline to a joint fixpoint.

    Returns ``(optimized_graph, report)``.  The input graph is never
    mutated.  ``dtype`` is the execution dtype the folded constants are
    evaluated at (folding at the wrong width would change wrapped
    results)."""
    unknown = set(passes) - set(PASS_NAMES)
    if unknown:
        raise ValueError(f"unknown passes {sorted(unknown)}; "
                         f"pick from {PASS_NAMES}")
    report = PassReport(nodes_before=len(graph.nodes),
                        arcs_before=len(graph.arcs))
    g = graph
    for _ in range(max(len(graph.nodes), 1)):
        report.iterations += 1
        before = (len(g.nodes), len(g.arcs), len(g.consts))
        if "fold" in passes:
            g, k = constant_fold(g, dtype)
            report.folded += k
        if "identity" in passes:
            g, k = eliminate_identities(g, dtype)
            report.identities += k
        if "dce" in passes:
            g, k = eliminate_dead(g)
            report.dead += k
        if (len(g.nodes), len(g.arcs), len(g.consts)) == before:
            break
    g.validate()
    report.nodes_after = len(g.nodes)
    report.arcs_after = len(g.arcs)
    return g, report
