"""One compile pipeline for static dataflow graphs (DESIGN.md §10).

:func:`compile` is the single entry point.  It probes the graph's
capabilities (:class:`GraphTraits`: cyclic? control operators?
initial-token annotations?) and selects an executor — replacing the
scattered per-function op-set checks the stack grew across PRs 1–4:

* ``"dag"``      — lockstep SSA: nodes scheduled in topological order
  into a pure function, ``vmap`` over the token stream (the TPU
  analogue of the paper's spatial pipelining).  Legal only when
  ``traits.tokens_out_static`` — acyclic, control-free, init-free — so
  every stream element fires every node exactly once.
* ``"unrolled"`` — token-presence SSA: the engine cycle unrolled over
  arcs at trace time; arc registers become loop-carried SSA values and
  every fire/consume/produce a masked ``jnp.where``.  Handles cycles,
  BRANCH/NDMERGE/DMERGE, and initial tokens, bit-identical to
  :class:`repro.core.engine.DataflowEngine` (property-tested).
* ``"xla" | "pallas" | "reference"`` — the cycle-accurate block-fused
  engines (resumable slots, batching, serving).
* ``"auto"``     — ``"dag"`` when the traits allow it, else
  ``"unrolled"`` (the historical ``compile_graph`` dispatch).

``compile_fn`` goes one step earlier: it traces an ordinary scalar jax
program (loops included — the frontend lowers ``lax.while_loop`` /
``fori_loop`` / carry-only ``scan`` onto the paper's cyclic loop
schema) through :mod:`repro.front` and hands the synthesized fabric to
the same probe.  ``compile_graph`` / ``compile_cyclic`` remain as thin
deprecated wrappers over :func:`compile` and the unrolled executor.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph, Op
from repro.core.engine import BACKENDS, EngineResult, _alu, pack_feeds


def _scalar_alu(op: Op, a, b, dtype):
    return _alu(Op, a, b, dtype)[op]


def _truthy1(v):
    return jnp.reshape(v, (-1,))[0] != 0


# ---------------------------------------------------------------------------
# Capability probe
# ---------------------------------------------------------------------------
_CONTROL_OPS = (Op.BRANCH, Op.NDMERGE, Op.DMERGE)


@dataclasses.dataclass(frozen=True)
class GraphTraits:
    """What a fabric demands of its executor (the :func:`compile` probe).

    cyclic       — the graph has feedback arcs (the paper's loop schema).
    control_ops  — names of token-routing operators present.  DMERGE
      counts: it consumes only its CHOSEN input token, so under
      data-dependent control the input streams advance unevenly — only
      token-presence execution reproduces that.
    has_inits    — initial-token annotations (one-shot pre-loaded arc
      registers, the loop back-edge delays of DESIGN.md §10).

    ``tokens_out_static`` is the lockstep property the "dag" executor
    needs: every stream element fires every node exactly once, so each
    output arc drains exactly one token per input element and the token
    counts are static in the stream length.
    """
    cyclic: bool
    control_ops: tuple[str, ...]
    has_inits: bool

    @classmethod
    def probe(cls, graph: Graph) -> "GraphTraits":
        return cls(
            cyclic=graph.is_cyclic(),
            control_ops=tuple(sorted({n.op.name for n in graph.nodes
                                      if n.op in _CONTROL_OPS})),
            has_inits=bool(graph.inits))

    @property
    def tokens_out_static(self) -> bool:
        return not (self.cyclic or self.control_ops or self.has_inits)

    def blockers(self) -> str:
        """The trait names that rule out lockstep execution."""
        why = []
        if self.cyclic:
            why.append("cyclic=True")
        if self.control_ops:
            why.append(f"control_ops={list(self.control_ops)}")
        if self.has_inits:
            why.append("has_inits=True")
        return ", ".join(why) or "none"


# ---------------------------------------------------------------------------
# DAG (lockstep SSA) executor
# ---------------------------------------------------------------------------
def compile_dag(graph: Graph, dtype=jnp.int32):
    """Return ``fn(inputs: dict) -> dict`` evaluating the fabric once.

    Supports primitive/decider/copy/dmerge/sink nodes.  ``branch`` and
    ``ndmerge`` (and initial-token annotations) need token-presence
    semantics — use the unrolled executor or an engine backend.
    Note ``dmerge`` here is a pure per-element select (both inputs
    advance together); that matches the engine only when every stream
    element fires every node once, which is why :func:`compile`'s
    auto dispatch sends DMERGE-bearing graphs to the unrolled executor.
    """
    order = graph.try_topo_order()
    if order is None:
        raise ValueError(f"{graph.name}: cyclic — use compile_cyclic")
    if graph.inits:
        raise ValueError(
            f"{graph.name}: initial-token annotations (has_inits) need "
            "token-presence semantics — use the unrolled executor")
    for n in graph.nodes:
        if n.op in (Op.BRANCH, Op.NDMERGE):
            raise ValueError(
                f"{graph.name}: {n.op.name} requires the cyclic backend")
    input_arcs = graph.input_arcs()
    output_arcs = graph.output_arcs()
    nodes = [graph.nodes[i] for i in order]
    consts = dict(graph.consts)

    def fn(inputs: Mapping[str, object]) -> dict:
        env = {a: jnp.asarray(v, dtype) for a, v in consts.items()}
        for a in input_arcs:
            env[a] = jnp.asarray(inputs[a], dtype)
        for n in nodes:
            a = env[n.inputs[0]]
            if n.op == Op.COPY:
                env[n.outputs[0]] = a
                env[n.outputs[1]] = a
            elif n.op == Op.SINK:
                pass
            elif n.op == Op.DMERGE:
                c = _truthy1(env[n.inputs[2]])
                env[n.outputs[0]] = jnp.where(c, a, env[n.inputs[1]])
            else:
                b = env[n.inputs[1]] if len(n.inputs) > 1 else a
                env[n.outputs[0]] = _scalar_alu(n.op, a, b, dtype)
        return {a: env[a] for a in output_arcs}

    return fn


def compile_dag_stream(graph: Graph, dtype=jnp.int32):
    """vmap the DAG fabric over a token stream (throughput mode)."""
    fn = compile_dag(graph, dtype)
    return jax.jit(lambda feeds: jax.vmap(fn)(feeds))


# ---------------------------------------------------------------------------
# Unrolled (token-presence SSA) executor
# ---------------------------------------------------------------------------
def compile_cyclic(graph: Graph, token_shape=(), dtype=jnp.int32,
                   max_cycles: int = 100_000):
    """Return ``fn(feeds: dict[str, [k,*ts] stream]) -> EngineResult``.

    This is the "unrolled" executor of :func:`compile` (kept under its
    historical name as a deprecated public entry point — new code
    should call ``compile(graph, backend="unrolled")``)."""
    graph.validate()
    ts = tuple(token_shape)
    dtype = jnp.dtype(dtype)
    arcs = graph.arcs
    input_arcs = graph.input_arcs()
    output_arcs = graph.output_arcs()
    consts = dict(graph.consts)
    inits = dict(graph.inits)
    nodes = list(graph.nodes)

    def run(feeds: Mapping[str, object], max_cycles: int = max_cycles):
        fv, fl = pack_feeds(input_arcs, feeds, ts, dtype)
        out_last, out_count, cycles, fired = _compiled(
            jnp.asarray(fv), jnp.asarray(fl), max_cycles)
        return EngineResult(
            outputs={a: out_last[i] for i, a in enumerate(output_arcs)},
            counts={a: int(out_count[i]) for i, a in enumerate(output_arcs)},
            cycles=int(cycles), fired=int(fired))

    @functools.partial(jax.jit, static_argnums=(2,))
    def _compiled(feed_vals, feed_len, max_cycles):
        zero = jnp.zeros(ts, dtype)
        full0 = {a: jnp.bool_(a in consts or a in inits) for a in arcs}
        val0 = {a: (jnp.asarray(np.broadcast_to(consts[a], ts), dtype)
                    if a in consts else
                    jnp.asarray(np.broadcast_to(inits[a], ts), dtype)
                    if a in inits else zero) for a in arcs}
        state0 = dict(
            full=full0, val=val0,
            ptr=jnp.zeros((max(n_in_ := len(input_arcs), 1),), jnp.int32),
            out_last=[zero] * max(len(output_arcs), 1),
            out_count=jnp.zeros((max(len(output_arcs), 1),), jnp.int32),
            cycles=jnp.int32(0), fired=jnp.int32(0),
            progress=jnp.bool_(True))

        def cycle(s):
            full, val = dict(s["full"]), dict(s["val"])
            progress = jnp.bool_(False)
            # 1. strobe inputs
            ptr = s["ptr"]
            for k, a in enumerate(input_arcs):
                can = (~full[a]) & (ptr[k] < feed_len[k])
                nxt = jax.lax.dynamic_index_in_dim(
                    feed_vals[k], jnp.clip(ptr[k], 0, feed_vals.shape[1] - 1),
                    keepdims=False)
                val[a] = jnp.where(can, nxt, val[a])
                full[a] = full[a] | can
                ptr = ptr.at[k].add(can.astype(jnp.int32))
                progress = progress | can
            # 2. fire all ready nodes against the cycle-start snapshot
            sfull, sval = dict(full), dict(val)
            consumed = {a: jnp.bool_(False) for a in arcs}
            produced = {a: jnp.bool_(False) for a in arcs}
            pval = dict(sval)
            n_fired = jnp.int32(0)
            for n in nodes:
                i, o = n.inputs, n.outputs
                a_v = sval[i[0]]
                if n.op == Op.NDMERGE:
                    rdy = (sfull[i[0]] | sfull[i[1]]) & ~sfull[o[0]]
                    pick0 = sfull[i[0]]
                    consumed[i[0]] |= rdy & pick0
                    consumed[i[1]] |= rdy & ~pick0
                    produced[o[0]] |= rdy
                    pval[o[0]] = jnp.where(
                        rdy, jnp.where(pick0, a_v, sval[i[1]]), pval[o[0]])
                elif n.op == Op.DMERGE:
                    c = _truthy1(sval[i[2]])
                    rdy = (sfull[i[2]]
                           & jnp.where(c, sfull[i[0]], sfull[i[1]])
                           & ~sfull[o[0]])
                    consumed[i[0]] |= rdy & c
                    consumed[i[1]] |= rdy & ~c
                    consumed[i[2]] |= rdy
                    produced[o[0]] |= rdy
                    pval[o[0]] = jnp.where(
                        rdy, jnp.where(c, a_v, sval[i[1]]), pval[o[0]])
                elif n.op == Op.BRANCH:
                    c = _truthy1(sval[i[1]])
                    rdy = (sfull[i[0]] & sfull[i[1]]
                           & jnp.where(c, ~sfull[o[0]], ~sfull[o[1]]))
                    consumed[i[0]] |= rdy
                    consumed[i[1]] |= rdy
                    produced[o[0]] |= rdy & c
                    produced[o[1]] |= rdy & ~c
                    pval[o[0]] = jnp.where(rdy & c, a_v, pval[o[0]])
                    pval[o[1]] = jnp.where(rdy & ~c, a_v, pval[o[1]])
                else:
                    rdy = functools.reduce(
                        jnp.logical_and, [sfull[x] for x in i],
                        jnp.bool_(True))
                    for x in o:
                        rdy = rdy & ~sfull[x]
                    for x in i:
                        consumed[x] |= rdy
                    if n.op == Op.COPY:
                        z = a_v
                    elif n.op == Op.SINK:
                        z = a_v
                    else:
                        z = _scalar_alu(n.op, a_v,
                                        sval[i[1]] if len(i) > 1 else a_v,
                                        dtype)
                    for x in o:
                        produced[x] |= rdy
                        pval[x] = jnp.where(rdy, z, pval[x])
                n_fired = n_fired + rdy.astype(jnp.int32)
            for a in arcs:
                if a in consts:
                    full[a] = jnp.bool_(True)
                else:
                    full[a] = (sfull[a] & ~consumed[a]) | produced[a]
                val[a] = jnp.where(produced[a], pval[a], sval[a])
            progress = progress | (n_fired > 0)
            # 3. drain outputs
            out_last = list(s["out_last"])
            out_count = s["out_count"]
            for k, a in enumerate(output_arcs):
                got = full[a]
                out_last[k] = jnp.where(got, val[a], out_last[k])
                out_count = out_count.at[k].add(got.astype(jnp.int32))
                full[a] = jnp.bool_(False)
                progress = progress | got
            return dict(full=full, val=val, ptr=ptr, out_last=out_last,
                        out_count=out_count, cycles=s["cycles"] + 1,
                        fired=s["fired"] + n_fired, progress=progress)

        s = jax.lax.while_loop(
            lambda s: s["progress"] & (s["cycles"] < max_cycles),
            cycle, state0)
        return (jnp.stack(s["out_last"]), s["out_count"], s["cycles"],
                s["fired"])

    return run


OPTIMIZE_LEVELS = (False, "spec", "full", True, "sched")
BACKENDS_NOTE = "xla | pallas | reference"
EXECUTORS = ("auto", "dag", "unrolled", *BACKENDS)


def compile(graph: Graph, token_shape=(), dtype=jnp.int32,     # noqa: A001
            max_cycles: int = 100_000, backend: str = "auto",
            block_cycles: int = 16, optimize=False,
            profile: bool = False, partition=None):
    """THE compile pipeline: probe traits, pick a legal executor +
    optimize level, return ``run(feeds) -> EngineResult`` (or the
    vmapped stream fn for the "dag" executor).

    backend:
      * ``"auto"``     — ``"dag"`` when ``GraphTraits.tokens_out_static``
        holds, else ``"unrolled"`` (the historical shape-directed
        dispatch, now trait-driven);
      * ``"dag"``      — lockstep stream-vmapped SSA
        (``compile_dag_stream``).  Raises, naming the blocking traits,
        for any graph that needs token-presence semantics — asking for
        lockstep on such a fabric would silently compute wrong token
        counts, not a slower right answer;
      * ``"unrolled"`` — trace-time unrolled token-presence SSA
        (``compile_cyclic``): cycles, control ops, initial tokens;
      * any :data:`repro.core.engine.BACKENDS` name — a cycle-accurate
        block-fused engine callable (plus ``.engine`` exposing the
        resumable slot API and ``run_batch``).

    optimize selects the compiler pipeline (DESIGN.md §8):
      * ``False``  — run the graph exactly as authored;
      * ``"spec"`` — opcode-class-specialized plan only: a pure layout
        permutation, every EngineResult field bit-identical to the
        unoptimized engine.  Engine backends only (the SSA executors
        have no plan to specialize);
      * ``True`` / ``"full"`` — graph rewrite passes (region-scoped
        constant folding, identity elimination, DCE;
        :func:`repro.core.passes.optimize_graph` — loop regions and
        their timing are left untouched) *then* the specialized plan
        where a plan exists.  For fabrics that quiesce the surviving
        output arcs drain bit-identical values and token counts while
        ``cycles``/``fired`` may shrink;
      * ``"sched"`` — everything ``"full"`` does, plus static firing
        schedules (DESIGN.md §13): when the rewritten graph is
        statically schedulable (``GraphTraits.tokens_out_static``) the
        engine compiles the per-cycle fire sets out of the run loop —
        no ready-mask reduction — and falls back to the dynamic engine
        otherwise (cyclic / control-bearing fabrics, §10).  Engine
        backends only, bit-identical results either way.

    profile=True turns on the DESIGN.md §12 fabric counters: every
    EngineResult carries ``node_fires`` and a
    :class:`repro.obs.FabricProfile`.  Engine backends only — the SSA
    executors have no fabric to count, so asking is an error, not a
    silent no-op.

    partition shards the fabric across regions (DESIGN.md §14):
      * ``None``   — single fabric (default);
      * ``int P``  — :func:`repro.core.partition.partition_graph` splits
        the (post-rewrite) graph into P cost-balanced regions, never
        cutting a loop cycle;
      * ``"auto"`` — :func:`repro.core.partition.auto_partition` picks P
        from the device count and graph size;
      * a :class:`repro.core.partition.Partition` — used as given
        (validated).
    A resolved P>1 partition needs a cycle-accurate engine: with
    ``backend="auto"`` the probe routes to the ``"xla"`` engine instead
    of the SSA executors; asking for ``"dag"``/``"unrolled"`` raises.
    Execution stays bit-identical to the single-fabric engine in every
    EngineResult field.  P=1 (or an ``"auto"`` resolution of 1) is a
    pass-through to the ordinary engine.

    The returned callable exposes the (possibly rewritten) graph as
    ``.graph``, the rewrite report as ``.report`` (None when no
    rewrites ran), the capability probe as ``.traits``, and the
    resolved partition (or None) as ``.partition``.
    """
    if block_cycles < 1:
        raise ValueError(
            f"block_cycles must be >= 1, got {block_cycles}")
    if optimize not in OPTIMIZE_LEVELS:
        raise ValueError(f"optimize {optimize!r} not in {OPTIMIZE_LEVELS}")
    if backend not in EXECUTORS:
        raise ValueError(f"backend {backend!r} not in {EXECUTORS}")
    if optimize in ("spec", "sched") and backend in ("auto", "dag",
                                                     "unrolled"):
        # specialization/scheduling is plan-level; the SSA executors
        # have no plan, so either would silently measure an
        # unoptimized runner
        raise ValueError(
            f'optimize={optimize!r} needs an engine backend '
            f'({BACKENDS_NOTE}); backend={backend!r} only supports the '
            'rewrite pipeline (optimize="full"/True)')
    if profile and backend not in BACKENDS and not (
            backend == "auto" and partition is not None):
        # (auto + partition defers: a resolved P>1 routes to the engine)
        raise ValueError(
            f"profile=True needs an engine backend ({BACKENDS_NOTE}); "
            f"backend={backend!r} runs SSA semantics with no fabric "
            "cycles to count")
    report = None
    if optimize in (True, "full", "sched"):
        from repro.core import passes
        graph, report = passes.optimize_graph(graph, dtype=np.dtype(
            str(jnp.dtype(dtype))))
    traits = GraphTraits.probe(graph)
    part = None
    if partition is not None:
        # resolve against the post-rewrite graph: node indices in the
        # assignment must name the fabric that actually runs
        from repro.core.partition import resolve_partition
        part = resolve_partition(graph, partition)
    if part is not None and part.P > 1:
        if backend in ("dag", "unrolled"):
            raise ValueError(
                f"{graph.name}: partition={partition!r} needs a "
                f"cycle-accurate engine backend ({BACKENDS_NOTE}); the "
                f"{backend!r} SSA executor has no fabric to shard")
        if backend == "auto":
            backend = "xla"
    if backend == "auto":
        backend = "dag" if traits.tokens_out_static else "unrolled"
        if profile and backend not in BACKENDS:
            # the deferred check above: partition resolved to P=1, so
            # auto landed on an SSA executor after all
            raise ValueError(
                f"profile=True needs an engine backend ({BACKENDS_NOTE});"
                f" backend={backend!r} runs SSA semantics with no fabric "
                "cycles to count")
    if backend == "dag" and not traits.tokens_out_static:
        raise ValueError(
            f"{graph.name}: backend='dag' runs lockstep SSA semantics "
            f"(one firing per node per stream element), but the "
            f"GraphTraits probe found {traits.blockers()} — these need "
            f"token-presence execution: backend='unrolled' or an "
            f"engine backend ({BACKENDS_NOTE})")
    if backend in BACKENDS:
        from repro.core.engine import DataflowEngine
        eng = DataflowEngine(graph, token_shape, dtype, max_cycles,
                             backend=backend, block_cycles=block_cycles,
                             optimize=optimize is not False,
                             profile=profile,
                             schedule="auto" if optimize == "sched"
                             else False, partition=part)
        run = lambda feeds, max_cycles=None: eng.run(feeds, max_cycles)
        run.engine = eng
    elif backend == "unrolled":
        # DMERGE joins BRANCH/NDMERGE in needing this executor:
        # compile_dag's DMERGE is a pure per-element select (both input
        # streams advance in lockstep), but the engine's DMERGE
        # consumes only the CHOSEN input token, so the streams advance
        # unevenly under data-dependent control — only token-presence
        # execution reproduces that
        run = compile_cyclic(graph, token_shape, dtype, max_cycles)
    else:
        fn = compile_dag_stream(graph, dtype)
        run = lambda feeds: fn(feeds)   # jit fns reject new attributes
    run.graph = graph
    run.report = report
    run.traits = traits
    run.partition = part
    return run


def compile_graph(graph: Graph, token_shape=(), dtype=jnp.int32,
                  max_cycles: int = 100_000, backend: str = "auto",
                  block_cycles: int = 16, optimize=False,
                  profile: bool = False, partition=None):
    """Deprecated name for :func:`compile` (kept as a thin wrapper —
    the historical PR 1–4 entry point).  New code should call
    ``compile`` directly."""
    return compile(graph, token_shape, dtype, max_cycles, backend,
                   block_cycles, optimize, profile, partition)


def compile_fn(fn, *avals, backend: str = "xla", block_cycles: int = 16,
               optimize=False, max_cycles: int = 100_000,
               name: str | None = None, const_args: dict | None = None,
               profile: bool = False):
    """Trace a scalar jax program (:func:`repro.front.trace`) and hand
    the synthesized fabric to :func:`compile` in one step.

    The fabric is routed through the :class:`GraphTraits` probe like
    any other graph, so a traced program that needs token-presence
    semantics (loops, ``jnp.where`` control, initial tokens) either
    gets an executor that provides them (the default ``backend="xla"``
    engine and ``"auto"`` both do) or a precise error naming the
    blocking trait — never a silently-lockstep compilation.

    Returns the executor callable with the frontend bookkeeping
    attached: ``run.make_feeds(*streams)`` is the positional feed
    adapter, ``run.out_arcs`` the result arcs in return order,
    ``run.traced`` the :class:`~repro.front.TracedProgram` as authored
    (``run.graph`` is the post-rewrite fabric when ``optimize`` folds
    it).  The execution dtype is the avals' common dtype::

        run = compile_fn(lambda x, y: jnp.where(x > y, x - y, y - x),
                         np.int32, np.int32,
                         backend="pallas", optimize="full")
        res = run(run.make_feeds([5, 1], [2, 9]))
        res.outputs[run.out_arcs[0]]        # -> 8 (last token)
    """
    from repro.front import trace
    prog = trace(fn, *avals, name=name, const_args=const_args)
    run = compile(prog, token_shape=(),
                  dtype=jnp.dtype(str(prog.dtype)),
                  max_cycles=max_cycles, backend=backend,
                  block_cycles=block_cycles, optimize=optimize,
                  profile=profile)
    run.traced = prog
    run.make_feeds = prog.make_feeds
    run.out_arcs = list(prog.out_arcs)
    return run
