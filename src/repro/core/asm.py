"""Assembler language for dataflow graphs (paper Listing 1).

Syntax, one node per statement::

    [lineno.] opcode arg, arg, ... ;     # comment

Arguments are arc labels: inputs first, then outputs, per the opcode
arity (e.g. ``add s10, dadoe, s11`` reads s10 and dadoe, writes s11;
``branch s9, s8, s10, pf`` reads data s9 and control s8, writes t-output
s10 and f-output pf; ``dmerge s2, dadoc, s1, s3`` reads a=s2, b=dadoc,
ctrl=s1, writes s3).

``const <arc> = <int>;`` declares a sticky environment bus (the FPGA input
bus that always presents its value, e.g. the `dadoe` increment in the
paper's Fibonacci graph).
"""
from __future__ import annotations

import re

from repro.core.graph import ARITY, Graph, Op

_ALIASES = {
    "gtdecider": Op.IFGT,
    "gedecider": Op.IFGE,
    "ltdecider": Op.IFLT,
    "ledecider": Op.IFLE,
    "eqdecider": Op.IFEQ,
    "dfdecider": Op.IFDF,
}

_STMT = re.compile(r"^(?:\d+\s*\.)?\s*(\w+)\s+(.*)$")


def parse(text: str, name: str = "asm") -> Graph:
    g = Graph(name=name)
    # strip comments, split on ';'
    lines = []
    for raw in text.splitlines():
        raw = raw.split("#", 1)[0].split("//", 1)[0]
        lines.append(raw)
    for stmt in " ".join(lines).split(";"):
        stmt = stmt.strip()
        if not stmt:
            continue
        m = _STMT.match(stmt)
        if not m:
            raise SyntaxError(f"bad statement: {stmt!r}")
        opname, rest = m.group(1).lower(), m.group(2)
        if opname == "const":
            arc, _, val = rest.partition("=")
            g.const(arc.strip(), int(val.strip(), 0))
            continue
        if opname in _ALIASES:
            op = _ALIASES[opname]
        else:
            try:
                op = Op[opname.upper()]
            except KeyError:
                raise SyntaxError(f"unknown opcode {opname!r} in {stmt!r}")
        args = [a.strip() for a in rest.split(",") if a.strip()]
        n_in, n_out = ARITY[op]
        if len(args) != n_in + n_out:
            raise SyntaxError(
                f"{opname} wants {n_in}+{n_out} args, got {args!r}")
        g.add(op, args[:n_in], args[n_in:])
    g.validate()
    return g


def emit(g: Graph) -> str:
    """Graph -> assembler text (round-trips through :func:`parse`)."""
    out = []
    for arc, val in g.consts.items():
        out.append(f"const {arc} = {int(val)};")
    for i, n in enumerate(g.nodes, start=1):
        args = ", ".join((*n.inputs, *n.outputs))
        out.append(f"{i}. {n.op.name.lower()} {args};")
    return "\n".join(out) + "\n"
