"""Assembler language for dataflow graphs (paper Listing 1).

Syntax, one node per statement::

    [lineno.] opcode arg, arg, ... ;     # comment

Arguments are arc labels: inputs first, then outputs, per the opcode
arity (e.g. ``add s10, dadoe, s11`` reads s10 and dadoe, writes s11;
``branch s9, s8, s10, pf`` reads data s9 and control s8, writes t-output
s10 and f-output pf; ``dmerge s2, dadoc, s1, s3`` reads a=s2, b=dadoc,
ctrl=s1, writes s3).

``const <arc> = <number>;`` declares a sticky environment bus (the FPGA
input bus that always presents its value, e.g. the `dadoe` increment in
the paper's Fibonacci graph).  Values may be integers (any Python int
literal base) or floats — float fabrics from the tracing frontend
(:mod:`repro.front`) carry non-integral coefficients, and ``emit`` must
round-trip them exactly for the serving layer's signature cache.

``init <arc> = <number>;`` declares an *initial-token annotation*
(DESIGN.md §10): the arc starts full with the given one-shot value —
the synchronous-dataflow delay marking on a loop back-edge register.
Cyclic fabrics synthesized by the loop-lowering frontend carry these,
so they must survive serialize/deserialize like everything else (the
serving signature cache hashes the emission).

Errors: malformed statements, unknown opcodes, wrong argument counts,
bad/duplicate const declarations raise :class:`SyntaxError` naming the
offending statement; structural violations (an arc with two producers
or two receivers, a const arc that is also produced) surface as the
:class:`ValueError` of :meth:`repro.core.graph.Graph.validate`.
"""
from __future__ import annotations

import re

import numpy as np

from repro.core.graph import ARITY, Graph, Op

_ALIASES = {
    "gtdecider": Op.IFGT,
    "gedecider": Op.IFGE,
    "ltdecider": Op.IFLT,
    "ledecider": Op.IFLE,
    "eqdecider": Op.IFEQ,
    "dfdecider": Op.IFDF,
}

_STMT = re.compile(r"^(?:\d+\s*\.)?\s*(\w+)\s+(.*)$")


def _parse_const(raw: str, stmt: str):
    """int (any base) or float const value; SyntaxError otherwise."""
    try:
        return int(raw, 0)
    except ValueError:
        try:
            return float(raw)
        except ValueError:
            raise SyntaxError(
                f"bad const value {raw!r} in {stmt!r}") from None


def _emit_const(val) -> str:
    """Round-trippable text for a const value: ints (and integral
    floats, which cast identically at any execution dtype) as ints,
    everything else through repr — float32-exact, and -0.0 / inf / nan
    keep their bit patterns."""
    if isinstance(val, (int, np.integer)):
        return str(int(val))
    f = float(val)
    if f.is_integer() and not (f == 0.0 and np.signbit(f)):
        return str(int(f))
    return repr(f)


def parse(text: str, name: str = "asm") -> Graph:
    g = Graph(name=name)
    # strip comments, split on ';'
    lines = []
    for raw in text.splitlines():
        raw = raw.split("#", 1)[0].split("//", 1)[0]
        lines.append(raw)
    for stmt in " ".join(lines).split(";"):
        stmt = stmt.strip()
        if not stmt:
            continue
        m = _STMT.match(stmt)
        if not m:
            raise SyntaxError(f"bad statement: {stmt!r}")
        opname, rest = m.group(1).lower(), m.group(2)
        if opname in ("const", "init"):
            arc, eq, val = rest.partition("=")
            arc, val = arc.strip(), val.strip()
            if not eq or not arc or not val:
                raise SyntaxError(
                    f"bad {opname} declaration {stmt!r} "
                    f"(want '{opname} <arc> = <number>;')")
            decls = g.consts if opname == "const" else g.inits
            if arc in decls:
                raise SyntaxError(f"{opname} arc {arc!r} redeclared "
                                  f"in {stmt!r}")
            if arc in g.consts or arc in g.inits:
                raise SyntaxError(
                    f"arc {arc!r} declared both const and init "
                    f"in {stmt!r}")
            decls[arc] = _parse_const(val, stmt)
            continue
        if opname in _ALIASES:
            op = _ALIASES[opname]
        else:
            try:
                op = Op[opname.upper()]
            except KeyError:
                raise SyntaxError(f"unknown opcode {opname!r} in {stmt!r}")
        args = [a.strip() for a in rest.split(",") if a.strip()]
        n_in, n_out = ARITY[op]
        if len(args) != n_in + n_out:
            raise SyntaxError(
                f"{opname} wants {n_in}+{n_out} args, got {args!r}")
        g.add(op, args[:n_in], args[n_in:])
    g.validate()
    return g


def emit(g: Graph) -> str:
    """Graph -> assembler text (round-trips through :func:`parse`)."""
    out = []
    for arc, val in g.consts.items():
        out.append(f"const {arc} = {_emit_const(val)};")
    for arc, val in g.inits.items():
        out.append(f"init {arc} = {_emit_const(val)};")
    for i, n in enumerate(g.nodes, start=1):
        args = ", ".join((*n.inputs, *n.outputs))
        out.append(f"{i}. {n.op.name.lower()} {args};")
    return "\n".join(out) + "\n"
