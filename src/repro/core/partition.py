"""Cost-model-balanced graph partitioning for multi-fabric sharding.

Splits one :class:`~repro.core.graph.Graph` into P *regions* so that
:mod:`repro.core.multifabric` can run each region as an independent
fabric on its own device, with every inter-region arc carried by a
token channel (DESIGN.md §14).  The segmentation follows netlist
partitioning practice (the connected-component / cost analysis used on
the 6502 netlist in the related repos): weight every node by a
per-opcode *fire cost*, charge a *cut penalty* for every crossing arc,
and search for an assignment that balances region weight while
minimizing cut arcs.

Legality rule — **never cut a loop cycle**.  A depth-1 handshake arc
inside a loop carries the loop's recurrence; splitting it across a
channel boundary would serialize the loop on inter-device latency and,
worse, make region quiescence detection circular.  Tarjan SCCs are
therefore collapsed into atomic *supernodes* before any assignment: a
cyclic loop core always lands whole in one region, so a cut arc always
connects two distinct SCCs.  This is enforced by construction and
re-checked by :meth:`Partition.validate`.

The cost model reuses the graph IR's ``LUT_WEIGHT`` table (the
Table-1 resource analogue): an operator's combinational datapath
complexity is the best static proxy for its per-fire work, exactly the
expression-complexity weighting the netlist segmentation uses.
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from repro.core.graph import Graph, LUT_WEIGHT, Op

# Per-opcode fire cost (region balance weight).  LUT complexity is the
# resource analogue the repo already trusts for Table 1; a fired node
# costs its datapath, an idle node costs (almost) nothing, so balancing
# summed LUT weight balances worst-case per-cycle region work.
FIRE_COST: dict[Op, int] = dict(LUT_WEIGHT)

# Cost charged per cut arc, in FIRE_COST units.  A crossing arc costs a
# channel slot exchange every block; 32 ≈ two ADD datapaths keeps the
# partitioner from shaving single nodes off regions just to balance.
CUT_PENALTY = 32.0

# auto partitioning declines to shard tiny fabrics: below this many
# nodes per region the per-cycle channel merge dwarfs the region work.
MIN_AUTO_REGION_NODES = 8


@dataclasses.dataclass(frozen=True)
class Partition:
    """An assignment of every node to one of P regions.

    ``assign[i]`` is the region id of ``graph.nodes[i]``.  The spec
    string (region count + assignment hash) is the cache-key component
    :func:`repro.serve.dataflow_server.cached_engine` uses, so a
    sharded and an unsharded compile of the same fabric signature never
    alias one engine.
    """

    P: int
    assign: tuple[int, ...]

    def regions(self) -> list[list[int]]:
        out: list[list[int]] = [[] for _ in range(self.P)]
        for i, r in enumerate(self.assign):
            out[r].append(i)
        return out

    def spec(self) -> str:
        """``P:assignment-hash`` — the partition's cache-key identity."""
        h = hashlib.sha256(
            np.asarray(self.assign, np.int64).tobytes()).hexdigest()[:12]
        return f"{self.P}:{h}"

    def cut_arcs(self, graph: Graph) -> list[str]:
        """Arcs whose producer and consumer live in different regions
        (graph arc order)."""
        prod = {a: ns[0] for a, ns in graph.producers().items()}
        cons = graph.consumers()
        cut = []
        for a in graph.arcs:
            if a in prod and a in cons and a not in graph.consts:
                if self.assign[prod[a]] != self.assign[cons[a][0]]:
                    cut.append(a)
        return cut

    def region_weights(self, graph: Graph) -> list[int]:
        w = [0] * self.P
        for i, n in enumerate(graph.nodes):
            w[self.assign[i]] += FIRE_COST[n.op]
        return w

    def validate(self, graph: Graph) -> None:
        """Raise unless this is a valid cover of ``graph``:

        * every node in exactly one region ``0 <= r < P``;
        * every region non-empty;
        * no cut arc closes a loop cycle (producer and consumer of a
          crossing arc must belong to different SCCs).
        """
        if len(self.assign) != len(graph.nodes):
            raise ValueError(
                f"partition covers {len(self.assign)} nodes but the graph "
                f"has {len(graph.nodes)}")
        seen = set(self.assign)
        if seen - set(range(self.P)):
            raise ValueError(f"region ids {sorted(seen)} outside 0..{self.P - 1}")
        if len(seen) != self.P:
            missing = sorted(set(range(self.P)) - seen)
            raise ValueError(f"empty regions {missing} (every region must "
                             "hold at least one node)")
        scc = _scc_ids(graph)
        prod = {a: ns[0] for a, ns in graph.producers().items()}
        cons = graph.consumers()
        for a in graph.arcs:
            if a in graph.consts or a not in prod or a not in cons:
                continue
            p, c = prod[a], cons[a][0]
            if self.assign[p] != self.assign[c] and scc[p] == scc[c]:
                raise ValueError(
                    f"arc {a!r} is cut but lies on a loop cycle "
                    f"(nodes {p} and {c} share an SCC) — loop cycles "
                    "must never cross a channel boundary")


def _node_edges(graph: Graph) -> list[tuple[int, int, str]]:
    """(producer, consumer, arc) node-level edges (const buses excluded:
    they have no producer node and are replicated, never cut)."""
    prod = {a: ns[0] for a, ns in graph.producers().items()}
    cons = graph.consumers()
    edges = []
    for a in graph.arcs:
        if a in graph.consts or a not in prod or a not in cons:
            continue
        edges.append((prod[a], cons[a][0], a))
    return edges


def _scc_ids(graph: Graph) -> list[int]:
    """Tarjan SCC ids per node (iterative — netlist-sized graphs would
    blow the recursion limit)."""
    n = len(graph.nodes)
    adj: list[list[int]] = [[] for _ in range(n)]
    for p, c, _ in _node_edges(graph):
        adj[p].append(c)
    index = [-1] * n
    low = [0] * n
    on_stack = [False] * n
    stack: list[int] = []
    ids = [-1] * n
    counter = 0
    n_scc = 0
    for root in range(n):
        if index[root] != -1:
            continue
        work = [(root, 0)]
        while work:
            v, pi = work[-1]
            if pi == 0:
                index[v] = low[v] = counter
                counter += 1
                stack.append(v)
                on_stack[v] = True
            advanced = False
            for k in range(pi, len(adj[v])):
                w = adj[v][k]
                if index[w] == -1:
                    work[-1] = (v, k + 1)
                    work.append((w, 0))
                    advanced = True
                    break
                if on_stack[w]:
                    low[v] = min(low[v], index[w])
            if advanced:
                continue
            work.pop()
            if low[v] == index[v]:
                while True:
                    w = stack.pop()
                    on_stack[w] = False
                    ids[w] = n_scc
                    if w == v:
                        break
                n_scc += 1
            if work:
                u, _ = work[-1]
                low[u] = min(low[u], low[v])
    return ids


def _condense(graph: Graph):
    """Collapse SCCs into supernodes: returns (scc ids, member lists,
    weights, inter-supernode edge multiset, topological order,
    locality order)."""
    ids = _scc_ids(graph)
    n_scc = max(ids) + 1 if ids else 0
    members: list[list[int]] = [[] for _ in range(n_scc)]
    weights = [0] * n_scc
    for i, n in enumerate(graph.nodes):
        members[ids[i]].append(i)
        weights[ids[i]] += FIRE_COST[n.op]
    edges: list[tuple[int, int]] = []
    for p, c, _ in _node_edges(graph):
        if ids[p] != ids[c]:
            edges.append((ids[p], ids[c]))
    # Kahn topological order over the condensation (always a DAG)
    indeg = [0] * n_scc
    succ: list[list[int]] = [[] for _ in range(n_scc)]
    for p, c in set(edges):
        succ[p].append(c)
        indeg[c] += 1
    ready = sorted(s for s in range(n_scc) if indeg[s] == 0)
    order: list[int] = []
    while ready:
        s = ready.pop(0)
        order.append(s)
        for t in sorted(succ[s]):
            indeg[t] -= 1
            if indeg[t] == 0:
                ready.append(t)
    # locality order for segmentation: post-order DFS over producers
    # from each sink, so a reduce subtree or an independent lane is
    # emitted contiguously and a contiguous chunk starts with
    # near-minimal crossing arcs.  (The Kahn order above interleaves
    # parallel structures — segmenting it would cut every lane of a
    # parallel fabric.)
    preds: list[list[int]] = [[] for _ in range(n_scc)]
    for p, c in sorted(set(edges)):
        preds[c].append(p)
    sinks = sorted(s for s in range(n_scc) if not succ[s])
    seen = [False] * n_scc
    lorder: list[int] = []
    for root in sinks + list(range(n_scc)):
        if seen[root]:
            continue
        seen[root] = True
        stack = [(root, 0)]
        while stack:
            v, pi = stack[-1]
            if pi < len(preds[v]):
                stack[-1] = (v, pi + 1)
                w = preds[v][pi]
                if not seen[w]:
                    seen[w] = True
                    stack.append((w, 0))
            else:
                stack.pop()
                lorder.append(v)
    return ids, members, weights, edges, order, lorder


def partition_graph(graph: Graph, P: int, *,
                    cut_penalty: float = CUT_PENALTY,
                    refine_rounds: int = 8) -> Partition:
    """Balanced min-cut assignment of ``graph`` into ``P`` regions.

    Two phases over the SCC condensation (supernodes are atomic, so no
    loop cycle can be cut):

    1. *Segmentation*: walk the condensation in producer-first DFS
       post-order (subtrees and independent lanes come out contiguous)
       and close a region whenever its accumulated fire cost reaches
       the balance target — contiguous chunks of that order start with
       few crossing arcs by construction (zero for parallel lanes).
    2. *Refinement*: greedy single-supernode moves; a move is taken
       when it lowers ``cut_penalty * cut_arcs + imbalance`` (imbalance
       is the sum of squared region weights, minimized when balanced)
       and leaves no region empty.  Deterministic: supernodes are
       visited in topological order, candidate regions in id order.
    """
    n = len(graph.nodes)
    if P < 1:
        raise ValueError(f"partition P must be >= 1, got {P}")
    if n == 0:
        raise ValueError("cannot partition an empty graph")
    if P == 1:
        return Partition(1, tuple([0] * n))
    ids, members, weights, edges, order, lorder = _condense(graph)
    if P > len(order):
        raise ValueError(
            f"{graph.name}: P={P} exceeds the {len(order)} atomic "
            "supernodes (loop cycles are never cut, so a fabric cannot "
            "be split finer than its SCC condensation)")

    total = float(sum(weights))
    # phase 1: contiguous segmentation of the locality order by prefix
    # cost (regions need not be topologically convex — the lockstep
    # channel exchange is direction-agnostic, so only cut count and
    # balance matter)
    sassign = [0] * len(order)
    region = 0
    done = 0.0      # weight already sealed into closed regions
    acc = 0.0       # weight of the currently-open region
    for k, s in enumerate(lorder):
        remaining_supers = len(lorder) - k
        remaining_regions = P - region
        # every remaining region must still receive >= 1 supernode
        must_close = remaining_supers <= remaining_regions and acc > 0
        target = total * (region + 1) / P
        if region < P - 1 and (must_close or done + acc >= target):
            region += 1
            done += acc
            acc = 0.0
        sassign[s] = region
        acc += weights[s]

    # phase 2: greedy cost-lowering moves
    def cost(sa):
        cut = sum(1 for p, c in edges if sa[p] != sa[c])
        w = [0.0] * P
        for s, r in enumerate(sa):
            w[r] += weights[s]
        return cut_penalty * cut + sum(x * x for x in w) / max(total, 1.0)

    cur = cost(sassign)
    counts = [0] * P
    for r in sassign:
        counts[r] += 1
    for _ in range(refine_rounds):
        improved = False
        for s in order:
            r0 = sassign[s]
            if counts[r0] == 1:
                continue    # never empty a region
            best_r, best_c = r0, cur
            for r1 in range(P):
                if r1 == r0:
                    continue
                sassign[s] = r1
                c1 = cost(sassign)
                if c1 < best_c - 1e-9:
                    best_r, best_c = r1, c1
            sassign[s] = best_r
            if best_r != r0:
                counts[r0] -= 1
                counts[best_r] += 1
                cur = best_c
                improved = True
        if not improved:
            break

    assign = [0] * n
    for s, r in enumerate(sassign):
        for i in members[s]:
            assign[i] = r
    part = Partition(P, tuple(assign))
    part.validate(graph)
    return part


def auto_partition(graph: Graph, devices: int | None = None) -> Partition:
    """Pick P from the fabric and the platform: bounded by the local
    device count, the SCC condensation size, and a minimum region size
    (sharding a tiny fabric only buys channel-merge overhead).  May
    return P=1 — the caller treats that as a solo fabric."""
    if devices is None:
        import jax
        devices = len(jax.devices())
    n = len(graph.nodes)
    if n == 0:
        return Partition(1, ())
    _, _, _, _, order, _ = _condense(graph)
    P = max(1, min(int(devices), len(order),
                   n // MIN_AUTO_REGION_NODES))
    return partition_graph(graph, P)


def resolve_partition(graph: Graph, spec) -> Partition | None:
    """Normalize a user-facing partition spec (None | int | "auto" |
    Partition) to a validated :class:`Partition` or None.

    ``None`` and ``P=1`` both mean "solo fabric"; callers gate the
    sharded path on ``part is not None and part.P > 1``.
    """
    if spec is None:
        return None
    if isinstance(spec, Partition):
        spec.validate(graph)
        return spec
    if spec == "auto":
        return auto_partition(graph)
    if isinstance(spec, (int, np.integer)):
        return partition_graph(graph, int(spec))
    raise ValueError(
        f"partition must be None, an int, 'auto', or a Partition — "
        f"got {spec!r}")
