"""Static firing schedules: compile the interpreter away on
control-free fabrics (DESIGN.md §13).

On a fabric whose token routing is value-independent — acyclic, no
BRANCH/NDMERGE/DMERGE, no one-shot init tokens (exactly the
``GraphTraits.tokens_out_static`` precondition, DESIGN.md §10) — arc
*presence* evolves independently of arc *values*: whether a node fires
on cycle t is a function of the feed lengths alone.  This module
simulates that boolean presence automaton once on the host, detects
its steady-state period, and compiles the resulting cycle-exact
firing schedule (prologue + steady-state period + epilogue) into
straight-line kernels with no runtime ready-mask reduction and no
empty-output checks: each scheduled cycle touches only the arcs that
actually move.

The pieces, in dependency order:

* :func:`schedule_blockers` — the schedulability probe (the same
  predicate `GraphTraits` reports, restated here so the engine does
  not need to import the compile layer).
* :class:`CyclePattern` — one deduplicated cycle's worth of schedule:
  which feed rows load, which plan rows fire (bucketed by opcode, the
  §8 specialization applied statically), which output rows drain, the
  post-cycle register occupancy, and the per-cycle §12 profile
  increments.  Patterns are value-free and shared across every
  concrete plan of the fabric.
* :class:`ConcretePlan` — the schedule for one tuple of feed lengths:
  a run-length-encoded sequence of pattern ids.  Built lazily by
  stepping the presence automaton; when the automaton's state
  (arc occupancy + which feed rows still have tokens) repeats, the
  cycle sequence between the two occurrences is a *period* that is
  fast-forwarded in closed form (``k = min_r floor(rem_r / c_r)``
  whole periods, where ``c_r`` is the period's per-row feed
  consumption) instead of being stepped cycle by cycle.
* run-path lowering — the scheduled cycles become a straight-line jnp
  program (one unrolled application per prologue/epilogue cycle, one
  ``fori_loop`` whose single iteration applies ALL cycles of a period
  for the steady state).  Fusing the period into one loop body is the
  software-pipelining step: the arc registers inside the period
  become SSA values XLA schedules freely, so one executed loop
  iteration retires a full period's worth of tokens — past the
  1-token-per-2-cycles handshake cadence of the dynamic interpreter.
* slot-path lowering — for the resumable slot API the schedule is
  table-driven: per-pattern gather tables indexed by a host-computed
  pid sequence, one ``fori_loop`` per block, per-slot clocks advanced
  on the host from the plan (no device sync per block at all).

Everything here is bookkeeping over the engine's `_plan` arrays;
results stay bit-identical to :func:`repro.core.engine.run_reference`
in every field (values, counts, cycles, node_fires, per-arc registers
at block boundaries) — property-tested in tests/test_schedule.py.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from .graph import Graph, Op
from .engine import _alu_op, _alu_numpy, pack_feeds

_CONTROL_OPS = (Op.BRANCH, Op.NDMERGE, Op.DMERGE)

# presence-automaton step budget per concrete plan: a plan that has
# not quiesced or locked onto a period within this many host-stepped
# cycles is pathological (the state space is finite but can be huge);
# construction bails and the caller falls back to the dynamic engine
# — a performance decision, never a correctness one.
BAIL_STEPS = 65536

_E32 = np.zeros((0,), np.int32)


class ScheduleBail(RuntimeError):
    """Schedule construction exceeded its step budget; the dynamic
    engine remains the executor for this (pathological) fabric."""


def schedule_blockers(graph: Graph) -> tuple[str, ...]:
    """Why this graph cannot be statically scheduled (empty = it can).

    Mirrors ``GraphTraits.tokens_out_static`` (DESIGN.md §10): value-
    dependent routing (BRANCH/DMERGE/NDMERGE), cycles, or one-shot
    init tokens make the firing pattern depend on token *values*, so
    the presence automaton would not be value-free."""
    why = []
    if graph.is_cyclic():
        why.append("cyclic fabric")
    ops = sorted({n.op.name for n in graph.nodes if n.op in _CONTROL_OPS})
    if ops:
        why.append(f"control ops {ops}")
    if graph.inits:
        why.append("one-shot init tokens")
    return tuple(why)


def schedulable(graph: Graph) -> bool:
    return not schedule_blockers(graph)


class CyclePattern:
    """One deduplicated scheduled cycle (value-free).

    fed        int32 rows into the plan's input_arcs that load a token
    fed_arcs   the matching arc indices
    fire       int32 plan node rows that fire this cycle
    drain      int32 rows into output_arcs that drain a token
    drain_arcs the matching arc indices
    busy       bool[A2] post-fire/pre-drain occupancy (§12 sample
               point; pads cleared)
    full_after bool[A2] post-drain occupancy — the arc registers a
               block ending on this cycle must expose (FULL_PAD set)
    bundles    opcode-bucketed fire table: (op, in0[k], in1[k],
               out_flat[2k]) with missing outputs mapped to the
               out-of-range drop sentinel A2
    nf/si/so/ab/ahw _inc   per-cycle §12 counter increments
    """

    __slots__ = ("pid", "fed", "fed_arcs", "fire", "drain", "drain_arcs",
                 "busy", "full_after", "bundles", "n_fires", "n_drains",
                 "nf_inc", "si_inc", "so_inc", "ab_inc", "ahw_inc")

    def __init__(self, pid, p, fed, fed_arcs, fire, drain, drain_arcs,
                 busy, ir, full_after):
        self.pid = pid
        self.fed = fed
        self.fed_arcs = fed_arcs
        self.fire = fire
        self.drain = drain
        self.drain_arcs = drain_arcs
        self.busy = busy
        self.full_after = full_after
        self.n_fires = int(fire.size)
        self.n_drains = int(drain.size)
        ready = np.zeros((len(p["opcode"]),), bool)
        ready[fire] = True
        # §12 partition: fired / blocked-on-input / blocked-on-output
        self.nf_inc = ready.astype(np.int64)
        self.si_inc = (~ir).astype(np.int64)
        self.so_inc = (ir & ~ready).astype(np.int64)
        self.ab_inc = busy.astype(np.int64)
        self.ahw_inc = busy.astype(np.int64)
        # opcode buckets (plan rows are opcode-sorted under optimize;
        # a stable argsort covers the unoptimized layout too)
        A2 = p["A"] + 2
        rows = fire[np.argsort(p["opcode"][fire], kind="stable")]
        bundles = []
        s = 0
        while s < rows.size:
            e = s
            op = int(p["opcode"][rows[s]])
            while e < rows.size and int(p["opcode"][rows[e]]) == op:
                e += 1
            rr = rows[s:e]
            out = p["out_idx"][rr].copy()           # [k, 2]
            out[out == p["EMPTY_PAD"]] = A2         # drop sentinel
            bundles.append((Op(op), p["in_idx"][rr, 0].copy(),
                            p["in_idx"][rr, 1].copy(), out.reshape(-1)))
            s = e
        self.bundles = bundles


class ConcretePlan:
    """The cycle-exact schedule for one tuple of feed lengths.

    ``segments`` is a run-length-encoded pid sequence:
    ``[(pids, reps), ...]`` meaning the ``pids`` cycle tuple repeats
    ``reps`` times.  ``total`` counts scheduled cycles including the
    one trailing idle cycle a quiescing fabric spends detecting its
    own quiescence (matching ``run_reference``'s cycle accounting).
    The plan is cap-agnostic and lazily extended: ``ensure(t)`` grows
    it to cover at least ``t`` cycles (a no-op once quiesced)."""

    def __init__(self, ctx: "ScheduleContext", flen: tuple[int, ...]):
        self.ctx = ctx
        self.flen = flen
        p = ctx.p
        full = np.zeros((ctx.A2,), bool)
        full[p["FULL_PAD"]] = True
        full[ctx.const_rows] = True
        self._full = full
        self._rem = np.asarray(flen, np.int64).copy()
        self.segments: list[tuple[tuple[int, ...], int]] = []
        self.total = 0
        self.quiesced = False
        self.idle_pid = None
        self._free = None            # free-running period (never quiesces)
        self._tail: list[int] = []   # pids since the last segment close
        self._seen: dict = {}
        self._stepped = 0
        self._record()

    # -- construction -----------------------------------------------------
    def _state_key(self):
        return (self._full.tobytes(), (self._rem > 0).tobytes())

    def _record(self):
        self._seen[self._state_key()] = (len(self._tail), self._rem.copy())

    def ensure(self, want: int) -> None:
        want = int(want)
        while not self.quiesced and self.total < want:
            if self._free is not None:
                q = len(self._free)
                reps = -(-(want - self.total) // q)
                self.segments.append((self._free, reps))
                self.total += reps * q
                return
            self._step()

    def _step(self):
        self._stepped += 1
        if self._stepped > BAIL_STEPS:
            raise ScheduleBail(
                f"no period within {BAIL_STEPS} cycles for feed "
                f"lengths {self.flen}")
        pid, progress = self.ctx.observe(self._full, self._rem)
        self._tail.append(pid)
        self.total += 1
        if not progress:
            # idle is absorbing: state unchanged forever after
            self.idle_pid = pid
            self.quiesced = True
            self.segments.append((tuple(self._tail), 1))
            self._tail = []
            self._seen = {}
            return
        key = self._state_key()
        prev = self._seen.get(key)
        if prev is None:
            self._seen[key] = (len(self._tail), self._rem.copy())
            return
        i, rem_i = prev
        period = tuple(self._tail[i:])
        c = rem_i - self._rem        # per-row feed consumption / period
        if not c.any():
            # progress with zero feed consumption from a repeated
            # state: the period repeats forever (free-running fabric)
            if i > 0:
                self.segments.append((tuple(self._tail[:i]), 1))
            self.segments.append((period, 1))
            self._free = period
            self._tail = []
            self._seen = {}
            return
        # fast-forward: k more whole periods are valid as long as no
        # feed row runs dry mid-period — the last feed event of row r
        # in replay m needs rem_r - (m+1)*c_r >= 0, so
        # k = min_{c_r > 0} floor(rem_r / c_r)
        k = int((self._rem[c > 0] // c[c > 0]).min())
        if k <= 0:
            # can't jump; re-anchor the detection on this occurrence
            # (the regime diverges within one period)
            self._seen[key] = (len(self._tail), self._rem.copy())
            return
        if i > 0:
            self.segments.append((tuple(self._tail[:i]), 1))
        self.segments.append((period, 1 + k))
        self.total += k * len(period)
        self._rem -= k * c
        self._tail = []
        self._seen = {}
        self._record()

    # -- accounting -------------------------------------------------------
    @property
    def progress_total(self):
        """1-based count of progress cycles (None = unbounded)."""
        return self.total - 1 if self.quiesced else None

    def _iter_clipped(self, upto: int):
        """RLE segments covering exactly cycles [0, upto) — clipping
        the last segment and extending a quiesced plan with idle."""
        t = 0
        for pids, reps in self.segments:
            if t >= upto:
                return
            q = len(pids)
            span = q * reps
            if t + span <= upto:
                yield pids, reps
                t += span
            else:
                fr, part = divmod(upto - t, q)
                if fr:
                    yield pids, fr
                if part:
                    yield pids[:part], 1
                t = upto
        if t < upto and self._tail:
            # cycles explored past the last closed segment (a cap can
            # land before the first period locks or the fabric quiesces)
            n = min(len(self._tail), upto - t)
            yield tuple(self._tail[:n]), 1
            t += n
        if t < upto:
            assert self.quiesced, "ensure() the plan before slicing it"
            yield (self.idle_pid,), upto - t

    def trace_struct(self, upto: int):
        """(structure, reps) for the run-path lowering: segments with
        reps == 1 unroll; larger reps become fori_loops whose traced
        trip counts live in the ``reps`` operand (so one trace serves
        every feed-length tuple sharing the structure)."""
        segs = list(self._iter_clipped(upto))
        struct = tuple((tuple(pids), reps > 1) for pids, reps in segs)
        reps = np.asarray([r for _, r in segs if r > 1] or [0], np.int32)
        return struct, reps

    def counts_upto(self, t: int) -> dict[int, int]:
        c: dict[int, int] = {}
        for pids, reps in self._iter_clipped(t):
            for pid in pids:
                c[pid] = c.get(pid, 0) + reps
        return c

    def counts_between(self, lo: int, hi: int) -> dict[int, int]:
        hi_c = self.counts_upto(hi)
        if lo:
            for pid, n in self.counts_upto(lo).items():
                hi_c[pid] -= n
        return {pid: n for pid, n in hi_c.items() if n}

    def fires_between(self, lo: int, hi: int) -> int:
        reg = self.ctx.registry
        return sum(n * reg[pid].n_fires
                   for pid, n in self.counts_between(lo, hi).items())

    def pids_window(self, lo: int, hi: int) -> np.ndarray:
        """Dense pid sequence for cycles [lo, hi) (the slot path's
        per-block device operand)."""
        out = np.empty((hi - lo,), np.int32)
        w = 0
        t = 0
        for pids, reps in self._iter_clipped(hi):
            q = len(pids)
            span = q * reps
            if t + span <= lo:
                t += span
                continue
            arr = np.asarray(pids, np.int32)
            s = max(lo - t, 0)
            e = min(hi - t, span)
            out[w:w + e - s] = arr[np.arange(s, e) % q]
            w += e - s
            t += span
        assert w == hi - lo
        return out

    def steady(self):
        """(period_cycles, period_tokens) of the dominant steady-state
        segment, or None if the plan never locked onto a repeating
        period (e.g. it quiesced before one formed)."""
        best = None
        if self._free is not None:
            best = self._free
        else:
            reps = 0
            for pids, r in self.segments:
                if r > reps:
                    best, reps = pids, r
            if reps < 2:
                return None
        reg = self.ctx.registry
        toks = sum(reg[pid].n_drains for pid in best)
        return len(best), toks


class SlotSched:
    """Host side of scheduled slots: per-slot plan refs + schedule
    positions, and (profiled engines) the host-accumulated §12
    counters — scheduled profiles are closed-form, never device
    state."""

    def __init__(self, ctx: "ScheduleContext", slots: int, profile: bool):
        self.ctx = ctx
        self.plans: list[ConcretePlan | None] = [None] * slots
        self.pos = np.zeros((slots,), np.int64)
        self.profile = profile
        if profile:
            n, a2 = ctx.n_nodes, ctx.A2
            self.nf = np.zeros((slots, n), np.int64)
            self.si = np.zeros((slots, n), np.int64)
            self.so = np.zeros((slots, n), np.int64)
            self.ab = np.zeros((slots, a2), np.int64)
            self.ahw = np.zeros((slots, a2), np.int64)

    def reset(self, b: int, plan: ConcretePlan) -> None:
        self.plans[b] = plan
        self.pos[b] = 0
        if self.profile:
            for x in (self.nf, self.si, self.so, self.ab, self.ahw):
                x[b] = 0

    def accrue(self, b: int, counts: dict[int, int]) -> None:
        reg = self.ctx.registry
        for pid, n in counts.items():
            pat = reg[pid]
            self.nf[b] += n * pat.nf_inc
            self.si[b] += n * pat.si_inc
            self.so[b] += n * pat.so_inc
            self.ab[b] += n * pat.ab_inc
            np.maximum(self.ahw[b], pat.ahw_inc, out=self.ahw[b])

    def prof_row(self, b: int):
        return (self.nf[b], self.si[b], self.so[b], self.ab[b],
                self.ahw[b])


class ScheduleContext:
    """Per-engine schedule state: the pattern registry (shared across
    every concrete plan of the fabric), the plan cache keyed by feed
    lengths, the device tables for the slot path, and the trace caches
    for both lowerings."""

    def __init__(self, p, graph: Graph, token_shape, dtype):
        self.p = p
        self.graph = graph
        self.token_shape = tuple(token_shape)
        self.dtype = dtype
        self.np_dtype = np.dtype(str(jnp.dtype(dtype)))
        self.A2 = p["A"] + 2
        self.n_nodes = len(p["opcode"])
        self.in_arc = np.asarray(
            [p["aidx"][a] for a in p["input_arcs"]], np.int32)
        self.out_arc = np.asarray(
            [p["aidx"][a] for a in p["output_arcs"]], np.int32)
        self.const_rows = np.nonzero(p["const_mask"])[0].astype(np.int32)
        self.ops_present = sorted({int(o) for o in p["opcode"]})
        # padded arc-index rows matching the slot state's n_in/n_out
        # (>= 1 each; pad feed targets are gated to the drop sentinel,
        # pad drain reads hit the always-empty EMPTY_PAD register)
        self.ia_pad = np.zeros((max(self.in_arc.size, 1),), np.int32)
        self.ia_pad[:self.in_arc.size] = self.in_arc
        self.oa_pad = np.full((max(self.out_arc.size, 1),),
                              p["EMPTY_PAD"], np.int32)
        self.oa_pad[:self.out_arc.size] = self.out_arc
        self.registry: list[CyclePattern] = []
        self._pid_by_key: dict = {}
        self._plans: dict[tuple[int, ...], ConcretePlan] = {}
        self._runners: dict = {}
        self._slot_steps: dict = {}
        self._tables = None
        self._tables_len = 0
        # reserved pid 0: the no-op filler inactive slots execute.  It
        # is registered under no key (a real all-quiet cycle must get
        # its own pattern: its full_after differs — FULL_PAD, consts,
        # possibly tokens stuck at quiescence) and its full_after is
        # never applied (fsel == -1 gates it).
        self._register(_E32, _E32, _E32, _E32, _E32,
                       np.zeros((self.A2,), bool),
                       np.zeros((self.n_nodes,), bool),
                       np.zeros((self.A2,), bool), key=None)

    # -- pattern registry -------------------------------------------------
    def _register(self, fed, fed_arcs, fire, drain, drain_arcs, busy, ir,
                  full_after, key):
        pid = len(self.registry)
        pat = CyclePattern(pid, self.p, fed, fed_arcs, fire, drain,
                           drain_arcs, busy, ir, full_after)
        self.registry.append(pat)
        if key is not None:
            self._pid_by_key[key] = pid
        return pid

    def observe(self, full: np.ndarray, rem: np.ndarray):
        """Advance the presence automaton one cycle in place; return
        (pattern id, progress).  Mirrors run_reference's cycle:
        feed -> simultaneous fire -> const restore -> §12 occupancy
        sample -> drain."""
        p = self.p
        ia, oa = self.in_arc, self.out_arc
        fed = _E32
        if ia.size:
            can = (~full[ia]) & (rem > 0)
            fed = np.nonzero(can)[0].astype(np.int32)
            if fed.size:
                full[ia[fed]] = True
                rem[fed] -= 1
        inf = full[p["in_idx"]]                   # [N, 3]; pads full
        ir = inf.all(axis=1)
        ready = ir & ~full[p["out_idx"]].any(axis=1)
        fire = np.nonzero(ready)[0].astype(np.int32)
        if fire.size:
            full[p["in_idx"][fire].reshape(-1)] = False
            full[p["out_idx"][fire].reshape(-1)] = True
            full[p["FULL_PAD"]] = True
            full[p["EMPTY_PAD"]] = False
        full[self.const_rows] = True              # consts are sticky-full
        busy = full.copy()
        busy[p["FULL_PAD"]] = False
        busy[p["EMPTY_PAD"]] = False
        drain = _E32
        if oa.size:
            drain = np.nonzero(full[oa])[0].astype(np.int32)
            if drain.size:
                full[oa[drain]] = False
        progress = bool(fed.size or fire.size or drain.size)
        key = (fed.tobytes(), fire.tobytes(), drain.tobytes(),
               np.packbits(busy).tobytes())
        pid = self._pid_by_key.get(key)
        if pid is None:
            pid = self._register(fed, ia[fed], fire, drain, oa[drain],
                                 busy, ir, full.copy(), key=key)
        return pid, progress

    def plan_for(self, flen: tuple[int, ...]) -> ConcretePlan:
        plan = self._plans.get(flen)
        if plan is None:
            plan = ConcretePlan(self, flen)
            self._plans[flen] = plan
            if len(self._plans) > 512:       # bound serve-path growth
                self._plans.pop(next(iter(self._plans)))
        return plan

    # -- profile reconstruction ------------------------------------------
    def profile_counts(self, plan: ConcretePlan, lo: int, hi: int):
        """Closed-form §12 counters over cycles [lo, hi) — bit-equal
        to what the reference oracle accumulates cycle by cycle."""
        nf = np.zeros((self.n_nodes,), np.int64)
        si = np.zeros((self.n_nodes,), np.int64)
        so = np.zeros((self.n_nodes,), np.int64)
        ab = np.zeros((self.A2,), np.int64)
        ahw = np.zeros((self.A2,), np.int64)
        for pid, n in plan.counts_between(lo, hi).items():
            pat = self.registry[pid]
            nf += n * pat.nf_inc
            si += n * pat.si_inc
            so += n * pat.so_inc
            ab += n * pat.ab_inc
            np.maximum(ahw, pat.ahw_inc, out=ahw)
        return nf, si, so, ab, ahw

    # -- run-path lowering ------------------------------------------------
    def state0_val(self) -> np.ndarray:
        val = np.zeros((self.A2, *self.token_shape), self.np_dtype)
        for a, v in self.graph.consts.items():
            val[self.p["aidx"][a]] = v
        return val

    def _apply_pattern(self, pat: CyclePattern, fv, st):
        """One scheduled cycle as pure jnp: static-index feed gather,
        opcode-bucketed fire (reads snapshot before writes; produced
        and consumed arcs are disjoint within a cycle), static drain.
        Missing outputs scatter to the out-of-range sentinel with
        mode='drop' so val[EMPTY_PAD] stays 0 on every backend."""
        val, ptr, ol, oc = st
        if pat.fed.size:
            nxt = fv[pat.fed, ptr[pat.fed]]
            val = val.at[pat.fed_arcs].set(nxt)
            ptr = ptr.at[pat.fed].add(1)
        if pat.n_fires:
            zs = [(out, jnp.repeat(_alu_op(op, val[i0], val[i1],
                                           self.dtype), 2, axis=0))
                  for op, i0, i1, out in pat.bundles]
            for out, z2 in zs:
                val = val.at[out].set(z2, mode="drop")
        if pat.drain.size:
            ol = ol.at[pat.drain].set(val[pat.drain_arcs])
            oc = oc.at[pat.drain].add(1)
        return (val, ptr, ol, oc)

    def _make_run_fn(self, struct):
        """The straight-line scheduled program for one structure:
        fn(fv, reps) -> (out_last, out_count).  reps carries the
        traced fori_loop trip counts; each loop iteration applies a
        whole period fused (the software-pipelining step)."""
        reg = self.registry
        ts = self.token_shape
        n_in_p = max(self.in_arc.size, 1)
        n_out_p = max(self.out_arc.size, 1)

        def fn(fv, reps):
            val = jnp.asarray(self.state0_val())
            ptr = jnp.zeros((n_in_p,), jnp.int32)
            ol = jnp.zeros((n_out_p, *ts), self.dtype)
            oc = jnp.zeros((n_out_p,), jnp.int32)
            st = (val, ptr, ol, oc)
            r = 0
            for pids, dyn in struct:
                pats = [reg[pid] for pid in pids]
                if not dyn:
                    for pat in pats:
                        st = self._apply_pattern(pat, fv, st)
                else:
                    def body(_, s, pats=pats):
                        for pat in pats:
                            s = self._apply_pattern(pat, fv, s)
                        return s
                    st = jax.lax.fori_loop(0, reps[r], body, st)
                    r += 1
            return st[2], st[3]
        return fn

    def runner(self, struct, length: int, backend: str, batched: bool):
        key = (struct, length, backend, batched)
        run = self._runners.get(key)
        if run is None:
            fn = self._make_run_fn(struct)
            if backend == "pallas":
                from repro.kernels import schedule_fire as _ksf
                run = _ksf.make_sched_run(fn, max(self.out_arc.size, 1),
                                          batched)
            elif batched:
                run = jax.jit(jax.vmap(fn, in_axes=(0, None)))
            else:
                run = jax.jit(fn)
            self._runners[key] = run
        return run

    # -- slot-path lowering -----------------------------------------------
    def slot_tables(self):
        """Per-pattern gather tables (jnp), rebuilt when the registry
        grows; P and F pad to powers of two so growth rarely changes
        operand shapes (bounding retraces)."""
        if self._tables is None or self._tables_len < len(self.registry):
            reg = self.registry
            np2 = lambda n: 1 << max(0, int(n - 1).bit_length())
            P = np2(len(reg))
            F = np2(max([p.n_fires for p in reg] + [1]))
            n_in_p = max(self.in_arc.size, 1)
            n_out_p = max(self.out_arc.size, 1)
            p = self.p
            t_op = np.full((P, F), int(Op.COPY), np.int32)
            t_i0 = np.full((P, F), p["FULL_PAD"], np.int32)
            t_i1 = np.full((P, F), p["FULL_PAD"], np.int32)
            t_o0 = np.full((P, F), self.A2, np.int32)   # drop sentinel
            t_o1 = np.full((P, F), self.A2, np.int32)
            t_feed = np.zeros((P, n_in_p), np.int32)
            t_drain = np.zeros((P, n_out_p), np.int32)
            t_full = np.zeros((P, self.A2), np.int32)
            for pat in reg:
                k = pat.n_fires
                if k:
                    rows = pat.fire
                    t_op[pat.pid, :k] = p["opcode"][rows]
                    t_i0[pat.pid, :k] = p["in_idx"][rows, 0]
                    t_i1[pat.pid, :k] = p["in_idx"][rows, 1]
                    out = p["out_idx"][rows].copy()
                    out[out == p["EMPTY_PAD"]] = self.A2
                    t_o0[pat.pid, :k] = out[:, 0]
                    t_o1[pat.pid, :k] = out[:, 1]
                t_feed[pat.pid, pat.fed] = 1
                t_drain[pat.pid, pat.drain] = 1
                t_full[pat.pid] = pat.full_after
            self._tables = tuple(jnp.asarray(t) for t in (
                t_op, t_i0, t_i1, t_o0, t_o1, t_feed, t_drain, t_full))
            self._tables_len = len(reg)
        return self._tables

    def _slot_cycle(self, tabs, fv, st, pid):
        """One table-driven scheduled cycle for one slot (int32
        scalar tokens — the slot API's contract).  pid 0 is a no-op,
        so inactive slots ride the same dispatch untouched."""
        t_op, t_i0, t_i1, t_o0, t_o1, t_feed, t_drain, _ = tabs
        val, ptr, ol, oc = st
        fm = t_feed[pid]
        pv = jnp.clip(ptr, 0, fv.shape[1] - 1)
        nxt = jnp.take_along_axis(fv, pv[:, None], axis=1)[:, 0]
        tgt = jnp.where(fm > 0, self.ia_pad, self.A2)
        val = val.at[tgt].set(nxt, mode="drop")
        ptr = ptr + fm
        a = val[t_i0[pid]]
        b = val[t_i1[pid]]
        opv = t_op[pid]
        z = a
        for op in self.ops_present:
            if Op(op) in (Op.COPY, Op.SINK):
                continue                          # z defaults to a
            z = jnp.where(opv == op,
                          _alu_op(Op(op), a, b, jnp.int32), z)
        val = val.at[t_o0[pid]].set(z, mode="drop")
        val = val.at[t_o1[pid]].set(z, mode="drop")
        dm = t_drain[pid]
        ol = jnp.where(dm > 0, val[self.oa_pad], ol)
        oc = oc + dm
        return (val, ptr, ol, oc)

    def slot_body(self, tabs, fv, pids, fsel, full, val, ptr, ol, oc,
                  n_cycles: int):
        """One slot's scheduled block: n_cycles table-driven cycles +
        the post-block arc registers selected from the last executed
        pattern's full_after (fsel == -1 leaves an inactive slot's
        registers untouched) — bit-identical to the dynamic kernels'
        block-boundary state."""
        def body(j, st):
            return self._slot_cycle(tabs, fv, st, pids[j])
        val, ptr, ol, oc = jax.lax.fori_loop(
            0, n_cycles, body, (val, ptr, ol, oc))
        t_full = tabs[7]
        full = jnp.where(fsel >= 0, t_full[jnp.maximum(fsel, 0)], full)
        return full, val, ptr, ol, oc

    def slot_step_fn(self, n_cycles: int, backend: str):
        key = (n_cycles, backend)
        step = self._slot_steps.get(key)
        if step is None:
            if backend == "pallas":
                from repro.kernels import schedule_fire as _ksf
                step = _ksf.make_sched_slot_step(self, n_cycles)
            else:
                def one(fv, pids, fsel, full, val, ptr, ol, oc, *tabs):
                    return self.slot_body(tabs, fv, pids, fsel, full,
                                          val, ptr, ol, oc, n_cycles)
                step = jax.jit(jax.vmap(
                    one, in_axes=(0,) * 8 + (None,) * 8))
            self._slot_steps[key] = step
        return step


# ---------------------------------------------------------------------------
# engine entry points (called from DataflowEngine; lazy — this module
# imports the engine, not the other way around at module scope)
# ---------------------------------------------------------------------------
def run_scheduled(eng, feeds, max_cycles: int):
    """Scheduled run() path for any backend.  Raises ScheduleBail if
    the plan never locks onto a period in budget (the caller falls
    back to the dynamic engine)."""
    ctx = eng._sched_ctx()
    fv, fl = pack_feeds(eng.p["input_arcs"], feeds, eng.token_shape,
                        ctx.np_dtype)
    plan = ctx.plan_for(tuple(int(x) for x in fl))
    plan.ensure(max_cycles)
    exec_ = min(plan.total, max_cycles)
    if eng.backend == "reference":
        return _run_reference_sched(eng, ctx, plan, fv, exec_)
    return _run_device_sched(eng, ctx, plan, fv[None], exec_)[0]


def run_batch_scheduled(eng, feeds_batch, max_cycles: int):
    """Scheduled run_batch() path: one vmapped straight-line program
    when every stream shares one feed-length tuple (so one schedule
    covers the batch).  Returns None on mixed-length batches — the
    dynamic path handles those."""
    ctx = eng._sched_ctx()
    length = max((max((np.shape(v)[0] for v in (f or {}).values()),
                      default=0) for f in feeds_batch), default=0)
    length = max(length, 1)
    packed = [pack_feeds(eng.p["input_arcs"], f, eng.token_shape,
                         ctx.np_dtype, min_len=length)
              for f in feeds_batch]
    flens = {tuple(int(x) for x in fl) for _, fl in packed}
    if len(flens) != 1:
        return None
    plan = ctx.plan_for(flens.pop())
    plan.ensure(max_cycles)
    exec_ = min(plan.total, max_cycles)
    if eng.backend == "reference":
        return [_run_reference_sched(eng, ctx, plan, fv, exec_)
                for fv, _ in packed]
    fvb = np.stack([fv for fv, _ in packed])
    return _run_device_sched(eng, ctx, plan, fvb, exec_)


def _run_device_sched(eng, ctx, plan, fvb, exec_):
    struct, reps = plan.trace_struct(exec_)
    B, n_in = fvb.shape[0], fvb.shape[1]
    n_in_p = max(n_in, 1)
    length = max(fvb.shape[2], 1)
    if (n_in, fvb.shape[2]) != (n_in_p, length):
        pad = np.zeros((B, n_in_p, length, *eng.token_shape),
                       ctx.np_dtype)
        pad[:, :n_in, :fvb.shape[2]] = fvb
        fvb = pad
    run = ctx.runner(struct, length, eng.backend, batched=B > 1)
    if B > 1:
        ol, oc = run(jnp.asarray(fvb), jnp.asarray(reps))
    else:
        ol, oc = run(jnp.asarray(fvb[0]), jnp.asarray(reps))
        ol, oc = ol[None], oc[None]
    fired = plan.fires_between(0, exec_)
    n_out = len(eng.p["output_arcs"])
    prof = None
    if eng.profile:
        prof = (*ctx.profile_counts(plan, 0, exec_), exec_, 1)
    return [eng._result_from_state(ol[b][:n_out], oc[b][:n_out], exec_,
                                   fired, 1, prof=prof)
            for b in range(B)]


def _run_reference_sched(eng, ctx, plan, fv, exec_):
    """Numpy schedule interpreter — the scheduled mirror of
    run_reference (same dispatches=None result shape, profile
    dispatches=0)."""
    with np.errstate(all="ignore"):
        val = ctx.state0_val()
        ptr = np.zeros((max(ctx.in_arc.size, 1),), np.int64)
        n_out = ctx.out_arc.size
        ol = np.zeros((n_out, *eng.token_shape), ctx.np_dtype)
        oc = np.zeros((n_out,), np.int64)
        for pid in plan.pids_window(0, exec_):
            pat = ctx.registry[pid]
            if pat.fed.size:
                val[pat.fed_arcs] = fv[pat.fed, ptr[pat.fed]]
                ptr[pat.fed] += 1
            for op, i0, i1, out in pat.bundles:
                z2 = np.repeat(_alu_numpy(op, val[i0], val[i1],
                                          ctx.np_dtype), 2, axis=0)
                ok = out < ctx.A2
                val[out[ok]] = z2[ok]
            if pat.drain.size:
                ol[pat.drain] = val[pat.drain_arcs]
                oc[pat.drain] += 1
    fired = plan.fires_between(0, exec_)
    prof = None
    if eng.profile:
        prof = (*ctx.profile_counts(plan, 0, exec_), exec_, 0)
    res = eng._result_from_state(ol, oc, exec_, fired, None, prof=prof)
    return res


def step_block_sched(eng, state, nb: int):
    """Scheduled step_block: host-computed pid sequences drive one
    table-driven device dispatch; per-slot clocks (base/last/fired/
    quiesced/stalled) advance from the plan in closed form — no
    device sync per block at all (the dynamic path needs one)."""
    import dataclasses as _dc
    ctx = eng._sched_ctx()
    sc = state.sched
    B = state.slots
    pidm = np.zeros((B, nb), np.int32)
    fsel = np.full((B,), -1, np.int32)
    f = np.zeros((B,), np.int64)
    lp = np.zeros((B,), np.int64)
    for b in range(B):
        if not state.active[b]:
            continue
        plan = sc.plans[b]
        pos0 = int(sc.pos[b])
        plan.ensure(pos0 + nb)
        pidm[b] = plan.pids_window(pos0, pos0 + nb)
        fsel[b] = pidm[b, -1]
        p_tot = plan.progress_total
        hi = pos0 + nb if p_tot is None else min(p_tot, pos0 + nb)
        lp[b] = max(0, hi - pos0)
        f[b] = plan.fires_between(pos0, pos0 + nb)
        if eng.profile:
            sc.accrue(b, plan.counts_between(pos0, pos0 + nb))
        sc.pos[b] = pos0 + nb
    step = ctx.slot_step_fn(nb, eng.backend)
    tabs = ctx.slot_tables()
    full, val, ptr, out_last, out_count = step(
        state.fv, jnp.asarray(pidm), jnp.asarray(fsel), state.full,
        state.val, state.ptr, state.out_last, state.out_count, *tabs)
    # host clocks: identical formulas to the dynamic step_block, with
    # (f, lp) read off the plan instead of synced from the device
    fired = state.fired + f
    last = np.where(lp > 0, state.base + lp, state.last)
    base = state.base + np.where(state.active > 0, nb, 0)
    quiesced = np.where(state.active > 0, lp < nb, state.quiesced)
    disp = state.dispatches + (state.active > 0)
    stalled = np.where(state.active > 0,
                       np.where(lp > 0, 0, state.stalled + 1),
                       state.stalled)
    prof_cycles = state.prof_cycles
    if eng.profile and prof_cycles is not None:
        prof_cycles = prof_cycles + np.where(state.active > 0, nb, 0)
    return _dc.replace(state, full=full, val=val, ptr=ptr,
                       out_last=out_last, out_count=out_count,
                       active=state.active.copy(), base=base, last=last,
                       fired=fired, quiesced=quiesced, dispatches=disp,
                       stalled=stalled, prof_cycles=prof_cycles,
                       sched=sc)
