"""Fault-tolerant training loop.

* checkpoint/restart: atomic checkpoints every ``ckpt_every`` steps; on
  start the loop restores LATEST and the deterministic data pipeline
  replays from exactly that step — restart is byte-exact (tested).
* elasticity: checkpoints store full arrays; a resume may present a
  different mesh/sharding and the restore re-shards (tested in
  tests/test_train_loop.py by resuming on a different device count).
* straggler mitigation: a per-step wall-clock watchdog flags steps slower
  than ``straggler_factor`` x the running median.  On a real pod this
  feeds the controller that evicts/replaces the slow host; here the event
  stream is recorded and surfaced in metrics (and tested via a fault
  hook).
* failure injection: ``fail_at_step`` raises mid-run to exercise the
  restart path in tests and examples.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Callable

import jax

from repro.ckpt import checkpoint as ckpt
from repro.data.pipeline import SyntheticLM
from repro.models import transformer as tfm
from repro.optim import adamw


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_ckpts: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    fail_at_step: int | None = None   # failure injection (tests/examples)


def make_train_step(cfg, opt_cfg: adamw.OptConfig,
                    donate: bool = True) -> Callable:
    """Build the jitted (state, batch) -> (state, metrics) step."""

    def step_fn(state, batch):
        params, opt_state = state

        def loss_of(p):
            return tfm.loss_fn(cfg, p, batch)

        (loss, aux), grads = jax.value_and_grad(loss_of, has_aux=True)(
            params)
        new_params, new_opt, om = adamw.update(opt_cfg, grads, opt_state,
                                               params)
        metrics = {"loss": loss, **om}
        return (new_params, new_opt), metrics

    return jax.jit(step_fn, donate_argnums=(0,) if donate else ())


def init_state(cfg, key):
    params = tfm.init_params(cfg, key)
    return params, adamw.init(params)


def run(cfg, loop: LoopConfig, opt_cfg: adamw.OptConfig,
        source: SyntheticLM, state=None, train_step=None,
        key=None) -> dict:
    """Run (or resume) training.  Returns summary dict."""
    if train_step is None:
        train_step = make_train_step(cfg, opt_cfg)
    if state is None:
        state = init_state(cfg, key if key is not None
                           else jax.random.key(0))
    start, restored = 0, False
    rstep, rstate = ckpt.restore(loop.ckpt_dir, state)
    if rstate is not None:
        state, start, restored = rstate, rstep, True

    times: list[float] = []
    straggler_events: list[int] = []
    losses: list[float] = []
    for step in range(start, loop.total_steps):
        if loop.fail_at_step is not None and step == loop.fail_at_step:
            raise SimulatedFailure(f"injected failure at step {step}")
        batch = source.batch_for_step(step)
        t0 = time.perf_counter()
        state, metrics = train_step(state, batch)
        loss = float(metrics["loss"])          # blocks; also step timing
        dt = time.perf_counter() - t0
        if len(times) >= 5:
            med = statistics.median(times)
            if dt > loop.straggler_factor * med:
                straggler_events.append(step)
        times.append(dt)
        losses.append(loss)
        if (step + 1) % loop.ckpt_every == 0 or \
                step + 1 == loop.total_steps:
            ckpt.save(loop.ckpt_dir, step + 1, state)
            ckpt.cleanup(loop.ckpt_dir, loop.keep_ckpts)
        if (step + 1) % loop.log_every == 0:
            print(f"step {step + 1}: loss={loss:.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"dt={dt * 1e3:.0f}ms")
    return {"state": state, "losses": losses, "resumed": restored,
            "start_step": start, "straggler_events": straggler_events,
            "step_times": times}
