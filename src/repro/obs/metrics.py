"""Process-local metrics registry: counters, gauges, histograms.

Deliberately tiny and dependency-free (no jax, no threads): the server
and benchmark drivers update metrics from their host loops, and
``snapshot()`` renders everything to a JSON-safe dict.  Metrics are
keyed by ``(name, sorted labels)`` -- requesting the same name+labels
twice returns the same instrument, so call sites never cache handles.
"""
from __future__ import annotations

import json
import math


class Counter:
    """Monotonically increasing count."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError("counters only go up")
        self.value += n


class Gauge:
    """A point-in-time value, with its session high-water mark."""

    __slots__ = ("value", "high_water")

    def __init__(self) -> None:
        self.value = 0
        self.high_water = 0

    def set(self, v) -> None:
        self.value = v
        if v > self.high_water:
            self.high_water = v


# Upper bucket bounds for block/cycle-scale quantities: exponential so
# one layout serves queue waits (~1-100 blocks) and residencies
# (~10-1e5 cycles) alike.
DEFAULT_BUCKETS = (1, 2, 5, 10, 25, 50, 100, 250, 500, 1000,
                   2500, 5000, 10000, 100000)


class Histogram:
    """Fixed-bucket histogram with sum/count/min/max."""

    __slots__ = ("buckets", "bucket_counts", "count", "total", "min", "max")

    def __init__(self, buckets=DEFAULT_BUCKETS) -> None:
        self.buckets = tuple(buckets)
        self.bucket_counts = [0] * (len(self.buckets) + 1)  # +inf overflow
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        for i, le in enumerate(self.buckets):
            if v <= le:
                self.bucket_counts[i] += 1
                return
        self.bucket_counts[-1] += 1

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0


def _key(name: str, labels: dict) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Registry of named, labeled instruments with a JSON snapshot."""

    def __init__(self) -> None:
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    # ----------------------------------------------------------- instruments
    def counter(self, name: str, **labels) -> Counter:
        return self._counters.setdefault(_key(name, labels), Counter())

    def gauge(self, name: str, **labels) -> Gauge:
        return self._gauges.setdefault(_key(name, labels), Gauge())

    def histogram(self, name: str, buckets=DEFAULT_BUCKETS, **labels) -> Histogram:
        return self._histograms.setdefault(_key(name, labels), Histogram(buckets))

    # ---------------------------------------------------------------- export
    def snapshot(self) -> dict:
        """JSON-safe dump of every instrument, sorted by key."""
        out = {
            "counters": {k: c.value for k, c in sorted(self._counters.items())},
            "gauges": {
                k: {"value": g.value, "high_water": g.high_water}
                for k, g in sorted(self._gauges.items())
            },
            "histograms": {
                k: {
                    "count": h.count,
                    "sum": h.total,
                    "mean": h.mean,
                    "min": h.min if h.count else None,
                    "max": h.max if h.count else None,
                    "buckets": {
                        (str(le) if i < len(h.buckets) else "+inf"): n
                        for i, (le, n) in enumerate(
                            zip(list(h.buckets) + ["+inf"], h.bucket_counts))
                    },
                }
                for k, h in sorted(self._histograms.items())
            },
        }
        return out

    def save(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.snapshot(), fh, indent=1)


def validate_snapshot(snap: dict) -> None:
    """Schema check for a ``MetricsRegistry.snapshot()`` dump.

    Raises ``ValueError`` on the first violation.  Used by the CI smoke
    (`serve_bench --quick --trace`) so a malformed export fails tier-1.
    """
    for section in ("counters", "gauges", "histograms"):
        if section not in snap or not isinstance(snap[section], dict):
            raise ValueError(f"metrics snapshot missing section {section!r}")
    for k, v in snap["counters"].items():
        if not isinstance(v, int) or v < 0:
            raise ValueError(f"counter {k!r} is not a non-negative int: {v!r}")
    for k, g in snap["gauges"].items():
        if not {"value", "high_water"} <= set(g):
            raise ValueError(f"gauge {k!r} missing value/high_water")
    for k, h in snap["histograms"].items():
        if h["count"] != sum(h["buckets"].values()):
            raise ValueError(f"histogram {k!r}: bucket counts do not sum to count")
