"""TraceRecorder -- block-clock event tracing for the serving lifecycle.

Records the DESIGN.md §11 slot-lifecycle state machine as a flat event
log and exports Chrome trace-event JSON (the ``traceEvents`` array
format) that Perfetto / chrome://tracing load directly.

Event kinds (one per lifecycle edge):

========== ==========================================================
kind       meaning
========== ==========================================================
submit     request accepted into the queue
reject     bounded-admission rejection (never enters the queue)
drop       drop-oldest policy evicted a queued request  (terminal)
poison     fault injection corrupted the request's feeds on submit
admit      request bound to a slot (begins a slot span)
requeue    degradation unbound a resident request (ends its slot span)
retry      a dispatch attempt failed and was retried
wedge      fault injection wedged a slot (suppressed its quiescence)
degrade    backend degradation (compile- or dispatch-triggered)
expire     a *queued* request passed its deadline       (terminal)
harvest    a resident request finished; ``status`` says how (terminal)
========== ==========================================================

Timestamps: every event carries the server's deterministic block clock
(``block``) and a wall-clock offset (``wall_s``).  Export with
``clock="block"`` (default; 1 block = 1000 us so Perfetto shows block
numbers as milliseconds -- deterministic, diffable) or ``clock="wall"``
(real time).

Track layout: one track (pid/tid pair) per slot under the "slots"
process, one per tenant under "tenants", plus a "server" track for
events not bound to a slot.  Slot spans run admit -> harvest/requeue;
tenant spans run submit -> terminal.
"""
from __future__ import annotations

import dataclasses
import json
import time

TERMINAL_KINDS = ("harvest", "expire", "drop")

# pids for the three track groups in the chrome export
_PID_SLOTS, _PID_TENANTS, _PID_SERVER = 1, 2, 3

US_PER_BLOCK = 1000  # block-clock export scale: 1 block == 1ms in Perfetto


class TraceInvariantError(AssertionError):
    """A trace export violated a lifecycle/clock invariant."""


@dataclasses.dataclass
class TraceEvent:
    kind: str
    block: int
    wall_s: float
    uid: int | None = None
    slot: int | None = None
    tenant: str | None = None
    status: str | None = None
    args: dict = dataclasses.field(default_factory=dict)


class TraceRecorder:
    """Append-only event log with Chrome trace-event export."""

    def __init__(self) -> None:
        self.events: list[TraceEvent] = []
        self._t0 = time.perf_counter()

    def record(self, kind: str, *, block: int, uid: int | None = None,
               slot: int | None = None, tenant: str | None = None,
               status: str | None = None, **args) -> TraceEvent:
        ev = TraceEvent(kind=kind, block=int(block),
                        wall_s=time.perf_counter() - self._t0,
                        uid=uid, slot=slot, tenant=tenant, status=status,
                        args=args)
        self.events.append(ev)
        return ev

    def __len__(self) -> int:
        return len(self.events)

    # ---------------------------------------------------------------- export
    def to_chrome(self, clock: str = "block") -> dict:
        """Render the log as a Chrome trace-event JSON object."""
        if clock not in ("block", "wall"):
            raise ValueError(f"clock must be 'block' or 'wall', got {clock!r}")

        def ts(ev: TraceEvent) -> float:
            if clock == "block":
                return ev.block * US_PER_BLOCK
            return ev.wall_s * 1e6

        out: list[dict] = []
        tenant_tids: dict[str, int] = {}
        seen_slots: set[int] = set()

        def meta(pid: int, tid: int, what: str, name: str) -> dict:
            return {"name": what, "ph": "M", "pid": pid, "tid": tid,
                    "args": {"name": name}}

        out.append(meta(_PID_SLOTS, 0, "process_name", "slots"))
        out.append(meta(_PID_TENANTS, 0, "process_name", "tenants"))
        out.append(meta(_PID_SERVER, 0, "process_name", "server"))

        def tenant_tid(tenant: str) -> int:
            if tenant not in tenant_tids:
                tenant_tids[tenant] = len(tenant_tids) + 1
                out.append(meta(_PID_TENANTS, tenant_tids[tenant],
                                "thread_name", str(tenant)))
            return tenant_tids[tenant]

        def slot_tid(slot: int) -> int:
            tid = slot + 1  # tid 0 is reserved for process metadata
            if slot not in seen_slots:
                seen_slots.add(slot)
                out.append(meta(_PID_SLOTS, tid, "thread_name", f"slot {slot}"))
            return tid

        def base_args(ev: TraceEvent) -> dict:
            args = {"block": ev.block, "wall_s": round(ev.wall_s, 6)}
            if ev.uid is not None:
                args["uid"] = ev.uid
            if ev.slot is not None:
                args["slot"] = ev.slot
            if ev.status is not None:
                args["status"] = ev.status
            if ev.tenant is not None:
                args["tenant"] = ev.tenant
            args.update(ev.args)
            return args

        for ev in self.events:
            args = base_args(ev)
            # slot spans: admit opens, harvest/requeue closes
            if ev.kind == "admit" and ev.slot is not None:
                out.append({"name": f"uid {ev.uid}", "ph": "B",
                            "pid": _PID_SLOTS, "tid": slot_tid(ev.slot),
                            "ts": ts(ev), "args": args})
            elif ev.kind in ("harvest", "requeue") and ev.slot is not None \
                    and ev.slot >= 0:
                out.append({"name": f"uid {ev.uid}", "ph": "E",
                            "pid": _PID_SLOTS, "tid": slot_tid(ev.slot),
                            "ts": ts(ev), "args": args})
            # tenant spans: submit opens, terminal closes.  Requests of
            # one tenant overlap (queued + resident), so these are async
            # events keyed by uid, not B/E (which must nest per track).
            if ev.kind == "submit" and ev.tenant is not None:
                out.append({"name": f"uid {ev.uid}", "cat": "request",
                            "id": ev.uid, "ph": "b",
                            "pid": _PID_TENANTS, "tid": tenant_tid(ev.tenant),
                            "ts": ts(ev), "args": args})
            elif ev.kind in TERMINAL_KINDS and ev.tenant is not None:
                out.append({"name": f"uid {ev.uid}", "cat": "request",
                            "id": ev.uid, "ph": "e",
                            "pid": _PID_TENANTS, "tid": tenant_tid(ev.tenant),
                            "ts": ts(ev), "args": args})
            # every event also lands as an instant on its home track
            if ev.slot is not None and ev.slot >= 0:
                pid, tid = _PID_SLOTS, slot_tid(ev.slot)
            elif ev.tenant is not None:
                pid, tid = _PID_TENANTS, tenant_tid(ev.tenant)
            else:
                pid, tid = _PID_SERVER, 1
            out.append({"name": ev.kind, "ph": "i", "s": "t",
                        "pid": pid, "tid": tid, "ts": ts(ev), "args": args})

        return {"traceEvents": out,
                "displayTimeUnit": "ms",
                "otherData": {"clock": clock,
                              "us_per_block": US_PER_BLOCK if clock == "block" else None}}

    def save(self, path: str, clock: str = "block") -> None:
        with open(path, "w") as fh:
            json.dump(self.to_chrome(clock), fh, indent=1)


def load_chrome(path: str) -> dict:
    with open(path) as fh:
        return json.load(fh)


def validate_chrome(trace: dict) -> dict:
    """Check a chrome export against the §12 invariants; raise on violation.

    Invariants:
      1. shape: a ``traceEvents`` list whose entries all carry
         name/ph/pid/tid (+ts for non-metadata) -- what Perfetto requires;
      2. monotone clocks: per track, timestamps never decrease in
         emission order;
      3. balanced spans: per track, B/E nest and the stack drains
         empty; async b/e pairs (tenant request spans) balance per id;
      4. lifecycle: every uid has exactly one submit and exactly one
         terminal event, and admits == requeues + slot-harvests.

    Returns ``{"events": n, "uids": n, "tracks": n}`` on success so
    callers can assert non-emptiness in one place.
    """
    if not isinstance(trace, dict) or not isinstance(trace.get("traceEvents"), list):
        raise TraceInvariantError("missing traceEvents list")
    events = trace["traceEvents"]

    last_ts: dict[tuple, float] = {}
    span_stack: dict[tuple, list[str]] = {}
    async_open: dict[tuple, int] = {}
    submits: dict[int, int] = {}
    terminals: dict[int, int] = {}
    admits: dict[int, int] = {}
    closes: dict[int, int] = {}

    for i, ev in enumerate(events):
        for field in ("name", "ph", "pid", "tid"):
            if field not in ev:
                raise TraceInvariantError(f"event {i} missing {field!r}: {ev}")
        if ev["ph"] == "M":
            continue
        if "ts" not in ev:
            raise TraceInvariantError(f"event {i} missing ts: {ev}")
        track = (ev["pid"], ev["tid"])
        ts = ev["ts"]
        if ts < last_ts.get(track, float("-inf")):
            raise TraceInvariantError(
                f"clock went backwards on track {track}: {ts} after {last_ts[track]}")
        last_ts[track] = ts
        if ev["ph"] == "b":
            async_open[(ev.get("cat"), ev.get("id"))] = \
                async_open.get((ev.get("cat"), ev.get("id")), 0) + 1
        elif ev["ph"] == "e":
            key = (ev.get("cat"), ev.get("id"))
            if async_open.get(key, 0) <= 0:
                raise TraceInvariantError(f"async end without begin: {ev}")
            async_open[key] -= 1
        elif ev["ph"] == "B":
            span_stack.setdefault(track, []).append(ev["name"])
        elif ev["ph"] == "E":
            stack = span_stack.get(track, [])
            if not stack:
                raise TraceInvariantError(f"unmatched end on track {track}: {ev}")
            opened = stack.pop()
            if opened != ev["name"]:
                raise TraceInvariantError(
                    f"mismatched span on track {track}: began {opened!r}, "
                    f"ended {ev['name']!r}")
        args = ev.get("args", {})
        uid = args.get("uid")
        if uid is not None and ev["ph"] == "i":
            kind = ev["name"]
            if kind == "submit":
                submits[uid] = submits.get(uid, 0) + 1
            if kind in TERMINAL_KINDS:
                terminals[uid] = terminals.get(uid, 0) + 1
            if kind == "admit":
                admits[uid] = admits.get(uid, 0) + 1
            if kind == "requeue" or (kind == "harvest"
                                     and args.get("slot", -1) >= 0):
                closes[uid] = closes.get(uid, 0) + 1

    open_tracks = {t: s for t, s in span_stack.items() if s}
    if open_tracks:
        raise TraceInvariantError(f"unbalanced spans left open: {open_tracks}")
    open_async = {k: n for k, n in async_open.items() if n}
    if open_async:
        raise TraceInvariantError(f"unbalanced async spans left open: {open_async}")
    for uid, n in submits.items():
        if n != 1:
            raise TraceInvariantError(f"uid {uid} submitted {n} times")
        if terminals.get(uid, 0) != 1:
            raise TraceInvariantError(
                f"uid {uid} has {terminals.get(uid, 0)} terminal events, want 1")
    for uid, n in terminals.items():
        if uid not in submits:
            raise TraceInvariantError(f"uid {uid} terminated without a submit")
    for uid, n in admits.items():
        if closes.get(uid, 0) != n:
            raise TraceInvariantError(
                f"uid {uid}: {n} admits but {closes.get(uid, 0)} slot closes")

    return {"events": len(events), "uids": len(submits), "tracks": len(last_ts)}
