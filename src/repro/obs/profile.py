"""FabricProfile -- per-node / per-arc counters from a profiled run.

The engine accumulates five int32 counter arrays in device state while
a fabric runs (see DESIGN.md §12 for the exact semantics):

- ``node_fires[n]`` : cycles in which node *n* fired.
- ``stall_in[n]``   : cycles in which *n*'s inputs were not ready.
- ``stall_out[n]``  : cycles in which inputs were ready but an output
  arc was still full (backpressure) -- or, for BRANCH/DMERGE, the
  selected output/input pairing blocked the fire.
- ``arc_busy[a]``   : cycles arc *a* held a token at the sample point
  (post-fire, pre-drain).
- ``arc_hw[a]``     : high-water token count on arc *a* (0 or 1 on this
  depth-1 fabric).

The three node counters partition the profiled cycles: for every node,
``node_fires + stall_in + stall_out == cycles``.  Counters are sampled
every *simulated* cycle, so ``cycles`` here can exceed
``EngineResult.cycles`` by up to K-1 idle tail cycles when the block
length K does not divide the quiescence point; ``node_fires`` is exact
regardless (nothing fires in an idle cycle).

All arrays are in **graph order** (the plan's node/arc permutations are
undone by the engine before this object is built).
"""
from __future__ import annotations

import dataclasses
import json
from typing import TYPE_CHECKING

import numpy as np

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.graph import Graph


@dataclasses.dataclass
class FabricProfile:
    """Counters for one fabric run (or one request's residency)."""

    node_names: list[str]
    arc_names: list[str]
    node_fires: np.ndarray  # int64[N]
    stall_in: np.ndarray    # int64[N]
    stall_out: np.ndarray   # int64[N]
    arc_busy: np.ndarray    # int64[A]
    arc_hw: np.ndarray      # int64[A]
    cycles: int             # simulated (profiled) cycles
    dispatches: int         # device dispatches that produced these counters

    # Channel counters -- present only on partitioned (multi-fabric) runs.
    # Channels are the inter-region arcs; each is a depth-1 register pair
    # replicated across shards, so busy/high-water obey the same bounds as
    # ordinary arcs.  ``ch_pushes`` counts tokens that crossed the channel,
    # i.e. the cut-arc traffic of the run.  ``ch_depth`` records the block
    # length K whose fused channel exchange the depth argument is about.
    ch_names: list[str] | None = None
    ch_busy: np.ndarray | None = None    # int64[C]
    ch_hw: np.ndarray | None = None      # int64[C]
    ch_pushes: np.ndarray | None = None  # int64[C]
    ch_depth: int | None = None

    # ---------------------------------------------------------------- derived
    @property
    def fired(self) -> int:
        """Total node firings -- equals ``EngineResult.fired`` exactly."""
        return int(self.node_fires.sum())

    def fires_per_cycle(self) -> np.ndarray:
        """Per-node firing rate over the profiled window (float64[N])."""
        c = max(self.cycles, 1)
        return self.node_fires.astype(np.float64) / c

    def occupancy(self) -> np.ndarray:
        """Per-arc fraction of cycles holding a token (float64[A])."""
        c = max(self.cycles, 1)
        return self.arc_busy.astype(np.float64) / c

    def utilization(self) -> float:
        """Fraction of node-cycles spent firing (the fabric's duty cycle)."""
        n = len(self.node_names)
        if n == 0 or self.cycles == 0:
            return 0.0
        return float(self.node_fires.sum()) / (n * self.cycles)

    def fires_per_dispatch(self) -> float:
        """Firings amortized per device dispatch (roofline numerator)."""
        return float(self.node_fires.sum()) / max(self.dispatches, 1)

    def top_nodes(self, k: int = 5) -> list[tuple[str, int]]:
        """The k hottest nodes by fire count."""
        order = np.argsort(self.node_fires)[::-1][:k]
        return [(self.node_names[i], int(self.node_fires[i])) for i in order]

    # ------------------------------------------------------------- validation
    def check(self) -> None:
        """Assert the counter partition invariant (DESIGN.md §12)."""
        total = self.node_fires + self.stall_in + self.stall_out
        if self.cycles and not (total == self.cycles).all():
            bad = int(np.argmax(total != self.cycles))
            raise AssertionError(
                f"profile partition broken at node {self.node_names[bad]}: "
                f"fires={int(self.node_fires[bad])} + stall_in="
                f"{int(self.stall_in[bad])} + stall_out="
                f"{int(self.stall_out[bad])} != cycles={self.cycles}")
        if (self.arc_busy > self.cycles).any():
            raise AssertionError("arc_busy exceeds profiled cycles")
        if (self.arc_hw > 1).any():
            raise AssertionError("arc high-water > 1 on a depth-1 fabric")
        if self.ch_busy is not None and (self.ch_busy > self.cycles).any():
            raise AssertionError("channel busy exceeds profiled cycles")
        if self.ch_hw is not None and (self.ch_hw > 1).any():
            raise AssertionError("channel high-water > 1 (register pair)")

    # ---------------------------------------------------------------- export
    def to_json(self) -> dict:
        out = {
            "cycles": int(self.cycles),
            "dispatches": int(self.dispatches),
            "fired": self.fired,
            "utilization": self.utilization(),
            "fires_per_dispatch": self.fires_per_dispatch(),
            "nodes": [
                {
                    "name": self.node_names[i],
                    "fires": int(self.node_fires[i]),
                    "stall_in": int(self.stall_in[i]),
                    "stall_out": int(self.stall_out[i]),
                }
                for i in range(len(self.node_names))
            ],
            "arcs": [
                {
                    "name": self.arc_names[i],
                    "busy": int(self.arc_busy[i]),
                    "high_water": int(self.arc_hw[i]),
                }
                for i in range(len(self.arc_names))
            ],
        }
        if self.ch_names is not None:
            out["channels"] = {
                "depth": int(self.ch_depth or 0),
                "arcs": [
                    {
                        "name": self.ch_names[i],
                        "busy": int(self.ch_busy[i]),
                        "high_water": int(self.ch_hw[i]),
                        "pushes": int(self.ch_pushes[i]),
                    }
                    for i in range(len(self.ch_names))
                ],
            }
        return out

    def save(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_json(), fh, indent=1)

    def summary(self) -> str:
        hot = ", ".join(f"{n}={c}" for n, c in self.top_nodes(3))
        return (f"cycles={self.cycles} fired={self.fired} "
                f"util={self.utilization():.3f} "
                f"fires/dispatch={self.fires_per_dispatch():.1f} hot[{hot}]")

    # ------------------------------------------------------------ constructor
    @staticmethod
    def names_for(graph: "Graph") -> tuple[list[str], list[str]]:
        node_names = [
            f"{i}:{node.op.name}" + (f":{node.name}" if getattr(node, "name", "") else "")
            for i, node in enumerate(graph.nodes)
        ]
        return node_names, list(graph.arcs)

    @classmethod
    def from_plan(
        cls,
        graph: "Graph",
        plan: dict,
        node_fires: np.ndarray,
        stall_in: np.ndarray,
        stall_out: np.ndarray,
        arc_busy: np.ndarray,
        arc_hw: np.ndarray,
        cycles: int,
        dispatches: int,
    ) -> "FabricProfile":
        """Undo the plan's node/arc permutations -> graph-order arrays.

        The counter arrays arrive in plan order and may carry padding
        rows (the pallas tables append a dummy node; the arc axis has
        FULL_PAD/EMPTY_PAD slots) -- both are sliced away here.
        """
        node_names, arc_names = cls.names_for(graph)
        node_inv = np.asarray(plan["node_inv"])          # graph idx -> plan row
        aidx = plan["aidx"]                              # arc name -> plan slot
        arc_rows = np.array([aidx[a] for a in graph.arcs], dtype=np.int64)
        return cls(
            node_names=node_names,
            arc_names=arc_names,
            node_fires=np.asarray(node_fires, dtype=np.int64)[node_inv],
            stall_in=np.asarray(stall_in, dtype=np.int64)[node_inv],
            stall_out=np.asarray(stall_out, dtype=np.int64)[node_inv],
            arc_busy=np.asarray(arc_busy, dtype=np.int64)[arc_rows],
            arc_hw=np.asarray(arc_hw, dtype=np.int64)[arc_rows],
            cycles=int(cycles),
            dispatches=int(dispatches),
        )
