"""repro.obs -- fabric observability (DESIGN.md §12).

Three layers, from device to host:

- ``profile``  : per-node / per-arc fabric counters (fire counts, stall
  attribution, arc occupancy) accumulated in device state by the block
  kernels and surfaced as a :class:`FabricProfile`.
- ``trace``    : :class:`TraceRecorder`, a block-clock event log of the
  slot-lifecycle state machine (DESIGN.md §11), exportable as Chrome
  trace-event JSON loadable in Perfetto.
- ``metrics``  : :class:`MetricsRegistry`, process-local counters /
  gauges / histograms with a JSON snapshot.

Nothing in this package imports jax: the engine hands over plain numpy
arrays, so obs stays importable from any host-side tool.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               validate_snapshot)
from repro.obs.profile import FabricProfile
from repro.obs.trace import (
    TraceInvariantError,
    TraceRecorder,
    load_chrome,
    validate_chrome,
)

__all__ = [
    "Counter",
    "FabricProfile",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "TraceInvariantError",
    "TraceRecorder",
    "load_chrome",
    "validate_chrome",
    "validate_snapshot",
]
