"""Sharding rules: parameter/batch/cache PartitionSpecs for the 2D
(data, model) production mesh (3D with a leading "pod" axis multi-pod).

Strategy (DESIGN.md §6):
  * TP (Megatron): attention QKV / MLP up column-sharded on `model`,
    out/down row-sharded on `model`; vocab sharded on `model`.
  * FSDP/ZeRO-3: the *other* large dim of every weight sharded over the
    data axes; optimizer moments follow parameters, giving ZeRO
    partitioning for free.  XLA inserts the per-layer all-gathers.
  * EP: MoE expert dim sharded on `model` (expert-parallel); token
    dispatch lowers to all-to-all on the (data × model) mesh.
  * DP: batch over ("pod", "data").
  * KV cache: heads on `model` when divisible, else head_dim on `model`
    (GQA kv-heads < mesh); batch=1 long-context shards the cache's
    *sequence* dim over `data` instead of batch.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def data_axes(mesh: Mesh):
    """The DP axes: ("pod","data") on a multi-pod mesh, else "data"."""
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def _dp_size(mesh: Mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n


@dataclasses.dataclass(frozen=True)
class ShardPolicy:
    fsdp: bool = True       # shard the non-TP dim of weights over data
    seq_shard_cache: bool = False  # force sequence-sharded kv cache


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------
_COL = {"wqkv", "wq", "wk", "wv", "w1", "w3", "fc1", "in_proj",
        "Wr", "Wk", "Wv", "Wg", "Wk_cm"}
_ROW = {"wo", "w2", "fc2", "out_proj", "Wo", "Wv_cm", "Wr_cm"}


def _param_rule(path: tuple, shape: tuple, mesh, policy) -> P:
    DATA = data_axes(mesh)
    dp = _dp_size(mesh)
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    leaf = names[-1]
    stacked = any(n in ("layers", "dense_layers", "xattn", "enc_layers")
                  for n in names)
    lead: list = [None] if stacked else []
    body_shape = shape[1:] if stacked else shape

    def spec(*axes):
        # drop shardings that don't divide the dim evenly (e.g. whisper's
        # vocab 51865 on a 16-way model axis; a production fix is Megatron
        # vocab padding — see EXPERIMENTS.md §Perf notes)
        out = []
        for dim, ax in zip(body_shape, axes):
            if ax is None:
                out.append(None)
            else:
                size = (dp if ax == DATA else mesh.shape[ax]
                        if isinstance(ax, str) else dp)
                out.append(ax if dim % size == 0 else None)
        return P(*lead, *out)

    d = None if not policy.fsdp else DATA
    if leaf == "embed":
        return spec("model", d)
    if leaf in ("head", "patch_proj", "frame_proj"):
        return spec(d, "model")
    if leaf == "router":
        return spec(d, None)
    if leaf in ("w1", "w3", "w2") and len(body_shape) == 3:  # MoE experts
        return spec("model", d, None)
    if leaf in _COL and len(body_shape) == 2:
        return spec(d, "model")
    if leaf in _ROW and len(body_shape) == 2:
        return spec("model", d)
    if leaf == "conv_w":
        return spec("model", None)
    if leaf == "wA":
        return spec(d, None)
    if leaf == "wB":
        return spec(None, d)
    if len(body_shape) >= 2:
        # fallback for any 2D+: shard largest dim over data
        big = int(np.argmax(body_shape))
        axes = [None] * len(body_shape)
        if policy.fsdp:
            axes[big] = DATA
        return spec(*axes)
    return P(*lead, *([None] * len(body_shape)))


def param_specs(struct_tree, mesh: Mesh,
                policy: ShardPolicy = ShardPolicy()):
    """PartitionSpec tree matching a params (or adam-moments) pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda p, x: _param_rule(p, x.shape, mesh, policy), struct_tree)


def shardings_of(spec_tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# batches
# ---------------------------------------------------------------------------
def batch_specs(cfg, mesh: Mesh, batch_tree, global_batch: int):
    DATA = data_axes(mesh)
    dp = _dp_size(mesh)
    b = DATA if global_batch % dp == 0 else None

    def rule(path, x):
        return P(b, *([None] * (x.ndim - 1)))

    return jax.tree_util.tree_map_with_path(rule, batch_tree)


# ---------------------------------------------------------------------------
# decode caches / states
# ---------------------------------------------------------------------------
def cache_specs(cfg, mesh: Mesh, cache_tree, global_batch: int):
    DATA = data_axes(mesh)
    dp = _dp_size(mesh)
    mp = mesh.shape["model"]
    b = DATA if global_batch % dp == 0 else None
    seq_data = b is None   # batch unshardable -> shard cache seq over data

    def rule(path, x):
        names = [getattr(k, "key", getattr(k, "name", str(k)))
                 for k in path]
        leaf = names[-1]
        if leaf in ("k", "v") or "cross" in names:
            # [L, B, S, Hkv, hd]
            L, B, S, Hkv, hd = x.shape
            heads_ok = Hkv % mp == 0
            return P(None, b, DATA if seq_data else None,
                     "model" if heads_ok else None,
                     None if heads_ok else ("model" if hd % mp == 0
                                            else None))
        if leaf == "len":
            return P()
        if leaf == "S":        # rwkv state [L,B,H,P,P]
            H = x.shape[2]
            return P(None, b, "model" if H % mp == 0 else None, None, None)
        if leaf == "h":        # mamba state [L,B,H,P,N]
            H = x.shape[2]
            return P(None, b, "model" if H % mp == 0 else None, None, None)
        if leaf == "conv":     # [L,B,K-1,conv_dim]
            cd = x.shape[-1]
            return P(None, b, None, "model" if cd % mp == 0 else None)
        if leaf in ("x_tm", "x_cm"):   # [L,B,1,d]
            d = x.shape[-1]
            return P(None, b, None, "model" if d % mp == 0 else None)
        return P(*([None] * x.ndim))

    return jax.tree_util.tree_map_with_path(rule, cache_tree)


def replicated(mesh: Mesh, tree):
    return jax.tree.map(lambda x: NamedSharding(mesh, P()), tree)
