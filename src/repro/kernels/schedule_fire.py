"""Pallas lowering of static firing schedules (DESIGN.md §13).

Two entry points, mirroring the dynamic kernels in dataflow_fire.py:

* :func:`make_sched_run` wraps the schedule context's straight-line
  scheduled program (prologue unrolled, each steady-state period fused
  into one ``fori_loop`` body) in a single ``pallas_call`` — the whole
  run is one kernel, arc registers live as kernel-local SSA values,
  and there is no ready-mask reduction anywhere.  The batched variant
  uses the same ``grid=(B,)`` row-block layout as
  ``fire_block_batched_pallas``.
* :func:`make_sched_slot_step` is the scheduled block step for the
  resumable slot API: per-pattern gather tables broadcast across the
  grid, a host-computed pid sequence per slot row, K table-driven
  cycles per dispatch.  Inactive slots ride pid 0 (a no-op pattern)
  with ``fsel == -1`` gating the post-block register update, exactly
  like the dynamic kernels' clock gate.

The scheduled programs bake per-pattern index vectors as trace-time
constants; ``pallas_call`` forbids captured array constants, so both
wrappers trace the program to a jaxpr once, hoist its constvars, and
feed them back in as ordinary kernel operands (``jax.closure_convert``
is not enough — it only hoists tracer-derived consts, not baked numpy
arrays).

Scalar int32 tokens only — the pallas backend's standing contract.
Kernels run in interpret mode on CPU (no TPU in CI), compiled on
accelerator backends.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hoist(fn, *example_args):
    """Trace ``fn`` to a jaxpr and hoist its constvars: returns
    ``(fn_c, consts)`` with ``fn_c(*args, *consts)`` equivalent to
    ``fn(*args)`` but capture-free (every baked array becomes an
    explicit operand, as pallas_call requires).  All example args and
    outputs must be flat arrays (they are — scheduled state is a flat
    tuple of int32 rows)."""
    closed = jax.make_jaxpr(fn)(*example_args)
    jaxpr, consts = closed.jaxpr, list(closed.consts)
    n_args = len(example_args)
    n_out = len(jaxpr.outvars)

    def fn_c(*args_and_consts):
        args = args_and_consts[:n_args]
        cs = args_and_consts[n_args:]
        out = jax.core.eval_jaxpr(jaxpr, cs, *args)
        return out[0] if n_out == 1 else tuple(out)
    return fn_c, consts


def _whole_s(shape):
    """Whole-operand block (broadcast across the grid)."""
    n = len(shape)
    return pl.BlockSpec(tuple(shape), lambda *_, n=n: (0,) * n)


def _row_s(shape):
    """Per-grid-step row block (leading batch axis)."""
    n = len(shape)
    return pl.BlockSpec((1, *shape[1:]), lambda b, n=n: (b,) + (0,) * (n - 1))


def make_sched_run(fn, n_out: int, batched: bool):
    """Pallas wrapper around the scheduled straight-line program
    ``fn(fv, reps) -> (out_last, out_count)``.

    fv[n_in, L] int32 (leading B axis when batched), reps int32[R]
    carries the traced fori_loop trip counts, so one kernel serves
    every feed-length tuple that shares the schedule structure.
    Compiled callables cache per operand shape."""
    cache = {}

    def _build(fv_shape, reps_shape):
        row_shape = fv_shape[1:] if batched else fv_shape
        fn_c, consts = _hoist(
            fn, jnp.zeros(row_shape, jnp.int32),
            jnp.zeros(reps_shape, jnp.int32))
        nc = len(consts)
        interpret = jax.default_backend() == "cpu"
        if not batched:
            out_sd = [jax.ShapeDtypeStruct((n_out,), jnp.int32),
                      jax.ShapeDtypeStruct((n_out,), jnp.int32)]

            def kern(*refs):
                fv_r, reps_r = refs[0], refs[1]
                cs = [r[...] for r in refs[2:2 + nc]]
                ol_r, oc_r = refs[2 + nc], refs[3 + nc]
                ol, oc = fn_c(fv_r[...], reps_r[...], *cs)
                ol_r[...] = ol
                oc_r[...] = oc
            pc = pl.pallas_call(
                kern,
                in_specs=[_whole_s(fv_shape), _whole_s(reps_shape)]
                + [_whole_s(c.shape) for c in consts],
                out_specs=[_whole_s(s.shape) for s in out_sd],
                out_shape=out_sd,
                interpret=interpret)
        else:
            B = fv_shape[0]
            out_sd = [jax.ShapeDtypeStruct((B, n_out), jnp.int32),
                      jax.ShapeDtypeStruct((B, n_out), jnp.int32)]

            def kern(*refs):
                fv_r, reps_r = refs[0], refs[1]
                cs = [r[...] for r in refs[2:2 + nc]]
                ol_r, oc_r = refs[2 + nc], refs[3 + nc]
                ol, oc = fn_c(fv_r[0], reps_r[...], *cs)
                ol_r[0] = ol
                oc_r[0] = oc
            pc = pl.pallas_call(
                kern, grid=(B,),
                in_specs=[_row_s(fv_shape), _whole_s(reps_shape)]
                + [_whole_s(c.shape) for c in consts],
                out_specs=[_row_s(s.shape) for s in out_sd],
                out_shape=out_sd,
                interpret=interpret)
        return jax.jit(lambda fv, reps: pc(fv, reps, *consts))

    def runner(fv, reps):
        key = (tuple(fv.shape), tuple(reps.shape))
        call = cache.get(key)
        if call is None:
            call = cache[key] = _build(*key)
        return call(fv, reps)
    return runner


def make_sched_slot_step(ctx, n_cycles: int):
    """Scheduled slot block step, grid=(B,): each slot row executes
    ``n_cycles`` table-driven scheduled cycles (its host-computed pid
    sequence) and lands on the pattern-exact post-block registers.

    Call signature (mirrors the xla vmapped stepper):
    (fv[B,n_in,L], pids[B,K], fsel[B], full[B,A2], val[B,A2],
    ptr[B,n_in], out_last[B,n_out], out_count[B,n_out], *tables)
    -> (full', val', ptr', out_last', out_count')."""
    cache = {}

    def _build(shapes):
        (fv_s, pids_s, fsel_s, *st_s), tab_s = shapes[:8], shapes[8:]
        nt = len(tab_s)

        def body(fv, pids, fsel, full, val, ptr, ol, oc, *tabs):
            return ctx.slot_body(tabs, fv, pids, fsel, full, val,
                                 ptr, ol, oc, n_cycles)
        ex = [jnp.zeros(fv_s[1:], jnp.int32),
              jnp.zeros(pids_s[1:], jnp.int32),
              jnp.zeros((), jnp.int32)] \
            + [jnp.zeros(s[1:], jnp.int32) for s in st_s] \
            + [jnp.zeros(s, jnp.int32) for s in tab_s]
        body_c, consts = _hoist(body, *ex)
        nc = len(consts)
        out_sd = [jax.ShapeDtypeStruct(s, jnp.int32) for s in st_s]
        B = fv_s[0]

        def kern(*refs):
            fv_r, pids_r, fsel_r = refs[0], refs[1], refs[2]
            st_r = refs[3:8]
            tab_r = refs[8:8 + nt]
            c_r = refs[8 + nt:8 + nt + nc]
            out_r = refs[8 + nt + nc:]
            res = body_c(fv_r[0], pids_r[0], fsel_r[0],
                         *(s[0] for s in st_r),
                         *(t[...] for t in tab_r),
                         *(c[...] for c in c_r))
            for r, v in zip(out_r, res):
                r[0] = v
        pc = pl.pallas_call(
            kern, grid=(B,),
            in_specs=[_row_s(fv_s), _row_s(pids_s),
                      pl.BlockSpec((1,), lambda b: (b,))]
            + [_row_s(s) for s in st_s]
            + [_whole_s(s) for s in tab_s]
            + [_whole_s(c.shape) for c in consts],
            out_specs=[_row_s(s.shape) for s in out_sd],
            out_shape=out_sd,
            interpret=jax.default_backend() == "cpu")
        return jax.jit(lambda *a: pc(*a, *consts))

    def runner(fv, pids, fsel, full, val, ptr, ol, oc, *tabs):
        args = (fv, pids, fsel, full, val, ptr, ol, oc, *tabs)
        key = tuple(tuple(x.shape) for x in args)
        call = cache.get(key)
        if call is None:
            call = cache[key] = _build(key)
        return call(*args)
    return runner
