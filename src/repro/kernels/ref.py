"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import naive_attention
from repro.kernels.dataflow_fire import _TABLE_KEYS, _block_body, _fire_body


def flash_attention_ref(q, k, v, *, causal=True):
    return naive_attention(q, k, v, causal=causal)


def rmsnorm_ref(x, w, eps=1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) *
            w.astype(jnp.float32)).astype(x.dtype)


def fire_step_ref(tables, full, val):
    """Same math as the kernel body, plain jnp (no pallas_call)."""
    return _fire_body(
        jnp.asarray(tables["opcode"]), jnp.asarray(tables["in_idx"]),
        jnp.asarray(tables["out_idx"]), jnp.asarray(tables["prod_node"]),
        jnp.asarray(tables["prod_slot"]), jnp.asarray(tables["cons_node"]),
        jnp.asarray(tables["cons_slot"]), jnp.asarray(tables["const_mask"]),
        full, val)


def fire_block_ref(tables, feed_vals, feed_len, full, val, ptr, out_last,
                   out_count, *, n_cycles: int):
    """Same math as the fused block kernel, plain jnp (no pallas_call).
    Also the vmap target for the batched-stream path."""
    tab = {k: jnp.asarray(tables[k]) for k in _TABLE_KEYS}
    return _block_body(tab, jnp.asarray(feed_vals), jnp.asarray(feed_len),
                       full, val, ptr, out_last, out_count,
                       n_cycles=n_cycles)
