"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import naive_attention
from repro.kernels.dataflow_fire import _TABLE_KEYS, _block_body, _fire_body


def flash_attention_ref(q, k, v, *, causal=True):
    return naive_attention(q, k, v, causal=causal)


def rmsnorm_ref(x, w, eps=1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) *
            w.astype(jnp.float32)).astype(x.dtype)


def fire_step_ref(tables, full, val):
    """Same math as the kernel body, plain jnp (no pallas_call)."""
    return _fire_body(
        jnp.asarray(tables["opcode"]), jnp.asarray(tables["in_idx"]),
        jnp.asarray(tables["out_idx"]), jnp.asarray(tables["prod_node"]),
        jnp.asarray(tables["prod_slot"]), jnp.asarray(tables["cons_node"]),
        jnp.asarray(tables["cons_slot"]), jnp.asarray(tables["const_mask"]),
        full, val)


def fire_block_ref(tables, feed_vals, feed_len, full, val, ptr, out_last,
                   out_count, *, n_cycles: int, prof=None):
    """Same math as the fused block kernel, plain jnp (no pallas_call).
    Also the vmap target for the batched-stream path.  ``prof`` is an
    optional 5-tuple of §12 counter arrays (nf, si, so, ab, ahw); when
    given the return tuple gains the accumulated counters after
    last_prog."""
    tab = {k: jnp.asarray(tables[k]) for k in _TABLE_KEYS}
    return _block_body(tab, jnp.asarray(feed_vals), jnp.asarray(feed_len),
                       full, val, ptr, out_last, out_count,
                       n_cycles=n_cycles,
                       class_slices=tables.get("class_slices")
                       if hasattr(tables, "get") else None,
                       prof=prof)


def fire_block_masked_ref(tables, feed_vals, feed_len, full, val, ptr,
                          out_last, out_count, active, *, n_cycles: int):
    """Single-stream block step gated by a scalar ``active`` flag — the
    pure-jnp mirror of the batched kernel's per-stream clock gate.  When
    active == 0 the state passes through untouched and fired/last_prog
    report 0.  vmapping this over a leading B axis gives the xla
    backend's slot stepper (a `where`-select per row; the Pallas kernel
    genuinely skips the block via `lax.cond`)."""
    res = fire_block_ref(tables, feed_vals, feed_len, full, val, ptr,
                         out_last, out_count, n_cycles=n_cycles)
    keep = active != 0
    old = (full, val, ptr, out_last, out_count)
    kept = tuple(jnp.where(keep, n, o) for n, o in zip(res[:5], old))
    return (*kept, jnp.where(keep, res[5], 0), jnp.where(keep, res[6], 0))


def fire_block_masked_prof_ref(tables, feed_vals, feed_len, full, val, ptr,
                               out_last, out_count, active, nf, si, so, ab,
                               ahw, *, n_cycles: int):
    """Profiled variant of fire_block_masked_ref: threads the §12 fabric
    counters (nf, si, so, ab, ahw) through the block and returns them
    after last_prog.  Clock-gated slots (active == 0) keep their old
    counters untouched — their block never happened, so the per-slot
    partition invariant nf+si+so == profiled-cycles holds."""
    prof = (nf, si, so, ab, ahw)
    res = fire_block_ref(tables, feed_vals, feed_len, full, val, ptr,
                         out_last, out_count, n_cycles=n_cycles, prof=prof)
    keep = active != 0
    old = (full, val, ptr, out_last, out_count)
    kept = tuple(jnp.where(keep, n, o) for n, o in zip(res[:5], old))
    kept_prof = tuple(jnp.where(keep, n, o)
                      for n, o in zip(res[7:12], prof))
    return (*kept, jnp.where(keep, res[5], 0), jnp.where(keep, res[6], 0),
            *kept_prof)
