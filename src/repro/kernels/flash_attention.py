"""Pallas TPU flash attention (GQA-aware, causal).

Grid: (batch*heads, q_blocks, kv_blocks); the kv axis is the innermost
(sequential on TPU) so (m, l, acc) accumulators live in VMEM scratch
across kv steps.  BlockSpecs keep one [bq, hd] q tile, one [bk, hd] k/v
tile and the f32 accumulators in VMEM; hd and block sizes should be
multiples of 128 on real hardware (validated shapes in tests cover
smaller tiles via interpret mode).

K/V are GQA-shaped [B, Skv, Hkv, hd]; the index map folds the q-head ->
kv-head mapping (h // group) so no materialized head broadcast is needed.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, causal, bq, bk, n_kv, seq_kv):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # [bq, hd]
    k = k_ref[0].astype(jnp.float32)                  # [bk, hd]
    v = v_ref[0].astype(jnp.float32)
    s = q @ k.T                                       # [bq, bk]
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < seq_kv
    if causal:
        mask &= qpos >= kpos
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal=True, bq=128, bk=128,
                           interpret=None):
    """q: [B, Sq, H, hd]; k,v: [B, Skv, Hkv, hd] -> [B, Sq, H, hd]."""
    B, Sq, H, hd = q.shape
    _, Skv, Hkv, _ = k.shape
    G = H // Hkv
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    pad_q = (-Sq) % bq
    pad_k = (-Skv) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    n_q = (Sq + pad_q) // bq
    n_kv = (Skv + pad_k) // bk
    if interpret is None:
        interpret = jax.default_backend() == "cpu"

    # layout: [B*H, Sq, hd] for q/o ; k/v indexed through head map
    qr = q.transpose(0, 2, 1, 3).reshape(B * H, Sq + pad_q, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv + pad_k, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv + pad_k, hd)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        return (bh // H * Hkv + (bh % H) // G, ki, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=1.0 / math.sqrt(hd),
                          causal=causal, bq=bq, bk=bk, n_kv=n_kv,
                          seq_kv=Skv),
        grid=(B * H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, hd), q_map),
            pl.BlockSpec((1, bk, hd), kv_map),
            pl.BlockSpec((1, bk, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq + pad_q, hd), q.dtype),
        scratch_shapes=[
            pltpu_scratch((bq,), jnp.float32),
            pltpu_scratch((bq,), jnp.float32),
            pltpu_scratch((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    out = out.reshape(B, H, Sq + pad_q, hd)[:, :, :Sq]
    return out.transpose(0, 2, 1, 3)


def pltpu_scratch(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
