"""Jitted public wrappers for the Pallas kernels.

On TPU these call the compiled kernels; on CPU (this container) they run
in interpret mode — same kernel body, Python-evaluated — so correctness
is validated everywhere while the BlockSpec tiling targets real TPUs.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.dataflow_fire import (block_plan_arrays,
                                         fire_block_batched_pallas,
                                         fire_block_pallas,
                                         fire_step_pallas, plan_arrays)
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk"))
def flash_attention(q, k, v, *, causal=True, bq=128, bk=128):
    return flash_attention_pallas(q, k, v, causal=causal, bq=bq, bk=bk)


@functools.partial(jax.jit, static_argnames=("eps", "rows_blk"))
def rmsnorm(x, w, eps=1e-5, rows_blk=256):
    return rmsnorm_pallas(x, w, eps=eps, rows_blk=rows_blk)


_STATIC_TABLE_KEYS = ("plan", "class_slices")   # never device arrays


def _device_tables(tables):
    """jnp copies of the array tables; static entries pass through."""
    import jax.numpy as jnp
    jt = {k: jnp.asarray(v) for k, v in tables.items()
          if k not in _STATIC_TABLE_KEYS}
    for k in _STATIC_TABLE_KEYS:
        if k in tables:
            jt[k] = tables[k]
    return jt


def make_fire_step(graph):
    """Compile the dataflow fire-step kernel for a fabric; returns
    (tables, jitted fn(full, val) -> (full', val', fired))."""
    tables = plan_arrays(graph)
    jt = _device_tables(tables)

    @jax.jit
    def step(full, val):
        return fire_step_pallas(jt, full, val)

    return tables, step


def make_block_step(graph, n_cycles: int, batched: bool = False,
                    tables=None, optimize: bool = False,
                    profile: bool = False):
    """Compile the fused K-cycle fire-block kernel for a fabric.

    Returns (tables, jitted step).  Single-stream step signature:
      step(feed_vals, feed_len, full, val, ptr, out_last, out_count)
        -> (full', val', ptr', out_last', out_count', fired[1],
            last_prog[1])
    With batched=True every array gains a leading B axis (grid over
    streams inside the kernel; one dispatch for all B) and the step
    takes a trailing ``active`` int32[B] clock gate: slots with
    active == 0 skip the block entirely (state frozen, fired/last_prog
    0) — pass ``jnp.ones((B,), jnp.int32)`` for the plain wave-batch
    semantics.  Pass a prior call's `tables` to reuse the plan instead
    of rebuilding it; ``optimize=True`` builds opcode-class-specialized
    tables (ignored when `tables` is given — the tables carry their own
    ``class_slices``).  With profile=True the step takes five trailing
    §12 counter arrays (nf, si, so, ab, ahw — per-stream rows when
    batched) and returns them, accumulated in-kernel, after last_prog:
    profiling adds zero extra dispatches."""
    if tables is None:
        tables = block_plan_arrays(graph, optimize=optimize)
    jt = _device_tables(tables)

    if batched:
        if profile:
            @jax.jit
            def step(feed_vals, feed_len, full, val, ptr, out_last,
                     out_count, active, nf, si, so, ab, ahw):
                return fire_block_batched_pallas(
                    jt, feed_vals, feed_len, full, val, ptr, out_last,
                    out_count, n_cycles=n_cycles, active=active,
                    prof=(nf, si, so, ab, ahw))
        else:
            @jax.jit
            def step(feed_vals, feed_len, full, val, ptr, out_last,
                     out_count, active):
                return fire_block_batched_pallas(
                    jt, feed_vals, feed_len, full, val, ptr, out_last,
                    out_count, n_cycles=n_cycles, active=active)
    elif profile:
        @jax.jit
        def step(feed_vals, feed_len, full, val, ptr, out_last, out_count,
                 nf, si, so, ab, ahw):
            return fire_block_pallas(
                jt, feed_vals, feed_len, full, val, ptr, out_last,
                out_count, n_cycles=n_cycles, prof=(nf, si, so, ab, ahw))
    else:
        @jax.jit
        def step(feed_vals, feed_len, full, val, ptr, out_last, out_count):
            return fire_block_pallas(
                jt, feed_vals, feed_len, full, val, ptr, out_last,
                out_count, n_cycles=n_cycles)

    return tables, step


def run_fabric(graph, feeds, dtype=None, max_cycles: int = 10_000,
               compiled=None):
    """Drive a fabric to completion using the per-cycle Pallas fire-step
    kernel, with the environment (feed/drain) handled host-side: ONE
    device dispatch per engine cycle.  This is the seed baseline the
    fused block engine (DataflowEngine backend="pallas") is benchmarked
    against.  Pass compiled=(tables, step) from make_fire_step to reuse
    a compilation across calls.  Returns an EngineResult mirroring
    repro.core.engine semantics (dispatches = cycles)."""
    import numpy as np
    from repro.core.engine import EngineResult

    tables, step = compiled if compiled is not None \
        else make_fire_step(graph)
    p = tables["plan"]
    A2 = p["A"] + 2
    full = np.zeros((A2,), np.int32)
    val = np.zeros((A2,), np.int32)
    full[p["FULL_PAD"]] = 1
    for a, v in graph.consts.items():
        full[p["aidx"][a]] = 1
        val[p["aidx"][a]] = int(v)
    feeds = {a: np.asarray(v, np.int32).reshape(-1)
             for a, v in (feeds or {}).items()}
    ptr = {a: 0 for a in p["input_arcs"]}
    out_last = {a: np.int32(0) for a in p["output_arcs"]}
    out_count = {a: 0 for a in p["output_arcs"]}
    cycles = fired = 0
    progress = True
    while progress and cycles < max_cycles:
        progress = False
        for a in p["input_arcs"]:
            i = p["aidx"][a]
            if not full[i] and a in feeds and ptr[a] < len(feeds[a]):
                val[i] = feeds[a][ptr[a]]
                full[i] = 1
                ptr[a] += 1
                progress = True
        nf, nv, nfired = step(full, val)
        full, val = np.asarray(nf).copy(), np.asarray(nv).copy()
        full[p["EMPTY_PAD"]] = 0
        full[p["FULL_PAD"]] = 1
        k = int(nfired[0])
        fired += k
        progress = progress or k > 0
        for a in p["output_arcs"]:
            i = p["aidx"][a]
            if full[i]:
                out_last[a] = val[i]
                out_count[a] += 1
                full[i] = 0
                progress = True
        cycles += 1
    return EngineResult(outputs=out_last, counts=out_count, cycles=cycles,
                        fired=fired, dispatches=cycles)
