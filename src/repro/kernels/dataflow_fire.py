"""Pallas kernels for static-dataflow engine cycles ("fire steps").

The paper's FPGA executes all ready operators concurrently; on TPU the
cycle is one vectorized pass.  The kernels are *gather-only*
(TPU-friendly, no scatters): node-side arrays compute readiness and
results, then each arc pulls its next state from its (unique)
producer/consumer — legal precisely BECAUSE of the paper's
one-sender/one-receiver channel rule.

Two granularities:

* ``fire_step_pallas``  — ONE engine cycle per ``pallas_call``; the
  environment (input strobe / output drain) is handled by the caller.
  Kept as the per-cycle baseline (and for tests of the bare fire rule).
* ``fire_block_pallas`` — K engine cycles per ``pallas_call`` via an
  in-kernel ``lax.fori_loop``.  The ``full``/``val`` arc registers stay
  VMEM-resident across all K cycles and the *environment itself runs
  inside the kernel*: input arcs are strobed from per-arc feed streams
  (``feed_vals``/``feed_len`` with a per-arc pointer) and output arcs
  are drained into last-value + token-count accumulators.  Quiescence
  is only observable at block granularity — the kernel reports the
  relative cycle of the last progress (``last_prog``), and the host
  stops when a block's tail goes idle (idle is absorbing: no feed, no
  fire, no drain can re-arm without one of the others).  This replaces
  one device dispatch + HBM round-trip per cycle with one per K cycles.
  ``fire_block_batched_pallas`` adds an explicit batch grid dimension:
  B independent token streams ride one fabric in a single dispatch.

Inputs (all VMEM-resident; fabrics are small — one FPGA's worth):
  full[A2] int32, val[A2] int32       arc registers (+2 dummy slots)
  opcode[N2], in_idx[N2,3], out_idx[N2,2]   node table (+1 dummy node)
  prod_node/prod_slot[A2], cons_node/cons_slot[A2]  arc adjacency
  const_mask[A2], env_row[A2], out_mask[A2]         environment maps
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.graph import Op


def _ready_and_z(opcode, in_idx, out_idx, full, val, class_slices=None):
    """Vectorized firing rule (shared by kernel and ref).

    class_slices — static ``((opcode, start, stop), ...)`` from an
    opcode-specialized plan (DESIGN.md §8).  When given, the node table
    is permuted so equal opcodes are contiguous and the rule unrolls a
    static loop over only the classes present: each bucket computes its
    exact ALU result on its slice instead of the dense ~20-way
    ``where``-chain, and the shift/div guards are only traced for
    SHL/SHR/DIV buckets.  Bit-identical to the dense rule."""
    if class_slices is not None:
        return _ready_and_z_spec(class_slices, in_idx, out_idx, full, val)
    inf = full[in_idx] > 0                    # [N,3]
    oute = full[out_idx] == 0                 # [N,2]
    a = val[in_idx[:, 0]]
    b = val[in_idx[:, 1]]
    c = val[in_idx[:, 2]]
    all_in = inf.all(axis=1)
    all_out = oute.all(axis=1)

    is_nd = opcode == int(Op.NDMERGE)
    is_dm = opcode == int(Op.DMERGE)
    is_br = opcode == int(Op.BRANCH)
    ctrl3 = c != 0
    ctrl2 = b != 0

    dm_chosen = jnp.where(ctrl3, inf[:, 0], inf[:, 1])
    ready = all_in & all_out
    ready = jnp.where(is_nd, (inf[:, 0] | inf[:, 1]) & all_out, ready)
    ready = jnp.where(is_dm, inf[:, 2] & dm_chosen & all_out, ready)
    ready = jnp.where(is_br, inf[:, 0] & inf[:, 1] &
                      jnp.where(ctrl2, oute[:, 0], oute[:, 1]), ready)

    bs = jnp.clip(b, 0, 31)
    safe_b = jnp.where(b == 0, 1, b)
    zs = {
        Op.ADD: a + b, Op.SUB: a - b, Op.MUL: a * b,
        Op.DIV: jnp.where(b == 0, 0, a // safe_b),
        Op.AND: a & b, Op.OR: a | b, Op.XOR: a ^ b,
        Op.MAX: jnp.maximum(a, b), Op.MIN: jnp.minimum(a, b),
        Op.SHL: a << bs, Op.SHR: a >> bs,
        Op.NOT: (a == 0).astype(a.dtype),
        Op.IFGT: (a > b).astype(a.dtype), Op.IFGE: (a >= b).astype(a.dtype),
        Op.IFLT: (a < b).astype(a.dtype), Op.IFLE: (a <= b).astype(a.dtype),
        Op.IFEQ: (a == b).astype(a.dtype),
        Op.IFDF: (a != b).astype(a.dtype),
        Op.NDMERGE: jnp.where(inf[:, 0], a, b),
        Op.DMERGE: jnp.where(ctrl3, a, b),
    }
    z = a
    for op, r in zs.items():
        z = jnp.where(opcode == int(op), r, z)

    # per-slot consume/produce masks
    nd_pick = jnp.stack([inf[:, 0], ~inf[:, 0],
                         jnp.zeros_like(inf[:, 0])], 1)
    dm_pick = jnp.stack([ctrl3, ~ctrl3, jnp.ones_like(ctrl3)], 1)
    consume = jnp.ones_like(inf)
    consume = jnp.where(is_nd[:, None], nd_pick, consume)
    consume = jnp.where(is_dm[:, None], dm_pick, consume)
    consume &= ready[:, None]
    br_pick = jnp.stack([ctrl2, ~ctrl2], 1)
    produce = jnp.ones_like(oute)
    produce = jnp.where(is_br[:, None], br_pick, produce)
    produce &= ready[:, None]
    return ready, z, consume, produce


_CTRL_OPS = (int(Op.NDMERGE), int(Op.DMERGE), int(Op.BRANCH))


def _ready_and_z_spec(class_slices, in_idx, out_idx, full, val):
    """Opcode-class-specialized firing rule (scalar int32 fabric).
    Control-free fabrics keep uniform ready/consume/produce masks as
    whole-array ops; only the ALU result is bucketed."""
    from repro.core.engine import _alu_op
    inf = full[in_idx] > 0                    # [N,3]
    oute = full[out_idx] == 0                 # [N,2]
    a = val[in_idx[:, 0]]
    b = val[in_idx[:, 1]]
    all_in = inf.all(axis=1)
    all_out = oute.all(axis=1)
    base = all_in & all_out
    if not any(op in _CTRL_OPS for op, _, _ in class_slices):
        z_p = [_alu_op(Op(op), a[lo:hi], b[lo:hi], jnp.int32)
               for op, lo, hi in class_slices]
        z = z_p[0] if len(z_p) == 1 else jnp.concatenate(z_p)
        return (base, z, base[:, None] & jnp.ones_like(inf),
                base[:, None] & jnp.ones_like(oute))
    r_p, z_p, c_p, p_p = [], [], [], []
    for opi, lo, hi in class_slices:
        op = Op(opi)
        ak, bk = a[lo:hi], b[lo:hi]
        infk, outek = inf[lo:hi], oute[lo:hi]
        if op == Op.NDMERGE:
            rk = (infk[:, 0] | infk[:, 1]) & all_out[lo:hi]
            zk = jnp.where(infk[:, 0], ak, bk)
            ck = rk[:, None] & jnp.stack(
                [infk[:, 0], ~infk[:, 0], jnp.zeros_like(infk[:, 0])], 1)
            pk = rk[:, None] & jnp.ones_like(outek)
        elif op == Op.DMERGE:
            c3 = val[in_idx[lo:hi, 2]] != 0
            rk = (infk[:, 2] & jnp.where(c3, infk[:, 0], infk[:, 1])
                  & all_out[lo:hi])
            zk = jnp.where(c3, ak, bk)
            ck = rk[:, None] & jnp.stack([c3, ~c3, jnp.ones_like(c3)], 1)
            pk = rk[:, None] & jnp.ones_like(outek)
        elif op == Op.BRANCH:
            c2 = bk != 0
            rk = (infk[:, 0] & infk[:, 1]
                  & jnp.where(c2, outek[:, 0], outek[:, 1]))
            zk = ak
            ck = rk[:, None] & jnp.ones_like(infk)
            pk = rk[:, None] & jnp.stack([c2, ~c2], 1)
        else:
            rk = base[lo:hi]
            zk = _alu_op(op, ak, bk, jnp.int32)
            ck = rk[:, None] & jnp.ones_like(infk)
            pk = rk[:, None] & jnp.ones_like(outek)
        r_p.append(rk)
        z_p.append(zk)
        c_p.append(ck)
        p_p.append(pk)
    return (jnp.concatenate(r_p), jnp.concatenate(z_p),
            jnp.concatenate(c_p), jnp.concatenate(p_p))


def _fire_parts(opcode, in_idx, out_idx, prod_node, prod_slot, cons_node,
                cons_slot, const_mask, full, val, class_slices=None):
    """Fire step returning the per-node ``ready`` vector (the profiled
    paths need it; :func:`_fire_body` reduces it to a sum)."""
    ready, z, consume, produce = _ready_and_z(opcode, in_idx, out_idx,
                                              full, val, class_slices)
    # arc-side gather (single producer / single consumer per channel)
    produced = produce[prod_node, prod_slot]
    consumed = consume[cons_node, cons_slot]
    new_full = ((full > 0) & ~consumed) | produced
    new_full = new_full | (const_mask > 0)
    new_val = jnp.where(produced, z[prod_node], val)
    return new_full.astype(full.dtype), new_val, ready


def _fire_body(opcode, in_idx, out_idx, prod_node, prod_slot, cons_node,
               cons_slot, const_mask, full, val, class_slices=None):
    new_full, new_val, ready = _fire_parts(
        opcode, in_idx, out_idx, prod_node, prod_slot, cons_node,
        cons_slot, const_mask, full, val, class_slices)
    return new_full, new_val, ready.astype(jnp.int32).sum()


def _kernel(opcode_ref, in_idx_ref, out_idx_ref, prod_node_ref,
            prod_slot_ref, cons_node_ref, cons_slot_ref, const_ref,
            full_ref, val_ref, nfull_ref, nval_ref, fired_ref):
    nf, nv, fired = _fire_body(
        opcode_ref[...], in_idx_ref[...], out_idx_ref[...],
        prod_node_ref[...], prod_slot_ref[...], cons_node_ref[...],
        cons_slot_ref[...], const_ref[...], full_ref[...], val_ref[...])
    nfull_ref[...] = nf
    nval_ref[...] = nv
    fired_ref[0] = fired


def plan_arrays(graph, optimize: bool = False):
    """Static numpy tables incl. arc adjacency (dummy node N = never
    ready; dummy slots pad).  With ``optimize=True`` the node table is
    opcode-bucketed (see ``_plan``) and ``class_slices`` records the
    static per-class ranges — the dummy node rides as a trailing
    one-row SINK bucket so the specialized rule covers all N+1 rows."""
    import numpy as np
    from repro.core.engine import _plan
    p = _plan(graph, optimize=optimize)
    A2 = p["A"] + 2
    N = len(graph.nodes)
    opcode = np.concatenate([p["opcode"], [int(Op.SINK)]]).astype(np.int32)
    in_idx = np.concatenate(
        [p["in_idx"], [[p["EMPTY_PAD"]] * 3]]).astype(np.int32)
    out_idx = np.concatenate(
        [p["out_idx"], [[p["EMPTY_PAD"]] * 2]]).astype(np.int32)
    prod_node = np.full((A2,), N, np.int32)
    prod_slot = np.zeros((A2,), np.int32)
    cons_node = np.full((A2,), N, np.int32)
    cons_slot = np.zeros((A2,), np.int32)
    node_row = p["node_inv"]    # original node index -> plan row
    for i, n in enumerate(graph.nodes):
        for s, arc in enumerate(n.outputs):
            prod_node[p["aidx"][arc]] = node_row[i]
            prod_slot[p["aidx"][arc]] = s
        for s, arc in enumerate(n.inputs):
            if arc not in graph.consts:      # consts are never consumed
                cons_node[p["aidx"][arc]] = node_row[i]
                cons_slot[p["aidx"][arc]] = s
    const_mask = p["const_mask"].astype(np.int32)
    class_slices = None
    if p["class_slices"] is not None:
        class_slices = (*p["class_slices"], (int(Op.SINK), N, N + 1))
    return dict(opcode=opcode, in_idx=in_idx, out_idx=out_idx,
                prod_node=prod_node, prod_slot=prod_slot,
                cons_node=cons_node, cons_slot=cons_slot,
                const_mask=const_mask, plan=p, class_slices=class_slices)


def fire_step_pallas(tables, full, val, interpret=None):
    """One engine cycle via pallas_call. full/val: int32[A+2]."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    A2 = full.shape[0]
    N2 = tables["opcode"].shape[0]
    out = pl.pallas_call(
        _kernel,
        in_specs=[pl.BlockSpec(x.shape, lambda n=x.ndim: (0,) * n)
                  for x in (tables["opcode"], tables["in_idx"],
                            tables["out_idx"], tables["prod_node"],
                            tables["prod_slot"], tables["cons_node"],
                            tables["cons_slot"], tables["const_mask"])]
        + [pl.BlockSpec((A2,), lambda: (0,)),
           pl.BlockSpec((A2,), lambda: (0,))],
        out_specs=[pl.BlockSpec((A2,), lambda: (0,)),
                   pl.BlockSpec((A2,), lambda: (0,)),
                   pl.BlockSpec((1,), lambda: (0,))],
        out_shape=[jax.ShapeDtypeStruct((A2,), jnp.int32),
                   jax.ShapeDtypeStruct((A2,), jnp.int32),
                   jax.ShapeDtypeStruct((1,), jnp.int32)],
        interpret=interpret,
    )(tables["opcode"], tables["in_idx"], tables["out_idx"],
      tables["prod_node"], tables["prod_slot"], tables["cons_node"],
      tables["cons_slot"], tables["const_mask"], full, val)
    return out


# ---------------------------------------------------------------------------
# Block-fused execution: K cycles + environment per pallas_call
# ---------------------------------------------------------------------------
_TABLE_KEYS = ("opcode", "in_idx", "out_idx", "prod_node", "prod_slot",
               "cons_node", "cons_slot", "const_mask", "env_row",
               "in_arc_idx", "out_arc_idx", "out_mask")


def block_plan_arrays(graph, optimize: bool = False):
    """plan_arrays + environment maps for in-kernel feed/drain.

    env_row[A2]     row into the feed table for input arcs, n_in (a pad
                    row with feed_len 0) otherwise — makes the input
                    strobe a pure gather.
    in_arc_idx[n_in]  arc slot of each feed row (EMPTY_PAD pad rows).
    out_arc_idx[n_out] arc slot of each output accumulator row.
    out_mask[A2]    1 on output arcs (drained unconditionally each cycle).
    n_in/n_out are padded to at least 1 so the kernel never sees a
    zero-length axis.
    """
    import numpy as np
    t = plan_arrays(graph, optimize=optimize)
    p = t["plan"]
    A2 = p["A"] + 2
    n_in = max(len(p["input_arcs"]), 1)
    n_out = max(len(p["output_arcs"]), 1)
    env_row = np.full((A2,), n_in, np.int32)
    in_arc_idx = np.full((n_in,), p["EMPTY_PAD"], np.int32)
    for r, a in enumerate(p["input_arcs"]):
        env_row[p["aidx"][a]] = r
        in_arc_idx[r] = p["aidx"][a]
    out_arc_idx = np.full((n_out,), p["EMPTY_PAD"], np.int32)
    out_mask = np.zeros((A2,), np.int32)
    for r, a in enumerate(p["output_arcs"]):
        out_arc_idx[r] = p["aidx"][a]
        out_mask[p["aidx"][a]] = 1
    t.update(env_row=env_row, in_arc_idx=in_arc_idx,
             out_arc_idx=out_arc_idx, out_mask=out_mask)
    return t


def _env_cycle(tab, feed_vals, feed_len, carry, class_slices=None,
               profile=False):
    """One full engine cycle (feed -> fire -> drain), gather-only.

    tab: dict of the _TABLE_KEYS arrays.  carry: (full, val, ptr,
    out_last, out_count, fired, last_prog, cyc) — with ``profile=True``
    five counter arrays (nf, si, so, ab, ahw; DESIGN.md §12) ride at
    the end of the carry and accumulate in-kernel.  Ordering matches
    `repro.core.engine.run_reference` exactly: inputs strobe first, the
    fire rule sees the post-feed registers, outputs drain post-fire;
    the occupancy sample point is post-fire, pre-drain.
    class_slices selects the opcode-specialized fire rule.
    """
    (full, val, ptr, out_last, out_count, fired, last_prog, cyc,
     *prof) = carry
    L = feed_vals.shape[1]
    # 1. strobe environment input buses (pad row: feed_len 0, never fires)
    can_feed = (full[tab["in_arc_idx"]] == 0) & (ptr < feed_len)
    nxt = jnp.take_along_axis(
        feed_vals, jnp.clip(ptr, 0, L - 1)[:, None], axis=1)[:, 0]
    can_p = jnp.concatenate([can_feed, jnp.zeros((1,), bool)])
    nxt_p = jnp.concatenate([nxt, jnp.zeros((1,), nxt.dtype)])
    fed_arc = can_p[tab["env_row"]]
    val = jnp.where(fed_arc, nxt_p[tab["env_row"]], val)
    full = jnp.where(fed_arc, 1, full)
    ptr = ptr + can_feed.astype(ptr.dtype)
    # 2. fire every ready node
    if profile:
        from repro.core.engine import _node_inputs_ready
        ir = _node_inputs_ready(tab["opcode"], tab["in_idx"], full, val)
    full, val, ready = _fire_parts(
        tab["opcode"], tab["in_idx"], tab["out_idx"], tab["prod_node"],
        tab["prod_slot"], tab["cons_node"], tab["cons_slot"],
        tab["const_mask"], full, val, class_slices)
    n_fired = ready.astype(jnp.int32).sum()
    if profile:
        nf, si, so, ab, ahw = prof
        occ = (full > 0).astype(jnp.int32)
        prof = (nf + ready, si + ~ir, so + (ir & ~ready),
                ab + occ, jnp.maximum(ahw, occ))
    # 3. environment drains output buses
    got = full[tab["out_arc_idx"]] > 0
    out_last = jnp.where(got, val[tab["out_arc_idx"]], out_last)
    out_count = out_count + got.astype(out_count.dtype)
    full = jnp.where(tab["out_mask"] > 0, 0, full)
    progress = jnp.any(can_feed) | (n_fired > 0) | jnp.any(got)
    return (full, val, ptr, out_last, out_count, fired + n_fired,
            jnp.where(progress, cyc + 1, last_prog), cyc + 1, *prof)


def _block_body(tab, feed_vals, feed_len, full, val, ptr, out_last,
                out_count, n_cycles: int, class_slices=None, prof=None):
    """Run `n_cycles` engine cycles; pure jnp (shared by kernel + ref).

    Returns (full, val, ptr, out_last, out_count, fired, last_prog)
    where fired counts firings within this block and last_prog is the
    1-based relative index of the last cycle that made progress (0 if
    the whole block was idle).  last_prog < n_cycles implies the fabric
    is quiescent — idle is absorbing.  ``prof`` (optional tuple of the
    5 §12 counter arrays) rides the carry and is returned after
    last_prog — counters accumulate across blocks because the caller
    passes the previous block's counters back in."""
    profile = prof is not None
    carry = (full, val, ptr, out_last, out_count,
             jnp.int32(0), jnp.int32(0), jnp.int32(0),
             *(prof if profile else ()))
    carry = jax.lax.fori_loop(
        0, n_cycles,
        lambda i, c: _env_cycle(tab, feed_vals, feed_len, c, class_slices,
                                profile=profile),
        carry)
    return carry[:7] + tuple(carry[8:])


def _block_kernel(n_cycles, class_slices, *refs):
    """pallas kernel: 12 table refs, feed_vals, feed_len, 5 state refs in;
    5 state refs + fired + last_prog out."""
    ins, outs = refs[:19], refs[19:]
    tab = {k: r[...] for k, r in zip(_TABLE_KEYS, ins[:12])}
    feed_vals, feed_len = ins[12][...], ins[13][...]
    state = [r[...] for r in ins[14:19]]
    res = _block_body(tab, feed_vals, feed_len, *state, n_cycles=n_cycles,
                      class_slices=class_slices)
    for r, v in zip(outs[:5], res[:5]):
        r[...] = v
    outs[5][0] = res[5]
    outs[6][0] = res[6]


def _batched_block_kernel(n_cycles, class_slices, *refs):
    """Same as _block_kernel but every non-table ref has a leading
    batch-block dim of 1 (grid over B selects the stream), plus a
    per-stream ``active`` flag: an inactive slot's block is skipped
    entirely (state passes through, fired/last_prog report 0) — the
    per-slot clock that lets a continuous-batching server freeze
    quiesced/empty slots instead of burning K cycles on them."""
    ins, outs = refs[:20], refs[20:]
    tab = {k: r[...] for k, r in zip(_TABLE_KEYS, ins[:12])}
    feed_vals, feed_len = ins[12][0], ins[13][0]
    state = [r[0] for r in ins[14:19]]
    active = ins[19][0] != 0
    res = jax.lax.cond(
        active,
        lambda: _block_body(tab, feed_vals, feed_len, *state,
                            n_cycles=n_cycles, class_slices=class_slices),
        lambda: (*state, jnp.int32(0), jnp.int32(0)))
    for r, v in zip(outs[:5], res[:5]):
        r[...] = v[None]
    outs[5][0, 0] = res[5]
    outs[6][0, 0] = res[6]


def _block_kernel_prof(n_cycles, class_slices, *refs):
    """Profiled :func:`_block_kernel`: 5 extra in-refs carry the §12
    counter arrays in and 5 extra out-refs carry them out, accumulated
    across the K in-kernel cycles — profiling adds zero extra
    dispatches, only wider block I/O."""
    ins, outs = refs[:24], refs[24:]
    tab = {k: r[...] for k, r in zip(_TABLE_KEYS, ins[:12])}
    feed_vals, feed_len = ins[12][...], ins[13][...]
    state = [r[...] for r in ins[14:19]]
    prof = tuple(r[...] for r in ins[19:24])
    res = _block_body(tab, feed_vals, feed_len, *state, n_cycles=n_cycles,
                      class_slices=class_slices, prof=prof)
    for r, v in zip(outs[:5], res[:5]):
        r[...] = v
    outs[5][0] = res[5]
    outs[6][0] = res[6]
    for r, v in zip(outs[7:12], res[7:12]):
        r[...] = v


def _batched_block_kernel_prof(n_cycles, class_slices, *refs):
    """Profiled :func:`_batched_block_kernel` — an inactive slot's
    counters pass through untouched (a parked slot accrues no stalls)."""
    ins, outs = refs[:25], refs[25:]
    tab = {k: r[...] for k, r in zip(_TABLE_KEYS, ins[:12])}
    feed_vals, feed_len = ins[12][0], ins[13][0]
    state = [r[0] for r in ins[14:19]]
    active = ins[19][0] != 0
    prof = tuple(r[0] for r in ins[20:25])
    res = jax.lax.cond(
        active,
        lambda: _block_body(tab, feed_vals, feed_len, *state,
                            n_cycles=n_cycles, class_slices=class_slices,
                            prof=prof),
        lambda: (*state, jnp.int32(0), jnp.int32(0), *prof))
    for r, v in zip(outs[:5], res[:5]):
        r[...] = v[None]
    outs[5][0, 0] = res[5]
    outs[6][0, 0] = res[6]
    for r, v in zip(outs[7:12], res[7:12]):
        r[...] = v[None]


def _whole(x):
    """BlockSpec covering the whole (broadcast) array, any grid arity."""
    nd = x.ndim
    return pl.BlockSpec(x.shape, lambda *_, n=nd: (0,) * n)


def fire_block_pallas(tables, feed_vals, feed_len, full, val, ptr,
                      out_last, out_count, *, n_cycles: int,
                      prof=None, interpret=None):
    """K fused engine cycles (environment included) via one pallas_call.

    tables: block_plan_arrays() output (jnp or numpy arrays).
    feed_vals[n_in, L] int32, feed_len[n_in] int32.
    State: full/val[A2], ptr[n_in], out_last/out_count[n_out], int32.
    Returns (full', val', ptr', out_last', out_count', fired[1],
    last_prog[1]).  prof: optional 5-tuple of §12 counter arrays
    (nf/si/so[N2], ab/ahw[A2] int32) — accumulated in-kernel and
    returned after last_prog."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    tabs = [jnp.asarray(tables[k]) for k in _TABLE_KEYS]
    state = [full, val, ptr, out_last, out_count]
    out_sd = ([jax.ShapeDtypeStruct(x.shape, jnp.int32) for x in state]
              + [jax.ShapeDtypeStruct((1,), jnp.int32)] * 2)
    if prof is None:
        return pl.pallas_call(
            functools.partial(_block_kernel, n_cycles,
                              tables.get("class_slices")),
            in_specs=[_whole(x)
                      for x in (*tabs, feed_vals, feed_len, *state)],
            out_specs=[_whole(s) for s in out_sd],
            out_shape=out_sd,
            interpret=interpret,
        )(*tabs, feed_vals, feed_len, *state)
    prof = list(prof)
    out_sd = out_sd + [jax.ShapeDtypeStruct(x.shape, jnp.int32)
                       for x in prof]
    return pl.pallas_call(
        functools.partial(_block_kernel_prof, n_cycles,
                          tables.get("class_slices")),
        in_specs=[_whole(x)
                  for x in (*tabs, feed_vals, feed_len, *state, *prof)],
        out_specs=[_whole(s) for s in out_sd],
        out_shape=out_sd,
        interpret=interpret,
    )(*tabs, feed_vals, feed_len, *state, *prof)


def fire_block_batched_pallas(tables, feed_vals, feed_len, full, val, ptr,
                              out_last, out_count, *, n_cycles: int,
                              active=None, prof=None, interpret=None):
    """Batched block step: grid=(B,) — B independent streams through one
    fabric in a single dispatch.  All state/feed arrays carry a leading
    batch axis; the node/arc tables are shared (broadcast) across the
    grid.  ``active`` (int32[B], default all-ones) is the per-stream
    clock gate: slots with active==0 skip the whole block (state frozen,
    fired/last_prog = 0), so a serving layer can park quiesced slots
    without a global barrier.  Returns the same tuple as
    fire_block_pallas with a leading B axis (fired/last_prog: [B, 1]).
    prof: optional 5-tuple of per-stream §12 counter arrays
    ([B, N2] / [B, A2] int32), accumulated in-kernel per active stream
    and returned after last_prog."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    B = full.shape[0]
    if active is None:
        active = jnp.ones((B,), jnp.int32)
    tabs = [jnp.asarray(tables[k]) for k in _TABLE_KEYS]
    state = [full, val, ptr, out_last, out_count]

    def row(x):
        nd = x.ndim
        return pl.BlockSpec((1, *x.shape[1:]),
                            lambda b, n=nd: (b,) + (0,) * (n - 1))

    out_sd = ([jax.ShapeDtypeStruct(x.shape, jnp.int32) for x in state]
              + [jax.ShapeDtypeStruct((B, 1), jnp.int32)] * 2)
    if prof is None:
        return pl.pallas_call(
            functools.partial(_batched_block_kernel, n_cycles,
                              tables.get("class_slices")),
            grid=(B,),
            in_specs=[_whole(x) for x in tabs]
            + [row(x) for x in (feed_vals, feed_len, *state)]
            + [pl.BlockSpec((1,), lambda b: (b,))],
            out_specs=[row(s) for s in out_sd],
            out_shape=out_sd,
            interpret=interpret,
        )(*tabs, feed_vals, feed_len, *state, active)
    prof = list(prof)
    out_sd = out_sd + [jax.ShapeDtypeStruct(x.shape, jnp.int32)
                       for x in prof]
    return pl.pallas_call(
        functools.partial(_batched_block_kernel_prof, n_cycles,
                          tables.get("class_slices")),
        grid=(B,),
        in_specs=[_whole(x) for x in tabs]
        + [row(x) for x in (feed_vals, feed_len, *state)]
        + [pl.BlockSpec((1,), lambda b: (b,))]
        + [row(x) for x in prof],
        out_specs=[row(s) for s in out_sd],
        out_shape=out_sd,
        interpret=interpret,
    )(*tabs, feed_vals, feed_len, *state, active, *prof)
