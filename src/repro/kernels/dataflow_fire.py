"""Pallas kernel for one static-dataflow engine cycle ("fire step").

The paper's FPGA executes all ready operators concurrently; on TPU the
cycle is one vectorized pass.  The kernel is *gather-only* (TPU-friendly,
no scatters): node-side arrays compute readiness and results, then each
arc pulls its next state from its (unique) producer/consumer — legal
precisely BECAUSE of the paper's one-sender/one-receiver channel rule.

Inputs (all VMEM-resident; fabrics are small — one FPGA's worth):
  full[A2] int32, val[A2] int32       arc registers (+2 dummy slots)
  opcode[N2], in_idx[N2,3], out_idx[N2,2]   node table (+1 dummy node)
  prod_node/prod_slot[A2], cons_node/cons_slot[A2]  arc adjacency
  const_mask[A2]
Outputs: new full/val, fired count.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.graph import Op


def _ready_and_z(opcode, in_idx, out_idx, full, val):
    """Vectorized firing rule (shared by kernel and ref)."""
    inf = full[in_idx] > 0                    # [N,3]
    oute = full[out_idx] == 0                 # [N,2]
    a = val[in_idx[:, 0]]
    b = val[in_idx[:, 1]]
    c = val[in_idx[:, 2]]
    all_in = inf.all(axis=1)
    all_out = oute.all(axis=1)

    is_nd = opcode == int(Op.NDMERGE)
    is_dm = opcode == int(Op.DMERGE)
    is_br = opcode == int(Op.BRANCH)
    ctrl3 = c != 0
    ctrl2 = b != 0

    dm_chosen = jnp.where(ctrl3, inf[:, 0], inf[:, 1])
    ready = all_in & all_out
    ready = jnp.where(is_nd, (inf[:, 0] | inf[:, 1]) & all_out, ready)
    ready = jnp.where(is_dm, inf[:, 2] & dm_chosen & all_out, ready)
    ready = jnp.where(is_br, inf[:, 0] & inf[:, 1] &
                      jnp.where(ctrl2, oute[:, 0], oute[:, 1]), ready)

    bs = jnp.clip(b, 0, 31)
    safe_b = jnp.where(b == 0, 1, b)
    zs = {
        Op.ADD: a + b, Op.SUB: a - b, Op.MUL: a * b,
        Op.DIV: jnp.where(b == 0, 0, a // safe_b),
        Op.AND: a & b, Op.OR: a | b, Op.XOR: a ^ b,
        Op.MAX: jnp.maximum(a, b), Op.MIN: jnp.minimum(a, b),
        Op.SHL: a << bs, Op.SHR: a >> bs,
        Op.NOT: (a == 0).astype(a.dtype),
        Op.IFGT: (a > b).astype(a.dtype), Op.IFGE: (a >= b).astype(a.dtype),
        Op.IFLT: (a < b).astype(a.dtype), Op.IFLE: (a <= b).astype(a.dtype),
        Op.IFEQ: (a == b).astype(a.dtype),
        Op.IFDF: (a != b).astype(a.dtype),
        Op.NDMERGE: jnp.where(inf[:, 0], a, b),
        Op.DMERGE: jnp.where(ctrl3, a, b),
    }
    z = a
    for op, r in zs.items():
        z = jnp.where(opcode == int(op), r, z)

    # per-slot consume/produce masks
    nd_pick = jnp.stack([inf[:, 0], ~inf[:, 0],
                         jnp.zeros_like(inf[:, 0])], 1)
    dm_pick = jnp.stack([ctrl3, ~ctrl3, jnp.ones_like(ctrl3)], 1)
    consume = jnp.ones_like(inf)
    consume = jnp.where(is_nd[:, None], nd_pick, consume)
    consume = jnp.where(is_dm[:, None], dm_pick, consume)
    consume &= ready[:, None]
    br_pick = jnp.stack([ctrl2, ~ctrl2], 1)
    produce = jnp.ones_like(oute)
    produce = jnp.where(is_br[:, None], br_pick, produce)
    produce &= ready[:, None]
    return ready, z, consume, produce


def _fire_body(opcode, in_idx, out_idx, prod_node, prod_slot, cons_node,
               cons_slot, const_mask, full, val):
    ready, z, consume, produce = _ready_and_z(opcode, in_idx, out_idx,
                                              full, val)
    # arc-side gather (single producer / single consumer per channel)
    produced = produce[prod_node, prod_slot]
    consumed = consume[cons_node, cons_slot]
    new_full = ((full > 0) & ~consumed) | produced
    new_full = new_full | (const_mask > 0)
    new_val = jnp.where(produced, z[prod_node], val)
    return (new_full.astype(full.dtype), new_val,
            ready.astype(jnp.int32).sum())


def _kernel(opcode_ref, in_idx_ref, out_idx_ref, prod_node_ref,
            prod_slot_ref, cons_node_ref, cons_slot_ref, const_ref,
            full_ref, val_ref, nfull_ref, nval_ref, fired_ref):
    nf, nv, fired = _fire_body(
        opcode_ref[...], in_idx_ref[...], out_idx_ref[...],
        prod_node_ref[...], prod_slot_ref[...], cons_node_ref[...],
        cons_slot_ref[...], const_ref[...], full_ref[...], val_ref[...])
    nfull_ref[...] = nf
    nval_ref[...] = nv
    fired_ref[0] = fired


def plan_arrays(graph):
    """Static numpy tables incl. arc adjacency (dummy node N = never
    ready; dummy slots pad)."""
    import numpy as np
    from repro.core.engine import _plan
    p = _plan(graph)
    A2 = p["A"] + 2
    N = len(graph.nodes)
    N2 = N + 1                                  # dummy node
    opcode = np.concatenate([p["opcode"], [int(Op.SINK)]]).astype(np.int32)
    in_idx = np.concatenate(
        [p["in_idx"], [[p["EMPTY_PAD"]] * 3]]).astype(np.int32)
    out_idx = np.concatenate(
        [p["out_idx"], [[p["EMPTY_PAD"]] * 2]]).astype(np.int32)
    prod_node = np.full((A2,), N, np.int32)
    prod_slot = np.zeros((A2,), np.int32)
    cons_node = np.full((A2,), N, np.int32)
    cons_slot = np.zeros((A2,), np.int32)
    for i, n in enumerate(graph.nodes):
        for s, arc in enumerate(n.outputs):
            prod_node[p["aidx"][arc]] = i
            prod_slot[p["aidx"][arc]] = s
        for s, arc in enumerate(n.inputs):
            if arc not in graph.consts:      # consts are never consumed
                cons_node[p["aidx"][arc]] = i
                cons_slot[p["aidx"][arc]] = s
    const_mask = p["const_mask"].astype(np.int32)
    return dict(opcode=opcode, in_idx=in_idx, out_idx=out_idx,
                prod_node=prod_node, prod_slot=prod_slot,
                cons_node=cons_node, cons_slot=cons_slot,
                const_mask=const_mask, plan=p)


def fire_step_pallas(tables, full, val, interpret=None):
    """One engine cycle via pallas_call. full/val: int32[A+2]."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    A2 = full.shape[0]
    N2 = tables["opcode"].shape[0]
    out = pl.pallas_call(
        _kernel,
        in_specs=[pl.BlockSpec(x.shape, lambda n=x.ndim: (0,) * n)
                  for x in (tables["opcode"], tables["in_idx"],
                            tables["out_idx"], tables["prod_node"],
                            tables["prod_slot"], tables["cons_node"],
                            tables["cons_slot"], tables["const_mask"])]
        + [pl.BlockSpec((A2,), lambda: (0,)),
           pl.BlockSpec((A2,), lambda: (0,))],
        out_specs=[pl.BlockSpec((A2,), lambda: (0,)),
                   pl.BlockSpec((A2,), lambda: (0,)),
                   pl.BlockSpec((1,), lambda: (0,))],
        out_shape=[jax.ShapeDtypeStruct((A2,), jnp.int32),
                   jax.ShapeDtypeStruct((A2,), jnp.int32),
                   jax.ShapeDtypeStruct((1,), jnp.int32)],
        interpret=interpret,
    )(tables["opcode"], tables["in_idx"], tables["out_idx"],
      tables["prod_node"], tables["prod_slot"], tables["cons_node"],
      tables["cons_slot"], tables["const_mask"], full, val)
    return out
