"""Pallas fused RMSNorm (memory-bound hot spot: 2x per layer).

Grid over row blocks; each step loads a [rows_blk, d] tile into VMEM,
computes the f32 mean-square on-chip and writes the normalized+scaled
tile — one HBM read + one write per element (vs separate
square/mean/rsqrt/mul kernels)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_pallas(x, w, eps: float = 1e-5, rows_blk: int = 256,
                   interpret=None):
    """x: [..., d]; w: [d]."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    orig_shape = x.shape
    d = x.shape[-1]
    xr = x.reshape(-1, d)
    R = xr.shape[0]
    rows_blk = min(rows_blk, R)
    pad = (-R) % rows_blk
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=((R + pad) // rows_blk,),
        in_specs=[pl.BlockSpec((rows_blk, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((rows_blk, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(((R + pad), d), x.dtype),
        interpret=interpret,
    )(xr, w)
    return out[:R].reshape(orig_shape)
