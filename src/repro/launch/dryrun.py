import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent (shardings
propagate, collectives legal, no shape errors) and extracts the roofline
terms from the compiled artifact:

    compute    = HLO_FLOPs   / (chips * 197e12)       [bf16 peak / chip]
    memory     = HLO_bytes   / (chips * 819e9)        [HBM BW / chip]
    collective = coll_bytes  / (chips * 50e9)         [ICI link BW]

Because ``cost_analysis()`` counts while-loop (scan) bodies once, the
terms come from ``hlo_analysis.analyze`` — a trip-count-aware walk of the
post-SPMD HLO (validated against unrolled compiles in tests).  All HLO
shapes are per-chip, so per-chip terms divide by one chip's peak;
all-reduce is counted 2x (reduce-scatter + all-gather wire phases).

Usage:
    python -m repro.launch.dryrun --arch internlm2-1.8b --shape train_4k \
        --mesh pod --out experiments/dryrun
    python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import dataclasses
import json
import re
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ARCHS, SHAPES, get_arch
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as tfm
from repro.optim import adamw
from repro.parallel import sharding as shd

PEAK_FLOPS = 197e12     # bf16 / chip (v5e)
HBM_BW = 819e9          # bytes/s / chip
LINK_BW = 50e9          # bytes/s / link (ICI)

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
                "c128": 16, "token": 0}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]\S*))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.I)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0, "count": 0}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2).lower()
        b = _shape_bytes(shape_str)
        out[op] += b
        out["count"] += 1
    # effective wire bytes: all-reduce moves ~2x its payload
    out["wire_bytes"] = (2 * out["all-reduce"] + out["all-gather"]
                         + out["reduce-scatter"] + out["all-to-all"]
                         + out["collective-permute"])
    return out


# ---------------------------------------------------------------------------
# abstract inputs per (arch, shape)
# ---------------------------------------------------------------------------
def input_specs(cfg, shape) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "decode":
        batch = {"tokens": sds((B, 1), jnp.int32)}
    else:
        batch = {"tokens": sds((B, S), jnp.int32)}
        if shape.kind == "train":
            batch["labels"] = sds((B, S), jnp.int32)
        if cfg.frontend == "patches":
            batch["patches"] = sds((B, cfg.n_patches, cfg.frontend_dim),
                                   jnp.float32)
        if cfg.frontend == "frames":
            batch["frames"] = sds((B, cfg.enc_seq, cfg.frontend_dim),
                                  jnp.float32)
    return batch


def abstract_params(cfg):
    return jax.eval_shape(lambda k: tfm.init_params(cfg, k),
                          jax.random.key(0))


def abstract_cache(cfg, B, S):
    c = jax.eval_shape(lambda: tfm.init_cache(cfg, B, S))
    if cfg.enc_dec:   # cross kv set at prefill: [L,B,enc_seq,Hkv,hd] x2
        sds = jax.ShapeDtypeStruct
        cdt = jnp.dtype(cfg.compute_dtype)
        kv = sds((cfg.n_layers, B, cfg.enc_seq, cfg.n_kv_heads,
                  cfg.head_dim), cdt)
        c = {"self": c["self"], "cross": (kv, kv)}
    return c


def model_flops(cfg, shape) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); D = tokens this step."""
    n = cfg.param_count(active_only=True) if cfg.n_experts else \
        cfg.param_count()
    toks = shape.global_batch * (1 if shape.kind == "decode"
                                 else shape.seq_len)
    mult = 6 if shape.kind == "train" else 2
    return float(mult) * n * toks


# ---------------------------------------------------------------------------
# per-cell lowering
# ---------------------------------------------------------------------------
def build_cell(cfg, shape, mesh, policy=None, master_weights=False):
    """Returns (jitted_fn, example_args (structs)) for this cell."""
    policy = policy or shd.ShardPolicy()
    p_struct = abstract_params(cfg)
    p_spec = shd.param_specs(p_struct, mesh, policy)
    p_shard = shd.shardings_of(p_spec, mesh)
    batch = input_specs(cfg, shape)
    b_shard = shd.shardings_of(
        shd.batch_specs(cfg, mesh, batch, shape.global_batch), mesh)
    repl = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

    if shape.kind == "train":
        opt_cfg = adamw.OptConfig()
        o_struct = jax.eval_shape(
            lambda p: adamw.init(p, master_weights=master_weights),
            p_struct)
        o_shard = adamw.OptState(
            step=repl,
            m=jax.tree.map(lambda s: s, p_shard),
            v=jax.tree.map(lambda s: s, p_shard),
            master=jax.tree.map(lambda s: s, p_shard)
            if master_weights else None)

        def step_fn(params, opt_state, batch):
            def loss_of(p):
                return tfm.loss_fn(cfg, p, batch)
            (loss, aux), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params)
            new_p, new_o, om = adamw.update(opt_cfg, grads, opt_state,
                                            params)
            return new_p, new_o, {"loss": loss, **om}

        fn = jax.jit(step_fn,
                     in_shardings=(p_shard, o_shard, b_shard),
                     out_shardings=(p_shard, o_shard, repl),
                     donate_argnums=(0, 1))
        return fn, (p_struct, o_struct, batch)

    if shape.kind == "prefill":
        def pf(params, batch):
            return tfm.prefill(cfg, params, batch, max_len=shape.seq_len)
        c_struct = jax.eval_shape(pf, p_struct, batch)[1]
        c_shard = shd.shardings_of(
            shd.cache_specs(cfg, mesh, c_struct, shape.global_batch), mesh)
        fn = jax.jit(pf, in_shardings=(p_shard, b_shard),
                     out_shardings=(repl, c_shard))
        return fn, (p_struct, batch)

    # decode
    c_struct = abstract_cache(cfg, shape.global_batch, shape.seq_len)
    # mark the cache as "full" (length S-1) conceptually; length is a
    # traced scalar so the struct is what matters
    c_shard = shd.shardings_of(
        shd.cache_specs(cfg, mesh, c_struct, shape.global_batch), mesh)

    def dec(params, tokens, cache):
        return tfm.decode_step(cfg, params, tokens, cache)

    fn = jax.jit(dec,
                 in_shardings=(p_shard, b_shard["tokens"], c_shard),
                 out_shardings=(repl, c_shard),
                 donate_argnums=(2,))
    return fn, (p_struct, batch["tokens"], c_struct)


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             out_dir: str | None = None, policy=None,
             tag: str = "baseline", overrides: dict | None = None,
             master_weights: bool = False) -> dict:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    skip = cfg.skipped_shapes().get(shape_name)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "tag": tag}
    if overrides:
        rec["overrides"] = {k: repr(v) for k, v in overrides.items()}
    if master_weights:
        rec["master_weights"] = True
    if skip:
        rec["status"] = f"skipped: {skip}"
        _emit(rec, out_dir)
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    chips = mesh.devices.size
    axes = tuple(mesh.axis_names)
    cfg = dataclasses.replace(cfg, mesh_axes=axes, **(overrides or {}))
    t0 = time.time()
    with mesh:
        fn, args = build_cell(cfg, shape, mesh, policy,
                              master_weights=master_weights)
        lowered = fn.lower(*args)
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 1)
        try:
            ma = compiled.memory_analysis()
            rec["memory"] = {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "peak_bytes": int(ma.temp_size_in_bytes
                                  + ma.argument_size_in_bytes),
            }
        except Exception as e:        # backend may not implement it
            rec["memory"] = {"error": str(e)}
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        hlo = compiled.as_text()
        hc = hlo_analysis.analyze(hlo)
        flops = hc["flops"]
        bytes_acc = hc["traffic_bytes"]
        rec["cost"] = {"flops_per_chip": flops,
                       "bytes_per_chip": bytes_acc,
                       "collective_bytes_per_chip": hc["collective_bytes"],
                       "collective_ops": hc["collective_ops"],
                       "collective_detail": hc["collective_detail"],
                       "xla_cost_flops_bodies_once":
                           float(ca.get("flops", 0.0))}
        compute_t = flops / PEAK_FLOPS
        memory_t = bytes_acc / HBM_BW
        coll_t = hc["collective_bytes"] / LINK_BW
        mf = model_flops(cfg, shape)
        rec["roofline"] = {
            "compute_s": compute_t, "memory_s": memory_t,
            "collective_s": coll_t,
            "dominant": max((("compute", compute_t), ("memory", memory_t),
                             ("collective", coll_t)),
                            key=lambda kv: kv[1])[0],
            "model_flops_global": mf,
            "useful_flops_ratio": mf / (flops * chips) if flops else 0.0,
            "chips": chips,
        }
        rec["status"] = "ok"
    _emit(rec, out_dir)
    return rec


def _emit(rec, out_dir):
    line = (f"[{rec['arch']} x {rec['shape']} x {rec['mesh']}] "
            f"{rec['status']}")
    if rec.get("roofline"):
        r = rec["roofline"]
        line += (f" compute={r['compute_s']:.3e}s "
                 f"memory={r['memory_s']:.3e}s "
                 f"coll={r['collective_s']:.3e}s -> {r['dominant']}"
                 f" useful={r['useful_flops_ratio']:.2f}")
    print(line, flush=True)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        name = (f"{rec['arch']}__{rec['shape']}__{rec['mesh']}"
                f"__{rec['tag']}.json")
        with open(os.path.join(out_dir, name), "w") as f:
            json.dump(rec, f, indent=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None)
    ap.add_argument("--shape", action="append", default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="pod")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="config override field=value (python literal)")
    ap.add_argument("--master-weights", action="store_true")
    args = ap.parse_args(argv)
    import ast
    overrides = {}
    for kv in args.set:
        k, _, v = kv.partition("=")
        try:
            overrides[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            overrides[k] = v
    archs = args.arch or (sorted(ARCHS) if args.all else
                          ["internlm2-1.8b"])
    shapes = args.shape or list(SHAPES)
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    failures = []
    for a in archs:
        for s in shapes:
            for m in meshes:
                try:
                    run_cell(a, s, m, args.out, tag=args.tag,
                             overrides=overrides,
                             master_weights=args.master_weights)
                except Exception as e:
                    failures.append((a, s, m, repr(e)))
                    print(f"[{a} x {s} x {m}] FAILED: {e!r}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
