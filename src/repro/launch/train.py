"""Training launcher: ``python -m repro.launch.train --arch <id>``.

On real hardware this runs the full config on the production mesh; on a
CPU host pass ``--reduced`` (default there) to smoke-train the same
architecture at reduced width.  Mesh axes come from the runtime device
count; the checkpoint/restart path is identical in both modes.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs.base import ARCHS, get_arch
from repro.data.pipeline import SyntheticLM
from repro.optim import adamw
from repro.train import loop as train_loop


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--reduced", action="store_true", default=None,
                    help="reduced-width config (default on CPU)")
    ap.add_argument("--full", dest="reduced", action="store_false")
    args = ap.parse_args(argv)

    reduced = args.reduced
    if reduced is None:
        reduced = jax.default_backend() == "cpu"
    cfg = get_arch(args.arch)
    if reduced:
        cfg = cfg.reduced()
    src = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch, seed=0,
                      frontend=cfg.frontend, n_patches=cfg.n_patches,
                      frontend_dim=cfg.frontend_dim, enc_seq=cfg.enc_seq)
    opt = adamw.OptConfig(lr=args.lr, warmup_steps=min(20, args.steps),
                          total_steps=args.steps)
    lp = train_loop.LoopConfig(total_steps=args.steps,
                               ckpt_every=args.ckpt_every,
                               ckpt_dir=args.ckpt_dir, log_every=10)
    out = train_loop.run(cfg, lp, opt, src, key=jax.random.key(0))
    print(f"done: arch={args.arch} reduced={reduced} "
          f"resumed={out['resumed']} final_loss={out['losses'][-1]:.4f}")


if __name__ == "__main__":
    main()
