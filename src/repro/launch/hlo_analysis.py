"""Trip-count-aware analysis of compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts while-loop bodies ONCE, which
undercounts scan-over-layers programs by ~n_layers.  XLA annotates every
scan-derived while with ``backend_config={"known_trip_count":{"n":...}}``,
so this module walks the computation graph from ENTRY, multiplying
while-body costs by their trip counts (nested loops multiply), and
reports:

  * flops            — 2*M*N*K for every dot (incl. dots inside fusions)
  * traffic_bytes    — fusion-boundary operand+output bytes (an HBM
                       traffic proxy: fusion internals never materialize)
  * collective_bytes — per collective op kind, wire-byte weighted
                       (all-reduce counted 2x), trip-count multiplied

Shapes are per-chip (post-partitioning), so all numbers are per-chip.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
                "pred": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"([a-z]\w*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^()]*\))|(?:[a-z]\w*\["
    r"[0-9,]*\](?:\{[^}]*\})?))\s*([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^{]*)?\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_elems_bytes(shape_str: str):
    total_b = 0
    total_e = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total_e += n
        total_b += n * _DTYPE_BYTES[dt]
    return total_e, total_b


def _dims(shape_str: str):
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str     # everything after the opening paren


def parse_computations(text: str) -> dict[str, list[Instr]]:
    comps: dict[str, list[Instr]] = {}
    cur: list[Instr] | None = None
    cur_name = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_RE.match(line.strip())
            if m and ("->" in line or line.strip().startswith("ENTRY")):
                cur_name, cur = m.group(1), []
                comps[cur_name] = cur
                if line.strip().startswith("ENTRY"):
                    comps["__entry__"] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            cur.append(Instr(m.group(1), m.group(2), m.group(3),
                             m.group(4)))
    return comps


_SKIP_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple",
                 "bitcast", "while", "conditional", "call", "after-all",
                 "partition-id", "replica-id", "iota"}


def _sub_computations(instr: Instr):
    """Computation names referenced via calls=/body=/condition=/branches."""
    out = []
    for key in ("calls=", "body=", "condition=", "to_apply="):
        for m in re.finditer(key + r"%?([\w.\-]+)", instr.rest):
            out.append((key[:-1], m.group(1)))
    m = re.search(r"branch_computations=\{([^}]*)\}", instr.rest)
    if m:
        for name in m.group(1).split(","):
            out.append(("branch", name.strip().lstrip("%")))
    return out


class HloCost:
    """v2: dynamic-slice / dynamic-update-slice (and fusions rooted in
    them) are buffer-aliased by XLA — traffic counts only the touched
    slice, not the whole carried scan stack (which inflated loop bodies
    by the trip count in v1)."""

    def __init__(self, text: str, detail: bool = False):
        self.comps = parse_computations(text)
        self.symbols: dict[str, dict[str, str]] = {}
        self.roots: dict[str, str] = {}
        for name, instrs in self.comps.items():
            tab = {}
            for ins in instrs:
                tab[ins.name] = ins.type_str
            self.symbols[name] = tab
            if instrs:
                self.roots[name] = instrs[-1].opcode
        self.flops = 0.0
        self.traffic = 0.0
        self.coll = defaultdict(float)
        self.coll_count = 0
        self.detail = defaultdict(float) if detail else None
        self._walk("__entry__", 1.0, count_traffic=True)

    # ------------------------------------------------------------------
    def _dot_flops(self, comp: str, ins: Instr) -> float:
        out_e, _ = _shape_elems_bytes(ins.type_str)
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rest)
        ops = _OPERAND_RE.findall(ins.rest.split("),")[0] + ")")
        if not m or not ops:
            return 2.0 * out_e
        lhs_type = self.symbols[comp].get(ops[0], "")
        lhs_dims = _dims(lhs_type)
        k = 1
        for ci in m.group(1).split(","):
            if ci and int(ci) < len(lhs_dims):
                k *= lhs_dims[int(ci)]
        return 2.0 * out_e * k

    def _walk(self, comp_name: str, mult: float, count_traffic: bool,
              flops_only: bool = False):
        instrs = self.comps.get(comp_name)
        if instrs is None:
            return
        for ins in instrs:
            op = ins.opcode
            base = op.replace("-start", "")
            if base in ("dot", "convolution"):
                self.flops += mult * self._dot_flops(comp_name, ins)
            if not flops_only and base in COLLECTIVES:
                _, b = _shape_elems_bytes(ins.type_str)
                if op.endswith("-start") and base == "all-gather":
                    # output of all-gather-start is (in, out) tuple; take
                    # the larger half as the payload
                    b = b  # tuple counted; acceptable upper bound
                w = 2.0 * b if base == "all-reduce" else b
                self.coll[base] += mult * w
                self.coll_count += 1
            if op == "while":
                m = _TRIP_RE.search(ins.rest)
                trip = float(m.group(1)) if m else 1.0
                for _, sub in _sub_computations(ins):
                    self._walk(sub, mult * trip, count_traffic,
                               flops_only)
            elif op == "conditional":
                for _, sub in _sub_computations(ins):
                    self._walk(sub, mult, count_traffic, flops_only)
            elif op == "fusion":
                # flops inside fusion bodies still execute; traffic does
                # not (values stay in registers/VMEM)
                for kind, sub in _sub_computations(ins):
                    if kind == "calls":
                        self._walk(sub, mult, count_traffic=False,
                                   flops_only=True)
            elif op == "call":
                for kind, sub in _sub_computations(ins):
                    if kind == "to_apply" or kind == "calls":
                        self._walk(sub, mult, count_traffic, flops_only)
            if count_traffic and not flops_only and \
                    op not in _SKIP_TRAFFIC and not op.endswith("-done"):
                _, out_b = _shape_elems_bytes(ins.type_str)
                in_b, max_in = 0, 0
                arg_str = ins.rest.split("),")[0]
                for o in _OPERAND_RE.findall(arg_str):
                    t = self.symbols[comp_name].get(o)
                    if t:
                        _, ob = _shape_elems_bytes(t)
                        in_b += ob
                        max_in = max(max_in, ob)
                update_b = None
                if op == "dynamic-update-slice":
                    ops_ = _OPERAND_RE.findall(arg_str)
                    if len(ops_) > 1:
                        t = self.symbols[comp_name].get(ops_[1])
                        if t:
                            update_b = _shape_elems_bytes(t)[1]
                elif op == "fusion":
                    for kind, sub in _sub_computations(ins):
                        if kind != "calls":
                            continue
                        if self.roots.get(sub) == "dynamic-update-slice":
                            root = self.comps[sub][-1]
                            rops = _OPERAND_RE.findall(
                                root.rest.split("),")[0] + ")")
                            if len(rops) > 1:
                                t = self.symbols[sub].get(rops[1])
                                if t:
                                    update_b = _shape_elems_bytes(t)[1]
                            if update_b is None:
                                update_b = max(out_b // 8, 1)
                        elif self.roots.get(sub) == "dynamic-slice":
                            update_b = out_b
                if op == "dynamic-slice":
                    tb = 2 * out_b       # read region + write out
                elif update_b is not None:
                    # buffer-aliased in-place update: touch ~3 slices
                    # (read src slice, write dest slice, index plumbing)
                    tb = 3 * update_b
                else:
                    tb = out_b + in_b
                self.traffic += mult * tb
                if self.detail is not None and tb * mult > 0:
                    m2 = re.search(r'op_name="([^"]*)"', ins.rest)
                    key = (op, (m2.group(1)[:100] if m2 else "?"),
                           ins.type_str[:44])
                    self.detail[key] += mult * tb

    # ------------------------------------------------------------------
    def summary(self) -> dict:
        wire = (2 * 0 + sum(self.coll.values()))  # already weighted
        return {
            "flops": self.flops,
            "traffic_bytes": self.traffic,
            "collective_bytes": sum(self.coll.values()),
            "collective_detail": dict(self.coll),
            "collective_ops": self.coll_count,
        }


def analyze(text: str) -> dict:
    return HloCost(text).summary()
