"""Serving launcher: ``python -m repro.launch.serve --arch <id>``."""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.base import ARCHS, get_arch
from repro.models import transformer as tfm
from repro.serve.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--reduced", action="store_true", default=None)
    ap.add_argument("--full", dest="reduced", action="store_false")
    args = ap.parse_args(argv)

    reduced = args.reduced
    if reduced is None:
        reduced = jax.default_backend() == "cpu"
    cfg = get_arch(args.arch)
    if reduced:
        cfg = cfg.reduced()
    params = tfm.init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, batch_size=args.batch_size,
                      max_len=args.max_len)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(
                        0, cfg.vocab,
                        (int(rng.integers(4, 32)),)).astype(np.int32),
                    max_new_tokens=args.max_new_tokens)
            for i in range(args.requests)]
    results = eng.run(reqs)
    total = sum(len(r.tokens) for r in results)
    print(f"arch={args.arch} reduced={reduced}: served {len(reqs)} "
          f"requests, {total} tokens")


if __name__ == "__main__":
    main()
