"""``trace(fn, *avals) -> TracedProgram``: capture a scalar jax
program as a jaxpr and synthesize its static dataflow fabric.

A :class:`TracedProgram` IS a :class:`~repro.core.graph.Graph` — it
runs on every engine backend, serializes through ``asm.emit`` (so the
:mod:`repro.serve.dataflow_server` compiled-plan cache treats a traced
program as just another fabric signature), and optimizes through
``core.passes`` — plus the frontend bookkeeping: which environment arc
carries which positional argument (``arg_arcs``), which arcs drain the
program's results (``out_arcs``), and the feed adapter
(:meth:`TracedProgram.make_feeds`) that turns positional token streams
into the engine's arc->stream dict.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core.graph import Graph
from repro.front.adapter import pack_arg_streams
from repro.front.lowering import _Ctx, LoweringError, lower_jaxpr


@dataclasses.dataclass
class TracedProgram(Graph):
    """A fabric synthesized from a traced Python program.

    arg_arcs: one entry per *stream* argument (positional arguments
      minus any const-bound via ``trace(const_args=...)``) — the input
      arc fed by that argument's token stream, or None when the
      argument is unused (the adapter then ignores its stream).
    out_arcs: one output arc per program result, in return order.
    dtype:   the fabric's execution dtype (all avals share it).
    has_loops: the program lowered ``lax`` control flow onto the
      cyclic loop schema (DESIGN.md §10).  Loop fabrics initiate ONCE
      per run — the entry NDMERGEs consume exactly one initial token —
      so ``make_feeds`` enforces one token per argument; evaluate a
      stream by running the program per element (the
      :class:`~repro.serve.dataflow_server.DataflowServer` does this
      as one request per evaluation).
    """
    arg_arcs: list = dataclasses.field(default_factory=list)
    out_arcs: list = dataclasses.field(default_factory=list)
    dtype: object = np.dtype(np.int32)
    has_loops: bool = False

    def make_feeds(self, *args) -> dict:
        """Feed adapter: positional [k]-token streams (scalars
        broadcast to the common k) -> arc->stream dict for the
        engines, ``run_batch``, and ``DataflowServer`` requests.
        Loop-bearing programs accept only single-token streams (see
        ``has_loops``)."""
        return pack_arg_streams(self.name, self.arg_arcs, self.dtype,
                                args, single_shot=self.has_loops)

    @property
    def out_arc(self) -> str:
        return self.out_arcs[0]


def _canon_aval(a, index: int):
    """Normalize one `avals` entry (dtype-like, ShapeDtypeStruct, or
    example scalar) to a canonical scalar dtype."""
    if isinstance(a, jax.ShapeDtypeStruct):
        if tuple(a.shape) != ():
            raise LoweringError(
                f"aval {index} has shape {tuple(a.shape)}; the fabric "
                "carries scalar (token-shaped) values — stream tensors "
                "element-wise instead")
        dt = a.dtype
    elif isinstance(a, (str, np.dtype)) or (isinstance(a, type)
                                            and issubclass(a, np.generic)):
        dt = np.dtype(a)
    elif isinstance(a, (bool, int)):
        dt = np.dtype(np.int32)
    elif isinstance(a, float):
        dt = np.dtype(np.float32)
    elif np.ndim(a) == 0:
        dt = np.asarray(a).dtype
    else:
        raise LoweringError(
            f"aval {index} ({a!r}) is neither a scalar dtype spec nor "
            "a scalar example value")
    dt = np.dtype(jax.dtypes.canonicalize_dtype(dt))
    if dt == np.bool_ or np.issubdtype(dt, np.complexfloating):
        raise LoweringError(
            f"aval {index} has dtype {dt}; fabric tokens are integer "
            "or float words (deciders encode booleans as 0/1)")
    return dt


def trace(fn, *avals, name: str | None = None,
          const_args: dict | None = None) -> TracedProgram:
    """Lower a jax-traceable scalar program onto fabric operators.

    avals: one scalar dtype spec (or example value) per positional
    argument of ``fn``; all must share one dtype — the fabric's
    execution dtype.  Raises :class:`LoweringError` (naming the
    offending primitive) when the program uses an equation the Veen
    operator set cannot express.

    const_args: {arg index: value} binds those arguments as *sticky
    const buses* (the paper's persistently-presented input buses, e.g.
    FIR coefficients) instead of token streams.  Operators fed only by
    const buses are genuine compile-time work — exactly what the PR 3
    constant-folding pass collapses.  Const-bound arguments take no
    stream: ``make_feeds`` expects one stream per *remaining*
    argument, in position order.
    """
    if not avals:
        raise LoweringError(
            "trace() needs at least one aval: a fabric with no input "
            "streams would free-run its constant outputs")
    const_args = dict(const_args or {})
    bad = [i for i in const_args if not 0 <= i < len(avals)]
    if bad:
        raise LoweringError(
            f"const_args indices {sorted(bad)} out of range for "
            f"{len(avals)} traced arguments")
    if len(const_args) == len(avals):
        raise LoweringError(
            "every argument is const-bound: a fabric with no input "
            "streams would free-run its constant outputs")
    dts = [_canon_aval(a, i) for i, a in enumerate(avals)]
    if len(set(dts)) != 1:
        raise LoweringError(
            f"mixed aval dtypes {sorted({str(d) for d in dts})}: every "
            "arc of one fabric carries one dtype")
    dtype = dts[0]
    name = name or getattr(fn, "__name__", None) or "traced"
    if name == "<lambda>":
        name = "traced"
    closed = jax.make_jaxpr(fn)(
        *[jax.ShapeDtypeStruct((), dt) for dt in dts])
    for v in closed.jaxpr.outvars:
        aval = getattr(v, "aval", None)
        if aval is not None and tuple(aval.shape) != ():
            raise LoweringError(
                f"program returns shape {tuple(aval.shape)}; fabric "
                "output buses drain scalar tokens")

    prog = TracedProgram(name=name, dtype=dtype)
    ctx = _Ctx(prog, dtype)
    ctx.const_args = const_args
    results = lower_jaxpr(ctx, closed.jaxpr, closed.consts, None)
    prog.arg_arcs = list(ctx.created_inputs)
    prog.has_loops = ctx.has_loops

    out_arcs = []
    for k, (arc, streamy) in enumerate(results):
        if not streamy:
            raise LoweringError(
                f"program output {k} is a compile-time constant; a "
                "const output bus free-runs (one token per cycle, "
                "forever) — return something derived from an argument")
        if arc in ctx.env_inputs:
            # a bare passthrough would leave the arc both fed and
            # drained by the environment; give it a real operator so
            # the arc classes stay disjoint
            from repro.core.graph import Op
            out, dead = ctx.fresh("out"), ctx.fresh("dead")
            prog.add(Op.COPY, [arc], [out, dead])
            prog.add(Op.SINK, [dead], [])
            arc = out
        out_arcs.append(arc)
    prog.out_arcs = out_arcs
    # a const arc no node reads (e.g. an unused const-bound argument)
    # would surface as a free-running environment output bus — prune
    used = {a for n in prog.nodes for a in (*n.inputs, *n.outputs)}
    prog.consts = {a: v for a, v in prog.consts.items() if a in used}
    prog.inits = {a: v for a, v in prog.inits.items() if a in used}
    prog.validate()
    return prog
