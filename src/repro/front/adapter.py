"""Feed adapter: positional argument streams -> arc->stream dicts.

The engines and the server speak ``{arc: [k]-stream}``
(:func:`repro.core.engine.pack_feeds`); a traced program's callers
speak positional arguments.  This module is the bridge — one stream
per traced argument, scalars broadcast to the common stream length,
unused arguments (``arg_arcs`` entry None) accepted and dropped so the
traced function's call signature keeps working verbatim.
"""
from __future__ import annotations

import numpy as np


def pack_arg_streams(name: str, arg_arcs, dtype, args,
                     single_shot: bool = False) -> dict:
    if len(args) != len(arg_arcs):
        raise ValueError(
            f"{name}: expected {len(arg_arcs)} argument streams "
            f"(one per traced argument), got {len(args)}")
    dtype = np.dtype(dtype)
    streams: list[tuple[str, np.ndarray]] = []
    k = None
    for i, (arc, v) in enumerate(zip(arg_arcs, args)):
        if arc is None:
            continue                      # argument unused by the program
        v = np.asarray(v, dtype)
        if v.ndim > 1:
            raise ValueError(
                f"{name}: argument {i} has shape {v.shape}; pass a "
                "[k] token stream (or a scalar) per argument")
        if v.ndim == 1:
            if k is None:
                k = v.shape[0]
            elif v.shape[0] != k:
                raise ValueError(
                    f"{name}: argument {i} has {v.shape[0]} tokens but "
                    f"earlier streams have {k} — every argument feeds "
                    "one token per program firing")
        streams.append((arc, v))
    k = 1 if k is None else k
    if single_shot and k > 1:
        raise ValueError(
            f"{name}: loop-bearing fabrics initiate once per run (the "
            "entry NDMERGEs consume exactly one initial token), so "
            f"every argument feeds ONE token — got a {k}-token stream. "
            "Run the program once per stream element, e.g. one "
            "DataflowServer request per evaluation.")
    return {arc: (np.full((k,), v, dtype) if v.ndim == 0 else v)
            for arc, v in streams}
