"""Expression-to-fabric frontend (DESIGN.md §9).

The paper's toolchain starts from an *algorithm* and synthesizes the
static dataflow graph of operators that computes it; this package is
that synthesis step for ordinary jax-traceable Python: ``trace(fn,
*avals)`` captures the program as a jaxpr and lowers every equation
onto the Veen operator set of :mod:`repro.core.graph`, so any scalar
(token-shaped) expression becomes a fabric the cycle-accurate engines,
the compiled backends, and the continuous-batching server can run.

    from repro.front import trace
    prog = trace(lambda x, y: jnp.where(x > y, x - y, y - x),
                 np.int32, np.int32)
    eng = DataflowEngine(prog, backend="pallas", block_cycles=16)
    res = eng.run(prog.make_feeds([5, 1], [2, 9]))
    res.outputs[prog.out_arcs[0]]      # -> [3, 8]

Unsupported jaxpr primitives raise :class:`LoweringError` naming the
primitive; see :data:`repro.front.lowering.SUPPORTED` for the table.
"""
from repro.front.lowering import SUPPORTED, LoweringError
from repro.front.tracer import TracedProgram, trace

__all__ = ["trace", "TracedProgram", "LoweringError", "SUPPORTED"]
