"""jaxpr-equation -> fabric-operator lowering rules (DESIGN.md §9).

One rule per jaxpr primitive.  The arithmetic/logic/relational
primitives map 1:1 onto :class:`~repro.core.graph.Op`; everything else
is a *schema* over several operators:

* fan-out — an arc carries one receiver, so a value consumed by k
  equations becomes a COPY tree (``library._fanout``);
* ``select_n`` (``jnp.where`` / ``lax.select``) — the classical
  dataflow conditional: each data operand rides a BRANCH steered by the
  predicate (the untaken side is SINKed) and a DMERGE reunites the
  taken tokens, so *both* operands are consumed every firing and the
  fabric streams without stale tokens;
* ``neg`` / ``abs`` / ``integer_pow`` / ``clamp`` — expanded into
  SUB/MUL/MAX/MIN trees that are bit-exact at the execution dtype
  (``neg`` is ``0 - x`` for ints, ``x * -1`` for floats, so ``-0.0``
  and INT_MIN behave exactly like jax);
* constants — jaxpr literals and closure consts become sticky const
  buses (always-full environment arcs), which is what lets the PR 3
  constant-folding pass collapse constant subexpressions at compile
  time;
* ``pjit`` / ``custom_jvp_call`` etc. — inlined recursively;
* ``while`` / carry-only ``scan`` (``lax.while_loop``, ``fori_loop``,
  carry-only ``lax.scan``) — the paper's cyclic loop schema
  (DESIGN.md §10): an NDMERGE entry per carry whose initial value
  arrives as a one-shot token (an initial-token annotation for
  compile-time values, the carry's supply arc otherwise), a predicate
  cone over per-iteration carry taps, and a BRANCH per carry steering
  the token onto the back-edge (predicate true) or the exit arc
  (false).  Loop-invariant values that are sticky const buses ride
  straight into the cones; streamy invariants become synthetic
  pass-through carries.  The resulting fabric is cyclic, so it runs on
  token-presence executors only, and it initiates ONCE per program
  run — ``TracedProgram.make_feeds`` enforces one token per argument.

Anything else raises :class:`LoweringError` naming the primitive.
"""
from __future__ import annotations

import itertools

import numpy as np

from repro.core.graph import Graph, Op
from repro.core.library import _fanout, _reduce_tree


class LoweringError(Exception):
    """A traced program contains an equation the fabric cannot run."""


# primitive name -> Op / schema note (the DESIGN.md §9 lowering table;
# also the vocabulary quoted by LoweringError messages)
SUPPORTED = {
    "add": "ADD", "sub": "SUB", "mul": "MUL",
    "div": "DIV (float dtypes only; the fabric ALU defines x/0 = 0)",
    "max": "MAX", "min": "MIN",
    "and": "AND", "or": "OR", "xor": "XOR", "not": "NOT",
    "shift_left": "SHL",
    "shift_right_arithmetic": "SHR (signed dtypes)",
    "shift_right_logical": "SHR (unsigned dtypes)",
    "gt": "IFGT", "ge": "IFGE", "lt": "IFLT", "le": "IFLE",
    "eq": "IFEQ", "ne": "IFDF",
    "select_n": "BRANCH x2 + SINK x2 + DMERGE (2-way, bool predicate)",
    "neg": "SUB(0, x) int / MUL(x, -1) float",
    "abs": "COPY + neg + MAX",
    "integer_pow": "MUL tree (int dtypes, y >= 0)",
    "clamp": "MAX + MIN",
    "convert_element_type": "alias (bool->dtype / same dtype) or "
                            "IFDF(x, 0) (dtype->bool)",
    "stop_gradient": "alias",
    "broadcast_in_dim": "alias (scalar)", "reshape": "alias (scalar)",
    "squeeze": "alias (scalar)",
    "pjit": "inlined", "closed_call": "inlined",
    "custom_jvp_call": "inlined", "custom_vjp_call": "inlined",
    "while": "cyclic loop schema: NDMERGE entry per carry + predicate "
             "cone + BRANCH back-edge/exit steering (scalar carries)",
    "scan": "carry-only (fori_loop with static bounds): counter carry "
            "+ IFLT trip decider + the while loop schema",
}

_BINOP = {
    "add": Op.ADD, "sub": Op.SUB, "mul": Op.MUL,
    "max": Op.MAX, "min": Op.MIN,
    "and": Op.AND, "or": Op.OR, "xor": Op.XOR,
    "shift_left": Op.SHL,
    "gt": Op.IFGT, "ge": Op.IFGE, "lt": Op.IFLT, "le": Op.IFLE,
    "eq": Op.IFEQ, "ne": Op.IFDF,
}
# `a op b == b op a` bit-exactly at any dtype (engine ALU formulas):
# used to put a const operand on the b side, where the identity-
# elimination pass looks for it.
_COMMUTATIVE = frozenset(
    ("add", "mul", "max", "min", "and", "or", "xor", "eq", "ne"))
_ALIAS = ("stop_gradient", "broadcast_in_dim", "reshape", "squeeze")
_CALL = ("pjit", "closed_call", "custom_jvp_call", "custom_vjp_call")


def _is_literal(atom) -> bool:
    return not hasattr(atom, "count")    # jax core Var has .count


class _Ctx:
    """Lowering state: per-var arc supplies, use counts, taint."""

    def __init__(self, graph: Graph, dtype):
        self.graph = graph
        self.dtype = np.dtype(dtype)
        self.supply: dict = {}     # Var -> list[str] queue | str const arc
        self.uses: dict = {}       # Var -> planned consumer count
        self.streamy: dict = {}    # Var -> depends on an env stream?
        self.env_inputs: set[str] = set()
        self.const_args: dict[int, object] = {}   # arg index -> value
        self.has_loops = False     # a while/scan lowered a cyclic region
        self.loop_depth = 0        # loop-body nesting during lowering
        self._n = itertools.count()
        self._lits: dict = {}

    def fresh(self, tag: str = "v") -> str:
        return f"{tag}{next(self._n)}"

    # -- constants ------------------------------------------------------
    def lit(self, value) -> str:
        """Const bus for a compile-time scalar (deduped by value bits —
        const arcs are sticky and may feed many receivers)."""
        v = np.asarray(value, self.dtype).reshape(()).item()
        key = repr(v)
        arc = self._lits.get(key)
        if arc is None:
            arc = self.fresh("lit")
            self.graph.const(arc, v)
            self._lits[key] = arc
        return arc

    # -- supplies -------------------------------------------------------
    def use(self, atom) -> str:
        """Claim one arc carrying the atom's value."""
        if _is_literal(atom):
            return self.lit(atom.val)
        s = self.supply[atom]
        return s if isinstance(s, str) else s.pop(0)

    def is_streamy(self, atom) -> bool:
        return (not _is_literal(atom)) and self.streamy.get(atom, False)

    def bind(self, var, arc: str, streamy: bool = True) -> None:
        """Register `arc` as var's value, fanning out through a COPY
        tree when the var has several consumers and SINKing it when it
        has none (a produced token must always find a receiver, or the
        arc would surface as a spurious environment output)."""
        u = self.uses.get(var, 0)
        if u == 0:
            self.graph.add(Op.SINK, [arc], [])
            self.supply[var] = []
        elif u == 1:
            self.supply[var] = [arc]
        else:
            self.supply[var] = _fanout(self.graph, arc, u, arc + "f")
        self.streamy[var] = streamy

    def bind_const(self, var, arc: str) -> None:
        self.supply[var] = arc      # sticky bus: unlimited receivers
        self.streamy[var] = False


def _err(eqn, why: str) -> LoweringError:
    return LoweringError(
        f"primitive '{eqn.primitive.name}' {why} "
        f"(fabric lowering table: {sorted(SUPPORTED)})")


def _aval_dtype(atom):
    return np.dtype(atom.aval.dtype) if not _is_literal(atom) \
        else np.dtype(np.asarray(atom.val).dtype)


def _convert_kind(ctx: _Ctx, eqn) -> str:
    """alias | ne0 — or raise for a conversion the fabric cannot carry
    (arcs hold one dtype; deciders already emit 0/1 at that dtype)."""
    src = _aval_dtype(eqn.invars[0])
    dst = np.dtype(eqn.params["new_dtype"])
    if src == dst or (src == np.bool_ and dst == ctx.dtype):
        return "alias"
    if dst == np.bool_ and src == ctx.dtype:
        return "ne0"
    raise _err(eqn, f"converts {src} -> {dst}, but every arc of this "
                    f"fabric carries {ctx.dtype} tokens")


def _pow_uses(eqn, uses) -> int:
    y = int(eqn.params["y"])
    if y == 1:
        return uses.get(eqn.outvars[0], 0)      # pure alias
    return max(y, 0)


def _multiplicities(ctx: _Ctx, eqn, uses) -> list[int]:
    """How many arcs of each operand the eqn's lowering will claim.
    ``uses`` holds the (already complete, thanks to reverse iteration)
    consumer counts of the eqn's outvars — alias lowerings forward
    their output's demand straight to their input."""
    name = eqn.primitive.name
    if name == "select_n":
        return [3] + [1] * (len(eqn.invars) - 1)
    if name == "abs":
        return [2]
    if name == "integer_pow":
        return [_pow_uses(eqn, uses)]
    if name in _ALIAS:
        return [uses.get(eqn.outvars[0], 0)]
    if name == "convert_element_type" and _convert_kind(ctx, eqn) == "alias":
        return [uses.get(eqn.outvars[0], 0)]
    return [1] * len(eqn.invars)


def _bind_alias(ctx: _Ctx, outvar, atom) -> None:
    """outvar shares atom's arcs (its demand was pre-charged to atom)."""
    if _is_literal(atom):
        ctx.bind_const(outvar, ctx.lit(atom.val))
        return
    s = ctx.supply[atom]
    if isinstance(s, str):
        ctx.bind_const(outvar, s)
    else:
        arcs = [ctx.use(atom) for _ in range(ctx.uses.get(outvar, 0))]
        ctx.supply[outvar] = arcs
        ctx.streamy[outvar] = ctx.is_streamy(atom)


# ---------------------------------------------------------------------------
# Loop lowering: lax control flow -> the paper's cyclic loop schema
# ---------------------------------------------------------------------------
def _check_scalar_loop(eqn) -> None:
    for v in (*eqn.invars, *eqn.outvars):
        aval = getattr(v, "aval", None)
        if aval is not None and tuple(aval.shape) != ():
            raise _err(eqn, f"carries a value of shape "
                            f"{tuple(aval.shape)}; fabric loops carry "
                            "scalar tokens")


def _one_shot_init(ctx: _Ctx, arc: str, streamy: bool, eqn) -> str:
    """Entry-NDMERGE initial-value input: must deliver exactly one
    token per loop INITIATION (a second arrival would re-initiate a
    live loop).  A top-level const-bus supply becomes a fresh
    init-annotated arc (the one-shot compile-time initial token of
    DESIGN.md §10); a streamy supply arc carries one token per
    initiation itself.  Nested const inits never reach here — the
    caller materializes them per initiation first.  A non-streamy
    non-const supply is produced by a free-running const-fed operator
    and is rejected."""
    g = ctx.graph
    if arc in g.consts:
        f = ctx.fresh("lz")
        g.init(f, np.asarray(g.consts[arc], ctx.dtype).reshape(()).item())
        return f
    if not streamy:
        raise _err(eqn, "has a loop initial value produced by a "
                        "free-running const-fed operator; hoist it to a "
                        "literal or derive it from an argument")
    return arc


def _loop_schema(ctx: _Ctx, eqn, *, init_sup, inv_entries, need_tap,
                 make_pred, make_backs) -> list[str]:
    """Build the paper's cyclic loop schema; returns the exit arcs.

    init_sup     ``[(arc, streamy)]`` initial-value supply per carry.
    inv_entries  ``[(bind, arc, streamy, where)]`` — loop-invariant
                 values that are NOT sticky const buses; each becomes a
                 *synthetic pass-through carry* (entry merge + tap +
                 BRANCH whose exit token is SINKed) and ``bind(tap)``
                 hands its per-iteration tap arc to the consuming cone.
                 ``where`` is the cone that consumes the tap: a
                 ``"cond"`` invariant is tapped BEFORE its BRANCH (the
                 predicate fires once more than the body — the final,
                 false evaluation still reads it), a ``"body"``
                 invariant AFTER (the tap must exist only on continuing
                 iterations, or a stale token per initiation would
                 poison re-initiating nested loops).
    need_tap[j]  carry j feeds the predicate cone (gets a COPY tap);
                 untapped carries wire straight into their BRANCH.
    make_pred(taps) -> (p_arc, p_streamy): lower the predicate cone
                 (``taps[j]`` is None when ``need_tap[j]`` is False).
    make_backs(live) -> ``[(arc, streamy)]``: lower the body cone from
                 the BRANCH-true arcs; one next-state arc per carry.

    Wiring per carry (DESIGN.md §10)::

            back ----v
        NDMERGE(back, init) -> carry -> COPY -> (tap, data)
            tap  -> predicate cone -> p (fanned out)
            data -> BRANCH(data, p) -> (live -> body -> back,  exit)

    The entry NDMERGE is race-free by construction: its init input
    delivers exactly one token per run and every later token arrives on
    the back edge, serialized by the cycle itself.
    """
    g = ctx.graph
    n = len(init_sup)
    s = len(inv_entries)
    # NESTED loops re-initiate once per enclosing iteration, and the
    # enclosing body's carries advance at skewed rates (a carry whose
    # cycle contains this loop iterates slower than one that does not),
    # so a fresh initiation token can arrive while the previous
    # initiation's back-edge token is still in flight — an NDMERGE
    # entry would race.  Nested loops therefore use the classical
    # DETERMINISTIC entry instead: a DMERGE steered by the loop
    # predicate carrying an initial-0 control token (sel=0 -> take the
    # init input, sel=p=1 -> take the back edge, and the exit firing's
    # p=0 becomes the NEXT initiation's sel) — re-initiation-safe by
    # construction, and const initial values ride their sticky buses
    # straight into the merge.  Top-level loops initiate exactly once
    # (make_feeds enforces the single-shot contract), so they keep the
    # paper's NDMERGE schema with one-shot initial tokens.
    nested = ctx.loop_depth > 0
    ctx.loop_depth += 1
    # entry-merge output arcs are allocated NOW; the NDMERGE nodes are
    # added LAST (their back-edge inputs only exist after the body cone
    # lowers) — node order in the table does not affect semantics
    carry = [ctx.fresh("lc") for _ in range(n)]
    inv = [ctx.fresh("li") for _ in range(s)]
    taps, data = [], []
    for j, a in enumerate(carry):
        if need_tap[j]:
            t, d = ctx.fresh(), ctx.fresh()
            g.add(Op.COPY, [a], [t, d])
        else:
            t, d = None, a
        taps.append(t)
        data.append(d)
    for (bind, _, _, where), a in zip(inv_entries, inv):
        if where == "cond":     # tap pre-BRANCH: T+1 per initiation
            t, d = ctx.fresh(), ctx.fresh()
            g.add(Op.COPY, [a], [t, d])
            bind(t)
            data.append(d)
        else:                   # tap post-BRANCH (below): T per init
            data.append(a)
    p_arc, p_streamy = make_pred(taps)
    if p_arc in g.consts or not p_streamy:
        raise _err(eqn, "has a loop predicate that does not depend on "
                        "the loop state — the trip count would be zero "
                        "or infinite at compile time")
    # the BRANCH nodes are added AFTER the body cone lowers — their
    # predicate-leg count depends on whether a predicate-derived gate
    # is needed (below), and the body only needs the live arc NAMES
    m = n + s
    live = [ctx.fresh("ll") for _ in range(n)]
    exits = [ctx.fresh("lx") for _ in range(n)]
    synth_live = [ctx.fresh("lv") for _ in range(s)]
    synth_backs = []
    for j, (bind, _, _, where) in enumerate(inv_entries):
        if where == "cond":
            synth_backs.append(synth_live[j])
        else:                           # body tap rides the live token
            t, back = ctx.fresh(), ctx.fresh()
            g.add(Op.COPY, [synth_live[j]], [t, back])
            bind(t)
            synth_backs.append(back)
    backs = list(make_backs(live))
    ctx.loop_depth -= 1
    # next-state fixup: a constant next value (body returns a literal /
    # const pass-through) has no per-iteration producer, and wiring the
    # always-full const bus into a top-level NDMERGE entry would
    # re-fire it every refill window.  Gate one token per CONTINUING
    # iteration instead: DMERGE with both data inputs riding the const
    # bus and the gate token as control produces exactly one
    # const-valued token per body firing.  The gate rides a streamy
    # back value when one exists, else an extra predicate token routed
    # by its own twin (BRANCH(p, p): the true output exists only on
    # continuing iterations) — a loop whose EVERY next state is
    # constant is still data-dependent through its zero-trip path.
    # The nested DMERGE entry consumes its chosen bus per firing, so
    # const backs ride their sticky buses directly there.
    const_j = [j for j, (a, _) in enumerate(backs) if a in g.consts]
    free_j = [j for j, (a, sy) in enumerate(backs)
              if a not in g.consts and not sy]
    if free_j:
        raise _err(eqn, "has a loop next-state value produced by a "
                        "free-running const-fed operator — its arc "
                        "would re-initiate the loop; hoist it to a "
                        "literal or derive it from the carry")
    need_gates = bool(const_j) and not nested
    gate_j = next((j for j, (a, sy) in enumerate(backs)
                   if a not in g.consts and sy), None) if need_gates \
        else None
    p_gate = need_gates and gate_j is None
    # nested entries consume the predicate too (as the DMERGE steering
    # stream): double the fan-out and pre-load each steering leg with
    # the initial-0 token that selects the first initiation's input
    ps = _fanout(g, p_arc, (2 * m if nested else m)
                 + (2 if p_gate else 0), p_arc + "f")
    sels = ps[m:2 * m] if nested else []
    for a in sels:
        g.init(a, 0)
    for j in range(n):
        g.add(Op.BRANCH, [data[j], ps[j]], [live[j], exits[j]])
    for j in range(s):
        ex = ctx.fresh()
        g.add(Op.BRANCH, [data[n + j], ps[n + j]], [synth_live[j], ex])
        g.add(Op.SINK, [ex], [])        # invariant's exit value is dead
    if need_gates:
        if p_gate:
            gl, gd = ctx.fresh("lgl"), ctx.fresh()
            g.add(Op.BRANCH, [ps[-2], ps[-1]], [gl, gd])
            g.add(Op.SINK, [gd], [])    # the final (false) evaluation
            gates = _fanout(g, gl, len(const_j), ctx.fresh("lg"))
        else:
            fan = _fanout(g, backs[gate_j][0], 1 + len(const_j),
                          ctx.fresh("lg"))
            backs[gate_j] = (fan[0], True)
            gates = fan[1:]
        for gate, j in zip(gates, const_j):
            out = ctx.fresh("lk")
            g.add(Op.DMERGE, [backs[j][0], backs[j][0], gate], [out])
            backs[j] = (out, True)
    # close the cycles: one entry merge per carry — the paper's NDMERGE
    # at top level, the predicate-steered deterministic DMERGE nested
    all_backs = [b for b, _ in backs] + synth_backs
    all_inits = list(init_sup) + [(a, sy) for _, a, sy, _ in inv_entries]
    all_carry = carry + inv
    for j in range(m):
        back, (ini_arc, ini_sy) = all_backs[j], all_inits[j]
        if nested:
            if ini_arc not in g.consts and not ini_sy:
                raise _err(eqn, "has a loop initial value produced by "
                                "a free-running const-fed operator; "
                                "hoist it to a literal or derive it "
                                "from an argument")
            g.add(Op.DMERGE, [back, ini_arc, sels[j]], [all_carry[j]])
        else:
            ini = _one_shot_init(ctx, ini_arc, ini_sy, eqn)
            g.add(Op.NDMERGE, [back, ini], [all_carry[j]])
    ctx.has_loops = True
    return exits


def _split_invariants(ctx: _Ctx, sup, out, where: str):
    """Partition loop-invariant supplies: sticky const buses ride into
    the cone directly (``out[k]`` set now); anything else registers a
    synthetic carry whose ``bind`` fills ``out[k]`` with the tap arc.
    ``where`` names the consuming cone ("cond" | "body") — it decides
    the tap cadence (see :func:`_loop_schema`)."""
    inv_entries = []
    for k, (arc, sy) in enumerate(sup):
        if arc in ctx.graph.consts:
            out[k] = (arc, False)
        else:
            def bind(t, k=k, out=out):
                out[k] = (t, True)
            inv_entries.append((bind, arc, sy, where))
    return inv_entries


def _lower_while(ctx: _Ctx, eqn) -> None:
    _check_scalar_loop(eqn)
    cond_cj = eqn.params["cond_jaxpr"]
    body_cj = eqn.params["body_jaxpr"]
    nc = eqn.params["cond_nconsts"]
    nb = eqn.params["body_nconsts"]
    n = len(eqn.invars) - nc - nb
    sup = [(ctx.use(v), ctx.is_streamy(v)) for v in eqn.invars]
    cond_in = [None] * nc
    body_in = [None] * nb
    inv_entries = (_split_invariants(ctx, sup[:nc], cond_in, "cond")
                   + _split_invariants(ctx, sup[nc:nc + nb], body_in,
                                       "body"))

    def make_pred(taps):
        res = lower_jaxpr(ctx, cond_cj.jaxpr, cond_cj.consts,
                          cond_in + [(t, True) for t in taps])
        return res[0]

    def make_backs(live):
        return lower_jaxpr(ctx, body_cj.jaxpr, body_cj.consts,
                           body_in + [(a, True) for a in live])

    exits = _loop_schema(ctx, eqn, init_sup=sup[nc + nb:],
                         inv_entries=inv_entries, need_tap=[True] * n,
                         make_pred=make_pred, make_backs=make_backs)
    for v, ex in zip(eqn.outvars, exits):
        ctx.bind(v, ex, streamy=True)


def _lower_scan(ctx: _Ctx, eqn) -> None:
    """Carry-only scan (what ``fori_loop`` with static bounds traces
    to): a synthetic counter carry and an ``IFLT(i, length)`` decider
    supply the predicate; the user carries ride the while schema with
    no predicate taps of their own.

    Note a fori-derived scan already carries the jax loop index, so
    such fabrics run two parallel counters (~5 extra nodes).  Reusing
    the existing one is a possible peephole, but it requires proving
    carry 0 is ``init==lo, +1 per step`` against arbitrary bounds —
    left as a simplification opportunity."""
    p = eqn.params
    num_consts, num_carry = p["num_consts"], p["num_carry"]
    n_xs = len(eqn.invars) - num_consts - num_carry
    n_ys = len(eqn.outvars) - num_carry
    if n_xs or n_ys:
        raise _err(eqn, f"scans over {n_xs} streamed input / {n_ys} "
                        "streamed output axes; only carry-only scans "
                        "(e.g. fori_loop with static bounds) ride the "
                        "loop schema")
    _check_scalar_loop(eqn)
    g = ctx.graph
    body_cj = p["jaxpr"]
    sup = [(ctx.use(v), ctx.is_streamy(v)) for v in eqn.invars]
    body_in = [None] * num_consts
    inv_entries = _split_invariants(ctx, sup[:num_consts], body_in,
                                    "body")
    len_bus = ctx.lit(int(p["length"]))
    one_bus = ctx.lit(1)

    def make_pred(taps):
        pa = ctx.fresh("lp")
        g.add(Op.IFLT, [taps[0], len_bus], [pa])
        return pa, True

    def make_backs(live):
        nxt = ctx.fresh("ln")
        g.add(Op.ADD, [live[0], one_bus], [nxt])
        res = lower_jaxpr(ctx, body_cj.jaxpr, body_cj.consts,
                          body_in + [(a, True) for a in live[1:]])
        return [(nxt, True)] + list(res)

    exits = _loop_schema(
        ctx, eqn, init_sup=[(ctx.lit(0), False)] + sup[num_consts:],
        inv_entries=inv_entries,
        need_tap=[True] + [False] * num_carry,
        make_pred=make_pred, make_backs=make_backs)
    g.add(Op.SINK, [exits[0]], [])      # final counter value is dead
    for v, ex in zip(eqn.outvars, exits[1:]):
        ctx.bind(v, ex, streamy=True)


def _lower_eqn(ctx: _Ctx, eqn) -> None:
    name = eqn.primitive.name
    g, dtype = ctx.graph, ctx.dtype
    is_int = np.issubdtype(dtype, np.integer)
    out = eqn.outvars[0] if eqn.outvars else None

    if name in _BINOP or name == "div" or name.startswith("shift_right"):
        if name == "div":
            if is_int:
                raise _err(eqn, "is round-toward-zero integer division "
                                "(jnp `//` also routes through `rem`); "
                                "the fabric DIV is float-only — use "
                                "shifts for powers of two")
            op = Op.DIV
        elif name == "shift_right_arithmetic":
            if not is_int or np.issubdtype(dtype, np.unsignedinteger):
                raise _err(eqn, "needs a signed integer dtype")
            op = Op.SHR
        elif name == "shift_right_logical":
            if not np.issubdtype(dtype, np.unsignedinteger):
                raise _err(eqn, "is a logical shift; the fabric SHR is "
                                "arithmetic for signed dtypes — use an "
                                "unsigned dtype")
            op = Op.SHR
        else:
            op = _BINOP[name]
        a, b = eqn.invars
        if (name in _COMMUTATIVE and not ctx.is_streamy(a)
                and ctx.is_streamy(b)):
            a, b = b, a          # const operand on the b side (passes
            #                      splice identities off inputs[1])
        streamy = ctx.is_streamy(a) or ctx.is_streamy(b)
        arc = ctx.fresh()
        g.add(op, [ctx.use(a), ctx.use(b)], [arc])
        ctx.bind(out, arc, streamy)
        return

    if name == "not":
        arc = ctx.fresh()
        g.add(Op.NOT, [ctx.use(eqn.invars[0])], [arc])
        ctx.bind(out, arc, ctx.is_streamy(eqn.invars[0]))
        return

    if name == "neg":
        x = eqn.invars[0]
        arc = ctx.fresh()
        if is_int:
            g.add(Op.SUB, [ctx.lit(0), ctx.use(x)], [arc])
        else:           # 0.0 - x flips -0.0; x * -1.0 is bit-exact
            g.add(Op.MUL, [ctx.use(x), ctx.lit(-1)], [arc])
        ctx.bind(out, arc, ctx.is_streamy(x))
        return

    if name == "abs":
        x = eqn.invars[0]
        x0, x1 = ctx.use(x), ctx.use(x)
        nn = ctx.fresh()
        if is_int:
            g.add(Op.SUB, [ctx.lit(0), x1], [nn])
        else:
            g.add(Op.MUL, [x1, ctx.lit(-1)], [nn])
        arc = ctx.fresh()
        g.add(Op.MAX, [x0, nn], [arc])    # MAX(+0,-0)=+0 matches |−0.0|
        ctx.bind(out, arc, ctx.is_streamy(x))
        return

    if name == "integer_pow":
        x = eqn.invars[0]
        y = int(eqn.params["y"])
        if y < 0:
            raise _err(eqn, f"has negative exponent y={y}")
        if y == 0:
            ctx.bind_const(out, ctx.lit(1))
            return
        if y == 1:
            _bind_alias(ctx, out, x)
            return
        if not is_int:
            raise _err(eqn, "expands to a MUL tree whose rounding "
                            "order is only bit-exact for integer "
                            "dtypes — spell out float powers as "
                            "explicit multiplies")
        arcs = [ctx.use(x) for _ in range(y)]
        arc = ctx.fresh()
        _reduce_tree(g, arcs, Op.MUL, arc + "p", final=arc)
        ctx.bind(out, arc, ctx.is_streamy(x))
        return

    if name == "clamp":
        lo, x, hi = eqn.invars    # lax.clamp(min, operand, max)
        t, arc = ctx.fresh(), ctx.fresh()
        g.add(Op.MAX, [ctx.use(x), ctx.use(lo)], [t])
        g.add(Op.MIN, [t, ctx.use(hi)], [arc])
        ctx.bind(out, arc, any(ctx.is_streamy(v) for v in eqn.invars))
        return

    if name == "select_n":
        pred = eqn.invars[0]
        if len(eqn.invars) != 3:
            raise _err(eqn, f"has {len(eqn.invars) - 1} cases; only "
                            "2-way (boolean) selects lower")
        if _aval_dtype(pred) != np.bool_:
            raise _err(eqn, "has a non-boolean selector")
        fv, tv = eqn.invars[1], eqn.invars[2]   # select_n: cases[pred]
        c_t, c_f, c_m = ctx.use(pred), ctx.use(pred), ctx.use(pred)
        t_live, t_dead = ctx.fresh(), ctx.fresh()
        f_live, f_dead = ctx.fresh(), ctx.fresh()
        g.add(Op.BRANCH, [ctx.use(tv), c_t], [t_live, t_dead])
        g.add(Op.SINK, [t_dead], [])
        g.add(Op.BRANCH, [ctx.use(fv), c_f], [f_dead, f_live])
        g.add(Op.SINK, [f_dead], [])
        arc = ctx.fresh()
        g.add(Op.DMERGE, [t_live, f_live, c_m], [arc])
        ctx.bind(out, arc, any(ctx.is_streamy(v) for v in eqn.invars))
        return

    if name == "convert_element_type":
        x = eqn.invars[0]
        if _convert_kind(ctx, eqn) == "alias":
            _bind_alias(ctx, out, x)
        else:                     # dtype -> bool: x != 0
            arc = ctx.fresh()
            g.add(Op.IFDF, [ctx.use(x), ctx.lit(0)], [arc])
            ctx.bind(out, arc, ctx.is_streamy(x))
        return

    if name in _ALIAS:
        aval = getattr(eqn.outvars[0], "aval", None)
        if aval is not None and tuple(aval.shape) != ():
            raise _err(eqn, f"produces shape {tuple(aval.shape)}; the "
                            "fabric carries scalar tokens")
        _bind_alias(ctx, out, eqn.invars[0])
        return

    if name == "while":
        _lower_while(ctx, eqn)
        return

    if name == "scan":
        _lower_scan(ctx, eqn)
        return

    if name in _CALL:
        inner = None
        for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
            cand = eqn.params.get(key)
            if cand is not None and hasattr(cand, "jaxpr"):
                inner = cand
                break
        if inner is None:
            raise _err(eqn, "has no inlinable sub-jaxpr")
        supplies = [(ctx.use(v), ctx.is_streamy(v)) for v in eqn.invars]
        results = lower_jaxpr(ctx, inner.jaxpr, inner.consts, supplies)
        for var, (arc, streamy) in zip(eqn.outvars, results):
            if arc in ctx.graph.consts:
                ctx.bind_const(var, arc)
            else:
                ctx.bind(var, arc, streamy)
        return

    raise _err(eqn, "has no fabric lowering")


def lower_jaxpr(ctx: _Ctx, jaxpr, const_vals, in_arcs
                ) -> list[tuple[str, bool]]:
    """Lower one jaxpr scope onto ctx.graph.

    in_arcs: one ``(arc, streamy)`` pair per invar — or None (top
    level) to create an environment input arc ``in{i}`` on demand,
    recording the created names (None for unused args) in
    ``ctx.created_inputs``.  Returns ``(arc, streamy)`` per outvar;
    unused invar arcs handed in by a caller are SINKed so every token
    still finds a receiver.
    """
    # 1. demand counting, in reverse so alias chains see their own
    #    consumers before charging their inputs
    uses: dict = {}

    def charge(atom, m):
        if not _is_literal(atom) and m:
            uses[atom] = uses.get(atom, 0) + m

    for v in jaxpr.outvars:
        charge(v, 1)
    for eqn in reversed(jaxpr.eqns):
        for atom, m in zip(eqn.invars, _multiplicities(ctx, eqn, uses)):
            charge(atom, m)
    ctx.uses.update(uses)

    # 2. bind closure consts and arguments
    for var, val in zip(jaxpr.constvars, const_vals):
        val = np.asarray(val)
        if val.shape != ():
            raise LoweringError(
                f"closure constant of shape {val.shape} cannot ride a "
                "scalar-token arc (fabric tokens are 0-d)")
        ctx.bind_const(var, ctx.lit(val))
    if in_arcs is None:                 # top level: environment streams
        created: list[str | None] = []
        for i, var in enumerate(jaxpr.invars):
            if i in ctx.const_args:     # sticky const bus, not a stream
                if ctx.uses.get(var, 0):
                    ctx.bind_const(var, ctx.lit(ctx.const_args[i]))
                continue
            if ctx.uses.get(var, 0) == 0:
                created.append(None)    # unused argument: no arc at all
                continue
            arc = f"in{i}"
            ctx.env_inputs.add(arc)
            created.append(arc)
            ctx.bind(var, arc, streamy=True)
        ctx.created_inputs = created
    else:                               # inlined call: arcs handed in
        for var, (arc, streamy) in zip(jaxpr.invars, in_arcs):
            if arc in ctx.graph.consts:
                ctx.bind_const(var, arc)
            else:
                ctx.bind(var, arc, streamy)

    # 3. equations in program order
    for eqn in jaxpr.eqns:
        _lower_eqn(ctx, eqn)

    # 4. outputs
    results = []
    for v in jaxpr.outvars:
        if _is_literal(v):
            results.append((ctx.lit(v.val), False))
        else:
            results.append((ctx.use(v), ctx.is_streamy(v)))
    return results
