"""jaxpr-equation -> fabric-operator lowering rules (DESIGN.md §9).

One rule per jaxpr primitive.  The arithmetic/logic/relational
primitives map 1:1 onto :class:`~repro.core.graph.Op`; everything else
is a *schema* over several operators:

* fan-out — an arc carries one receiver, so a value consumed by k
  equations becomes a COPY tree (``library._fanout``);
* ``select_n`` (``jnp.where`` / ``lax.select``) — the classical
  dataflow conditional: each data operand rides a BRANCH steered by the
  predicate (the untaken side is SINKed) and a DMERGE reunites the
  taken tokens, so *both* operands are consumed every firing and the
  fabric streams without stale tokens;
* ``neg`` / ``abs`` / ``integer_pow`` / ``clamp`` — expanded into
  SUB/MUL/MAX/MIN trees that are bit-exact at the execution dtype
  (``neg`` is ``0 - x`` for ints, ``x * -1`` for floats, so ``-0.0``
  and INT_MIN behave exactly like jax);
* constants — jaxpr literals and closure consts become sticky const
  buses (always-full environment arcs), which is what lets the PR 3
  constant-folding pass collapse constant subexpressions at compile
  time;
* ``pjit`` / ``custom_jvp_call`` etc. — inlined recursively.

Anything else raises :class:`LoweringError` naming the primitive.
"""
from __future__ import annotations

import itertools

import numpy as np

from repro.core.graph import Graph, Op
from repro.core.library import _fanout, _reduce_tree


class LoweringError(Exception):
    """A traced program contains an equation the fabric cannot run."""


# primitive name -> Op / schema note (the DESIGN.md §9 lowering table;
# also the vocabulary quoted by LoweringError messages)
SUPPORTED = {
    "add": "ADD", "sub": "SUB", "mul": "MUL",
    "div": "DIV (float dtypes only; the fabric ALU defines x/0 = 0)",
    "max": "MAX", "min": "MIN",
    "and": "AND", "or": "OR", "xor": "XOR", "not": "NOT",
    "shift_left": "SHL",
    "shift_right_arithmetic": "SHR (signed dtypes)",
    "shift_right_logical": "SHR (unsigned dtypes)",
    "gt": "IFGT", "ge": "IFGE", "lt": "IFLT", "le": "IFLE",
    "eq": "IFEQ", "ne": "IFDF",
    "select_n": "BRANCH x2 + SINK x2 + DMERGE (2-way, bool predicate)",
    "neg": "SUB(0, x) int / MUL(x, -1) float",
    "abs": "COPY + neg + MAX",
    "integer_pow": "MUL tree (int dtypes, y >= 0)",
    "clamp": "MAX + MIN",
    "convert_element_type": "alias (bool->dtype / same dtype) or "
                            "IFDF(x, 0) (dtype->bool)",
    "stop_gradient": "alias",
    "broadcast_in_dim": "alias (scalar)", "reshape": "alias (scalar)",
    "squeeze": "alias (scalar)",
    "pjit": "inlined", "closed_call": "inlined",
    "custom_jvp_call": "inlined", "custom_vjp_call": "inlined",
}

_BINOP = {
    "add": Op.ADD, "sub": Op.SUB, "mul": Op.MUL,
    "max": Op.MAX, "min": Op.MIN,
    "and": Op.AND, "or": Op.OR, "xor": Op.XOR,
    "shift_left": Op.SHL,
    "gt": Op.IFGT, "ge": Op.IFGE, "lt": Op.IFLT, "le": Op.IFLE,
    "eq": Op.IFEQ, "ne": Op.IFDF,
}
# `a op b == b op a` bit-exactly at any dtype (engine ALU formulas):
# used to put a const operand on the b side, where the identity-
# elimination pass looks for it.
_COMMUTATIVE = frozenset(
    ("add", "mul", "max", "min", "and", "or", "xor", "eq", "ne"))
_ALIAS = ("stop_gradient", "broadcast_in_dim", "reshape", "squeeze")
_CALL = ("pjit", "closed_call", "custom_jvp_call", "custom_vjp_call")


def _is_literal(atom) -> bool:
    return not hasattr(atom, "count")    # jax core Var has .count


class _Ctx:
    """Lowering state: per-var arc supplies, use counts, taint."""

    def __init__(self, graph: Graph, dtype):
        self.graph = graph
        self.dtype = np.dtype(dtype)
        self.supply: dict = {}     # Var -> list[str] queue | str const arc
        self.uses: dict = {}       # Var -> planned consumer count
        self.streamy: dict = {}    # Var -> depends on an env stream?
        self.env_inputs: set[str] = set()
        self.const_args: dict[int, object] = {}   # arg index -> value
        self._n = itertools.count()
        self._lits: dict = {}

    def fresh(self, tag: str = "v") -> str:
        return f"{tag}{next(self._n)}"

    # -- constants ------------------------------------------------------
    def lit(self, value) -> str:
        """Const bus for a compile-time scalar (deduped by value bits —
        const arcs are sticky and may feed many receivers)."""
        v = np.asarray(value, self.dtype).reshape(()).item()
        key = repr(v)
        arc = self._lits.get(key)
        if arc is None:
            arc = self.fresh("lit")
            self.graph.const(arc, v)
            self._lits[key] = arc
        return arc

    # -- supplies -------------------------------------------------------
    def use(self, atom) -> str:
        """Claim one arc carrying the atom's value."""
        if _is_literal(atom):
            return self.lit(atom.val)
        s = self.supply[atom]
        return s if isinstance(s, str) else s.pop(0)

    def is_streamy(self, atom) -> bool:
        return (not _is_literal(atom)) and self.streamy.get(atom, False)

    def bind(self, var, arc: str, streamy: bool = True) -> None:
        """Register `arc` as var's value, fanning out through a COPY
        tree when the var has several consumers and SINKing it when it
        has none (a produced token must always find a receiver, or the
        arc would surface as a spurious environment output)."""
        u = self.uses.get(var, 0)
        if u == 0:
            self.graph.add(Op.SINK, [arc], [])
            self.supply[var] = []
        elif u == 1:
            self.supply[var] = [arc]
        else:
            self.supply[var] = _fanout(self.graph, arc, u, arc + "f")
        self.streamy[var] = streamy

    def bind_const(self, var, arc: str) -> None:
        self.supply[var] = arc      # sticky bus: unlimited receivers
        self.streamy[var] = False


def _err(eqn, why: str) -> LoweringError:
    return LoweringError(
        f"primitive '{eqn.primitive.name}' {why} "
        f"(fabric lowering table: {sorted(SUPPORTED)})")


def _aval_dtype(atom):
    return np.dtype(atom.aval.dtype) if not _is_literal(atom) \
        else np.dtype(np.asarray(atom.val).dtype)


def _convert_kind(ctx: _Ctx, eqn) -> str:
    """alias | ne0 — or raise for a conversion the fabric cannot carry
    (arcs hold one dtype; deciders already emit 0/1 at that dtype)."""
    src = _aval_dtype(eqn.invars[0])
    dst = np.dtype(eqn.params["new_dtype"])
    if src == dst or (src == np.bool_ and dst == ctx.dtype):
        return "alias"
    if dst == np.bool_ and src == ctx.dtype:
        return "ne0"
    raise _err(eqn, f"converts {src} -> {dst}, but every arc of this "
                    f"fabric carries {ctx.dtype} tokens")


def _pow_uses(eqn, uses) -> int:
    y = int(eqn.params["y"])
    if y == 1:
        return uses.get(eqn.outvars[0], 0)      # pure alias
    return max(y, 0)


def _multiplicities(ctx: _Ctx, eqn, uses) -> list[int]:
    """How many arcs of each operand the eqn's lowering will claim.
    ``uses`` holds the (already complete, thanks to reverse iteration)
    consumer counts of the eqn's outvars — alias lowerings forward
    their output's demand straight to their input."""
    name = eqn.primitive.name
    if name == "select_n":
        return [3] + [1] * (len(eqn.invars) - 1)
    if name == "abs":
        return [2]
    if name == "integer_pow":
        return [_pow_uses(eqn, uses)]
    if name in _ALIAS:
        return [uses.get(eqn.outvars[0], 0)]
    if name == "convert_element_type" and _convert_kind(ctx, eqn) == "alias":
        return [uses.get(eqn.outvars[0], 0)]
    return [1] * len(eqn.invars)


def _bind_alias(ctx: _Ctx, outvar, atom) -> None:
    """outvar shares atom's arcs (its demand was pre-charged to atom)."""
    if _is_literal(atom):
        ctx.bind_const(outvar, ctx.lit(atom.val))
        return
    s = ctx.supply[atom]
    if isinstance(s, str):
        ctx.bind_const(outvar, s)
    else:
        arcs = [ctx.use(atom) for _ in range(ctx.uses.get(outvar, 0))]
        ctx.supply[outvar] = arcs
        ctx.streamy[outvar] = ctx.is_streamy(atom)


def _lower_eqn(ctx: _Ctx, eqn) -> None:
    name = eqn.primitive.name
    g, dtype = ctx.graph, ctx.dtype
    is_int = np.issubdtype(dtype, np.integer)
    out = eqn.outvars[0] if eqn.outvars else None

    if name in _BINOP or name == "div" or name.startswith("shift_right"):
        if name == "div":
            if is_int:
                raise _err(eqn, "is round-toward-zero integer division "
                                "(jnp `//` also routes through `rem`); "
                                "the fabric DIV is float-only — use "
                                "shifts for powers of two")
            op = Op.DIV
        elif name == "shift_right_arithmetic":
            if not is_int or np.issubdtype(dtype, np.unsignedinteger):
                raise _err(eqn, "needs a signed integer dtype")
            op = Op.SHR
        elif name == "shift_right_logical":
            if not np.issubdtype(dtype, np.unsignedinteger):
                raise _err(eqn, "is a logical shift; the fabric SHR is "
                                "arithmetic for signed dtypes — use an "
                                "unsigned dtype")
            op = Op.SHR
        else:
            op = _BINOP[name]
        a, b = eqn.invars
        if (name in _COMMUTATIVE and not ctx.is_streamy(a)
                and ctx.is_streamy(b)):
            a, b = b, a          # const operand on the b side (passes
            #                      splice identities off inputs[1])
        streamy = ctx.is_streamy(a) or ctx.is_streamy(b)
        arc = ctx.fresh()
        g.add(op, [ctx.use(a), ctx.use(b)], [arc])
        ctx.bind(out, arc, streamy)
        return

    if name == "not":
        arc = ctx.fresh()
        g.add(Op.NOT, [ctx.use(eqn.invars[0])], [arc])
        ctx.bind(out, arc, ctx.is_streamy(eqn.invars[0]))
        return

    if name == "neg":
        x = eqn.invars[0]
        arc = ctx.fresh()
        if is_int:
            g.add(Op.SUB, [ctx.lit(0), ctx.use(x)], [arc])
        else:           # 0.0 - x flips -0.0; x * -1.0 is bit-exact
            g.add(Op.MUL, [ctx.use(x), ctx.lit(-1)], [arc])
        ctx.bind(out, arc, ctx.is_streamy(x))
        return

    if name == "abs":
        x = eqn.invars[0]
        x0, x1 = ctx.use(x), ctx.use(x)
        nn = ctx.fresh()
        if is_int:
            g.add(Op.SUB, [ctx.lit(0), x1], [nn])
        else:
            g.add(Op.MUL, [x1, ctx.lit(-1)], [nn])
        arc = ctx.fresh()
        g.add(Op.MAX, [x0, nn], [arc])    # MAX(+0,-0)=+0 matches |−0.0|
        ctx.bind(out, arc, ctx.is_streamy(x))
        return

    if name == "integer_pow":
        x = eqn.invars[0]
        y = int(eqn.params["y"])
        if y < 0:
            raise _err(eqn, f"has negative exponent y={y}")
        if y == 0:
            ctx.bind_const(out, ctx.lit(1))
            return
        if y == 1:
            _bind_alias(ctx, out, x)
            return
        if not is_int:
            raise _err(eqn, "expands to a MUL tree whose rounding "
                            "order is only bit-exact for integer "
                            "dtypes — spell out float powers as "
                            "explicit multiplies")
        arcs = [ctx.use(x) for _ in range(y)]
        arc = ctx.fresh()
        _reduce_tree(g, arcs, Op.MUL, arc + "p", final=arc)
        ctx.bind(out, arc, ctx.is_streamy(x))
        return

    if name == "clamp":
        lo, x, hi = eqn.invars    # lax.clamp(min, operand, max)
        t, arc = ctx.fresh(), ctx.fresh()
        g.add(Op.MAX, [ctx.use(x), ctx.use(lo)], [t])
        g.add(Op.MIN, [t, ctx.use(hi)], [arc])
        ctx.bind(out, arc, any(ctx.is_streamy(v) for v in eqn.invars))
        return

    if name == "select_n":
        pred = eqn.invars[0]
        if len(eqn.invars) != 3:
            raise _err(eqn, f"has {len(eqn.invars) - 1} cases; only "
                            "2-way (boolean) selects lower")
        if _aval_dtype(pred) != np.bool_:
            raise _err(eqn, "has a non-boolean selector")
        fv, tv = eqn.invars[1], eqn.invars[2]   # select_n: cases[pred]
        c_t, c_f, c_m = ctx.use(pred), ctx.use(pred), ctx.use(pred)
        t_live, t_dead = ctx.fresh(), ctx.fresh()
        f_live, f_dead = ctx.fresh(), ctx.fresh()
        g.add(Op.BRANCH, [ctx.use(tv), c_t], [t_live, t_dead])
        g.add(Op.SINK, [t_dead], [])
        g.add(Op.BRANCH, [ctx.use(fv), c_f], [f_dead, f_live])
        g.add(Op.SINK, [f_dead], [])
        arc = ctx.fresh()
        g.add(Op.DMERGE, [t_live, f_live, c_m], [arc])
        ctx.bind(out, arc, any(ctx.is_streamy(v) for v in eqn.invars))
        return

    if name == "convert_element_type":
        x = eqn.invars[0]
        if _convert_kind(ctx, eqn) == "alias":
            _bind_alias(ctx, out, x)
        else:                     # dtype -> bool: x != 0
            arc = ctx.fresh()
            g.add(Op.IFDF, [ctx.use(x), ctx.lit(0)], [arc])
            ctx.bind(out, arc, ctx.is_streamy(x))
        return

    if name in _ALIAS:
        aval = getattr(eqn.outvars[0], "aval", None)
        if aval is not None and tuple(aval.shape) != ():
            raise _err(eqn, f"produces shape {tuple(aval.shape)}; the "
                            "fabric carries scalar tokens")
        _bind_alias(ctx, out, eqn.invars[0])
        return

    if name in _CALL:
        inner = None
        for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
            cand = eqn.params.get(key)
            if cand is not None and hasattr(cand, "jaxpr"):
                inner = cand
                break
        if inner is None:
            raise _err(eqn, "has no inlinable sub-jaxpr")
        supplies = [(ctx.use(v), ctx.is_streamy(v)) for v in eqn.invars]
        results = lower_jaxpr(ctx, inner.jaxpr, inner.consts, supplies)
        for var, (arc, streamy) in zip(eqn.outvars, results):
            if arc in ctx.graph.consts:
                ctx.bind_const(var, arc)
            else:
                ctx.bind(var, arc, streamy)
        return

    raise _err(eqn, "has no fabric lowering")


def lower_jaxpr(ctx: _Ctx, jaxpr, const_vals, in_arcs
                ) -> list[tuple[str, bool]]:
    """Lower one jaxpr scope onto ctx.graph.

    in_arcs: one ``(arc, streamy)`` pair per invar — or None (top
    level) to create an environment input arc ``in{i}`` on demand,
    recording the created names (None for unused args) in
    ``ctx.created_inputs``.  Returns ``(arc, streamy)`` per outvar;
    unused invar arcs handed in by a caller are SINKed so every token
    still finds a receiver.
    """
    # 1. demand counting, in reverse so alias chains see their own
    #    consumers before charging their inputs
    uses: dict = {}

    def charge(atom, m):
        if not _is_literal(atom) and m:
            uses[atom] = uses.get(atom, 0) + m

    for v in jaxpr.outvars:
        charge(v, 1)
    for eqn in reversed(jaxpr.eqns):
        for atom, m in zip(eqn.invars, _multiplicities(ctx, eqn, uses)):
            charge(atom, m)
    ctx.uses.update(uses)

    # 2. bind closure consts and arguments
    for var, val in zip(jaxpr.constvars, const_vals):
        val = np.asarray(val)
        if val.shape != ():
            raise LoweringError(
                f"closure constant of shape {val.shape} cannot ride a "
                "scalar-token arc (fabric tokens are 0-d)")
        ctx.bind_const(var, ctx.lit(val))
    if in_arcs is None:                 # top level: environment streams
        created: list[str | None] = []
        for i, var in enumerate(jaxpr.invars):
            if i in ctx.const_args:     # sticky const bus, not a stream
                if ctx.uses.get(var, 0):
                    ctx.bind_const(var, ctx.lit(ctx.const_args[i]))
                continue
            if ctx.uses.get(var, 0) == 0:
                created.append(None)    # unused argument: no arc at all
                continue
            arc = f"in{i}"
            ctx.env_inputs.add(arc)
            created.append(arc)
            ctx.bind(var, arc, streamy=True)
        ctx.created_inputs = created
    else:                               # inlined call: arcs handed in
        for var, (arc, streamy) in zip(jaxpr.invars, in_arcs):
            if arc in ctx.graph.consts:
                ctx.bind_const(var, arc)
            else:
                ctx.bind(var, arc, streamy)

    # 3. equations in program order
    for eqn in jaxpr.eqns:
        _lower_eqn(ctx, eqn)

    # 4. outputs
    results = []
    for v in jaxpr.outvars:
        if _is_literal(v):
            results.append((ctx.lit(v.val), False))
        else:
            results.append((ctx.use(v), ctx.is_streamy(v)))
    return results
