"""Deterministic synthetic data pipeline.

``batch_for_step(step)`` is a pure function of (seed, step, shape): token
streams are generated with a counter-based RNG keyed on the step, so a
restarted job reproduces the exact batch sequence with NO pipeline state
in the checkpoint — this is what makes checkpoint/restart byte-exact and
lets an *elastic* resume re-shard the same global batch over a different
mesh.  A host-sharded loader would slice ``[host_offset : host_offset +
per_host]`` of the same global batch; on this single-process runtime we
materialize the global batch.

A background prefetch thread overlaps batch synthesis with the train step
(the CPU-side analogue of overlapping host->device transfer).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np


class SyntheticLM:
    """Markov-ish synthetic token stream with learnable structure
    (next token correlates with current), so loss visibly decreases."""

    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, frontend: str = "none",
                 n_patches: int = 0, frontend_dim: int = 0,
                 enc_seq: int = 0):
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.frontend = frontend
        self.n_patches = n_patches
        self.frontend_dim = frontend_dim
        self.enc_seq = enc_seq

    def batch_for_step(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        B, S, V = self.global_batch, self.seq_len, self.vocab
        # structured stream: x_{t+1} = (a*x_t + b + noise) % V
        a = 31
        x0 = rng.integers(0, V, (B, 1))
        noise = (rng.random((B, S)) < 0.1) * rng.integers(0, V, (B, S))
        toks = np.zeros((B, S + 1), np.int64)
        toks[:, 0:1] = x0
        for t in range(S):
            toks[:, t + 1] = (a * toks[:, t] + 7 + noise[:, t]) % V
        batch = {"tokens": toks[:, :-1].astype(np.int32),
                 "labels": toks[:, 1:].astype(np.int32)}
        if self.frontend == "patches":
            batch["patches"] = rng.normal(
                0, 1, (B, self.n_patches, self.frontend_dim)
            ).astype(np.float32)
            batch["labels"][:, :self.n_patches] = -1   # mask image slots
        if self.frontend == "frames":
            batch["frames"] = rng.normal(
                0, 1, (B, self.enc_seq, self.frontend_dim)
            ).astype(np.float32)
        return batch


def prefetch(source: SyntheticLM, start_step: int, depth: int = 2
             ) -> Iterator[dict]:
    """Background-thread prefetch of successive steps."""
    q: queue.Queue = queue.Queue(maxsize=depth)
    stop = threading.Event()

    def worker():
        step = start_step
        while not stop.is_set():
            try:
                q.put(source.batch_for_step(step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()


def make_source(cfg, shape, seed: int = 0) -> SyntheticLM:
    return SyntheticLM(
        vocab=cfg.vocab, seq_len=shape.seq_len,
        global_batch=shape.global_batch, seed=seed,
        frontend=cfg.frontend, n_patches=cfg.n_patches,
        frontend_dim=cfg.frontend_dim, enc_seq=cfg.enc_seq)
