"""--arch config (assignment-exact); see configs/base.py."""
from repro.configs.base import COMMAND_R_PLUS_104B

CONFIG = COMMAND_R_PLUS_104B
