"""--arch config (assignment-exact); see configs/base.py."""
from repro.configs.base import KIMI_K2

CONFIG = KIMI_K2
