"""--arch config (assignment-exact); see configs/base.py."""
from repro.configs.base import STABLELM_1_6B

CONFIG = STABLELM_1_6B
