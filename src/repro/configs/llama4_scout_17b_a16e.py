"""--arch config (assignment-exact); see configs/base.py."""
from repro.configs.base import LLAMA4_SCOUT

CONFIG = LLAMA4_SCOUT
