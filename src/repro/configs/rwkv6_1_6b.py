"""--arch config (assignment-exact); see configs/base.py."""
from repro.configs.base import RWKV6_1_6B

CONFIG = RWKV6_1_6B
