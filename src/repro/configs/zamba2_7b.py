"""--arch config (assignment-exact); see configs/base.py."""
from repro.configs.base import ZAMBA2_7B

CONFIG = ZAMBA2_7B
