"""Architecture config system.

One :class:`ArchConfig` per assigned architecture (exact numbers from the
assignment table) plus the paper's own benchmark config.  Every config is
selectable via ``--arch <id>`` in the launchers.

Shape sets (assignment): each architecture is paired with
  train_4k     seq=4096,   global_batch=256   -> train_step
  prefill_32k  seq=32768,  global_batch=32    -> serve_prefill
  decode_32k   seq=32768,  global_batch=128   -> serve_step (1 new token,
                                                 KV cache of seq_len)
  long_500k    seq=524288, global_batch=1     -> serve_step; SUB-QUADRATIC
               archs only (zamba2, rwkv6) — skipped for pure
               full-attention archs per the assignment (see DESIGN.md
               §Arch-applicability).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, Shape] = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | hybrid | vlm | audio | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    # ---- style knobs ----
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    act: str = "swiglu"         # swiglu | gelu
    rope: bool = True
    qkv_bias: bool = False
    attn_out_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    # ---- MoE ----
    n_experts: int = 0
    top_k: int = 1
    moe_d_ff: int = 0
    n_dense_layers: int = 0     # leading dense layers (kimi-k2 style)
    shared_expert: bool = False
    moe_group_size: int = 512   # GShard dispatch group length
    capacity_factor: float = 1.25
    # ---- SSM / hybrid ----
    ssm_state: int = 0          # Mamba2 state dim (zamba2)
    ssm_head_dim: int = 64
    attn_every: int = 0         # hybrid: shared attn block every k layers
    rwkv: bool = False          # RWKV6 blocks instead of attention
    # ---- enc-dec (whisper) ----
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 4_096        # stub audio context (frame embeddings)
    # ---- modality frontend stubs ----
    frontend: str = "none"      # none | patches | frames
    n_patches: int = 256
    frontend_dim: int = 1024    # raw patch/frame embedding width
    # ---- numerics / memory policy ----
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    loss_chunk: int = 512       # chunked-vocab cross entropy (memory opt)
    attn_q_block: int = 1024    # pure-JAX flash attention block sizes
    attn_kv_block: int = 1024
    remat: bool = True          # activation checkpoint each layer
    fused_qkv: bool = True
    ssm_chunk: int = 256        # mamba2 SSD chunk length
    # ---- distribution hints (set per dry-run cell, not per arch) ----
    mesh_axes: tuple | None = None       # e.g. ("data","model")
    attn_partition: str = "auto"         # auto | seq (sequence-parallel
    #                                      attention via sharding hints)
    moe_partition: str = "auto"          # auto | tokens (pin expert
    #                                      activations to (E->model,
    #                                      tokens->data); gathers weights
    #                                      instead of reducing activations)
    ssm_partition: str = "auto"          # auto | tokens (pin mamba/rwkv
    #                                      intermediates: batch->data,
    #                                      heads/channels->model)

    # ------------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def is_subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def shapes(self) -> list[Shape]:
        out = [SHAPES["train_4k"], SHAPES["prefill_32k"],
               SHAPES["decode_32k"]]
        if self.is_subquadratic:
            out.append(SHAPES["long_500k"])
        return out

    def skipped_shapes(self) -> dict[str, str]:
        if self.is_subquadratic:
            return {}
        return {"long_500k": "full-attention arch: 524k-token full "
                             "attention is out of scope per assignment"}

    # ---- parameter count (for MODEL_FLOPS = 6·N·D) -------------------
    def param_count(self, active_only: bool = False) -> int:
        d, ff, V = self.d_model, self.d_ff, self.vocab
        hd, H, Hkv = self.head_dim, self.n_heads, self.n_kv_heads
        attn = d * (H * hd) + 2 * d * (Hkv * hd) + (H * hd) * d
        mlp_mult = 3 if self.act == "swiglu" else 2
        dense_mlp = mlp_mult * d * ff
        n = 0
        if self.rwkv:
            # rwkv6: time-mix (r,k,v,g,o + decay/bonus) ~ 5*d*d, channel-mix
            per = 5 * d * d + 2 * d * self.d_ff + d * self.d_ff // 2
            n += self.n_layers * per
        elif self.family == "hybrid":
            n_attn = self.n_layers // max(self.attn_every, 1)
            per_mamba = (2 * d * (2 * d + 2 * self.ssm_state)  # in_proj
                         + 2 * d * d                            # out/gate
                         + mlp_mult * d * ff // 2)
            n += self.n_layers * per_mamba
            n += 1 * (attn + dense_mlp)  # ONE shared attn block (reused)
        elif self.n_experts:
            eff = self.top_k if active_only else self.n_experts
            per_moe = attn + mlp_mult * d * self.moe_d_ff * eff
            if self.shared_expert:
                per_moe += mlp_mult * d * self.moe_d_ff
            n += (self.n_layers - self.n_dense_layers) * per_moe
            n += self.n_dense_layers * (attn + dense_mlp)
        else:
            n += self.n_layers * (attn + dense_mlp)
        if self.enc_dec:
            # encoder stack + decoder cross-attention
            n += self.n_enc_layers * (attn + dense_mlp)
            n += self.n_layers * attn  # cross-attn per decoder layer
        n += V * d  # embedding
        if not self.tie_embeddings:
            n += V * d
        return n

    # ---- reduced config for CPU smoke tests --------------------------
    def reduced(self) -> "ArchConfig":
        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 4 if self.attn_every else 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads
            < self.n_heads else 4,
            d_ff=256,
            vocab=512,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            moe_d_ff=64 if self.n_experts else 0,
            n_dense_layers=min(self.n_dense_layers, 1),
            moe_group_size=64,
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=32 if self.ssm_state or self.rwkv else 64,
            attn_every=min(self.attn_every, 2),
            n_enc_layers=min(self.n_enc_layers, 2),
            enc_seq=64,
            n_patches=8,
            frontend_dim=64,
            loss_chunk=64,
            attn_q_block=64,
            attn_kv_block=64,
            param_dtype="float32",
            compute_dtype="float32",
        )


# ---------------------------------------------------------------------------
# the assigned architectures (exact assignment-table numbers)
# ---------------------------------------------------------------------------
ARCHS: dict[str, ArchConfig] = {}


def _reg(cfg: ArchConfig) -> ArchConfig:
    ARCHS[cfg.name] = cfg
    return cfg


STARCODER2_7B = _reg(ArchConfig(
    name="starcoder2-7b", family="dense", n_layers=32, d_model=4608,
    n_heads=36, n_kv_heads=4, d_ff=18432, vocab=49152,
    norm="layernorm", act="gelu", rope=True, qkv_bias=True,
    attn_out_bias=True))

INTERNLM2_1_8B = _reg(ArchConfig(
    name="internlm2-1.8b", family="dense", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=8, d_ff=8192, vocab=92544,
    norm="rmsnorm", act="swiglu", rope=True))

COMMAND_R_PLUS_104B = _reg(ArchConfig(
    name="command-r-plus-104b", family="dense", n_layers=64, d_model=12288,
    n_heads=96, n_kv_heads=8, d_ff=33792, vocab=256000,
    norm="layernorm", act="swiglu", rope=True, qkv_bias=False,
    tie_embeddings=True))  # no-bias; Cohere ties embeddings

STABLELM_1_6B = _reg(ArchConfig(
    name="stablelm-1.6b", family="dense", n_layers=24, d_model=2048,
    n_heads=32, n_kv_heads=32, d_ff=5632, vocab=100352,
    norm="layernorm", act="swiglu", rope=True))

ZAMBA2_7B = _reg(ArchConfig(
    name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584,
    n_heads=32, n_kv_heads=32, d_ff=14336, vocab=32000,
    norm="rmsnorm", act="swiglu", rope=True,
    ssm_state=64, ssm_head_dim=64, attn_every=6))

LLAMA4_SCOUT = _reg(ArchConfig(
    name="llama4-scout-17b-a16e", family="moe", n_layers=48, d_model=5120,
    n_heads=40, n_kv_heads=8, d_ff=8192, vocab=202048,
    norm="rmsnorm", act="swiglu", rope=True,
    n_experts=16, top_k=1, moe_d_ff=8192, shared_expert=True))

KIMI_K2 = _reg(ArchConfig(
    name="kimi-k2-1t-a32b", family="moe", n_layers=61, d_model=7168,
    n_heads=64, n_kv_heads=8, d_ff=18432, vocab=163840,
    norm="rmsnorm", act="swiglu", rope=True,
    n_experts=384, top_k=8, moe_d_ff=2048, n_dense_layers=1,
    shared_expert=True))

INTERNVL2_76B = _reg(ArchConfig(
    name="internvl2-76b", family="vlm", n_layers=80, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=28672, vocab=128256,
    norm="rmsnorm", act="swiglu", rope=True,
    frontend="patches", n_patches=256, frontend_dim=3200))  # InternViT-6B

WHISPER_MEDIUM = _reg(ArchConfig(
    name="whisper-medium", family="audio", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=4096, vocab=51865,
    norm="layernorm", act="gelu", rope=False,
    enc_dec=True, n_enc_layers=24, enc_seq=4096,
    frontend="frames", frontend_dim=80, tie_embeddings=True))

RWKV6_1_6B = _reg(ArchConfig(
    name="rwkv6-1.6b", family="ssm", n_layers=24, d_model=2048,
    n_heads=32, n_kv_heads=32, d_ff=7168, vocab=65536,
    norm="layernorm", rwkv=True, rope=False, ssm_head_dim=64))


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]
