"""--arch config (assignment-exact); see configs/base.py."""
from repro.configs.base import WHISPER_MEDIUM

CONFIG = WHISPER_MEDIUM
