"""--arch config (assignment-exact); see configs/base.py."""
from repro.configs.base import INTERNLM2_1_8B

CONFIG = INTERNLM2_1_8B
