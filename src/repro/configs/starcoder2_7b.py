"""--arch config (assignment-exact); see configs/base.py."""
from repro.configs.base import STARCODER2_7B

CONFIG = STARCODER2_7B
