"""--arch config (assignment-exact); see configs/base.py."""
from repro.configs.base import INTERNVL2_76B

CONFIG = INTERNVL2_76B
