"""Expression-to-fabric frontend, end to end (DESIGN.md §9).

An ordinary Python function becomes a static dataflow fabric: traced
through jax, lowered onto the Veen operator set, optimized by the
graph-rewrite passes, executed bit-identically on every backend, and
served by the continuous-batching DataflowServer — the paper's
algorithm-to-graph toolchain step, reproduced in software.

Run: PYTHONPATH=src python examples/frontend_trace.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import asm
from repro.core.compile import compile_fn
from repro.front import trace
from repro.serve.dataflow_server import DataflowServer

# -- 1. trace: Python expression -> fabric -----------------------------------
def wave_shaper(x, gain, bias):
    """Clamped gain stage with a data-dependent fold: everyday DSP
    written as everyday Python.  ``bias * bias`` is a const-fed
    operator the folding pass evaluates at compile time."""
    y = jnp.clip(gain * x + bias * bias, -128, 127)
    return jnp.where(y > 64, 127 - y, y)

prog = trace(wave_shaper, np.int32, np.int32, np.int32,
             const_args={1: 3, 2: 10})      # gain/bias as sticky const buses
print(prog.summary())
print(asm.emit(prog))                       # Listing-1 assembler of the fabric

# -- 2. run it on every backend, optimized -----------------------------------
x = np.asarray([0, 10, 40, -100, 25], np.int32)
y = np.clip(3 * x + 10 * 10, -128, 127)
want = np.where(y > 64, 127 - y, y)
for backend in ("reference", "xla", "pallas"):
    run = compile_fn(wave_shaper, np.int32, np.int32, np.int32,
                     const_args={1: 3, 2: 10},
                     backend=backend, block_cycles=8, optimize="full")
    res = run(run.make_feeds(x))
    got = int(np.asarray(res.outputs[run.out_arcs[0]]))
    shrunk = (f" (fabric shrunk {run.report.nodes_before}->"
              f"{run.report.nodes_after} nodes)"
              if run.report and run.report.changed else "")
    print(f"{backend:10s} last={got} want={int(want[-1])} "
          f"tokens={res.counts[run.out_arcs[0]]} "
          f"cycles={res.cycles}{shrunk}")

# -- 3. serve it: a traced program is just another fabric signature ----------
srv = DataflowServer(prog, slots=4, block_cycles=8, backend="xla")
rng = np.random.default_rng(0)
uids = [srv.submit(prog.make_feeds(rng.integers(-50, 50, (k,))))
        for k in (1, 5, 2, 7)]
for r in sorted(srv.drain(), key=lambda r: r.uid):
    print(f"request {r.uid}: tokens={r.metrics.tokens_out} "
          f"queue_wait={r.metrics.queue_wait_blocks} blocks, "
          f"residency={r.metrics.residency_blocks} blocks")
