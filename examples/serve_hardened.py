"""Hardened serving under injected faults, end to end (DESIGN.md §11).

One multi-tenant workload — deadlines, per-request cycle budgets, two
tenants sharing a bounded queue — served through a seeded FaultPlan
that makes the primary backend die, wedges one slot, and poisons one
request's feeds.  The point: the server *always* answers.  Every
submitted request comes back with exactly one Result and a one-word
disposition; a failing backend degrades down the
``pallas -> xla -> reference`` chain instead of taking the server out.

Run: PYTHONPATH=src python examples/serve_hardened.py
"""
import numpy as np

from repro.core import library
from repro.serve.dataflow_server import DataflowServer
from repro.serve.faults import FaultPlan
from repro.serve.types import Request

bench = library.vector_sum_graph(8)
rng = np.random.default_rng(0)

# every xla dispatch fails from block 7 on (forcing degradation to
# the reference oracle), request 4's slot wedges, request 5's feeds are
# poisoned with INT_MIN/INT_MAX tokens
plan = FaultPlan(seed=7, persistent_backends={"xla"},
                 persistent_from_block=7, wedge_uids={4}, poison_uids={5})

srv = DataflowServer(bench.graph, slots=2, block_cycles=4, backend="xla",
                     max_queue=8, policy="reject",       # bounded admission
                     wedge_timeout_blocks=4, max_retries=2, faults=plan)

for uid in range(1, 7):
    srv.submit(Request(
        uid=uid,
        feeds=library.random_feeds("vector_sum", bench,
                                   1 + uid % 4, rng),
        tenant=("alice", "bob")[uid % 2],                # fair queueing
        deadline_blocks=40 if uid == 3 else None,        # per-request SLO
        max_cycles=3 if uid == 6 else None))             # cycle budget

results = sorted(srv.drain(), key=lambda r: r.uid)       # never raises

print("uid  tenant  status     backend    degraded  note")
for r in results:
    req_tenant = ("alice", "bob")[r.uid % 2]
    note = {4: "wedge: watchdog freed the slot",
            5: "poisoned feeds, still deterministic",
            6: "truncated at its 3-cycle budget"}.get(r.uid, "")
    print(f"{r.uid:3d}  {req_tenant:6s}  {r.status:9s}  "
          f"{r.metrics.backend or '-':9s}  "
          f"{str(r.metrics.degraded):8s}  {note}")

assert len(results) == 6, "every request must be answered"
print(f"\nserver backend now: {srv.backend} "
      f"(degraded from xla after its dispatches started failing)")
print("degradation events:")
for e in srv.events:
    if e["kind"] in ("degrade", "degrade-to"):
        print(f"  block {e['block']:3d}  {e['kind']:10s} "
              f"{e.get('from_backend', '')} {e.get('backend', '')}")
