"""Multi-fabric sharding: one graph, P regions, token channels
(DESIGN.md §14).

1. Partition a graph: inspect regions, cut arcs, balance, and the
   cache-key spec; see the loop-cycle guarantee on a cyclic graph.
2. Run sharded vs solo and check bit-identity in every field,
   including the merged §12 profile with per-channel counters.
3. Compose with the optimizing compiler via compile_graph(partition=).
4. Serve a sharded fabric through the resumable slot API.

Run: PYTHONPATH=src python examples/shard.py
(Single-device here, so the shards run under vmap; set
 XLA_FLAGS=--xla_force_host_platform_device_count=2 before launch to
 see the same program run under shard_map — same bits either way.)
"""
import numpy as np

from repro.core import library
from repro.core.compile import compile_graph
from repro.core.engine import DataflowEngine
from repro.core.partition import partition_graph
from repro.serve.dataflow_server import DataflowServer

# -- 1. the partition ---------------------------------------------------------
bench = library.BENCHES["vector_sum"]()
part = partition_graph(bench.graph, 2)
cut = part.cut_arcs(bench.graph)
w = part.region_weights(bench.graph)
print(f"partition {part.spec()}: regions of {[len(r) for r in part.regions()]} "
      f"nodes, weights={w} (max frac {max(w) / sum(w):.3f}), "
      f"cut arcs={cut}")

gcd = library.BENCHES["gcd"]()
gpart = partition_graph(gcd.graph, 2)
gcut = gpart.cut_arcs(gcd.graph)
print(f"gcd (value-dependent loop) still partitions: cut={gcut} — "
      "the loop SCC is one atomic supernode, so no recurrence arc is cut")

# -- 2. bit-identity: sharded vs solo -----------------------------------------
rng = np.random.default_rng(0)
feeds = library.random_feeds("vector_sum", bench, 8, rng)
solo = DataflowEngine(bench.graph, block_cycles=4, profile=True)
shard = DataflowEngine(bench.graph, block_cycles=4, profile=True,
                       partition=part)
want, got = solo.run(feeds), shard.run(feeds)
assert got.cycles == want.cycles and got.fired == want.fired
assert np.array_equal(got.node_fires, want.node_fires)
for arc in want.outputs:
    assert np.asarray(got.outputs[arc]).tobytes() == \
        np.asarray(want.outputs[arc]).tobytes()
got.profile.check()
ch = got.profile.to_json()["channels"]
print(f"sharded run bit-identical: {got.cycles} cycles, {got.fired} firings; "
      f"channel depth={ch['depth']}, traffic="
      + ", ".join(f"{a['name']}:{a['pushes']}tok" for a in ch["arcs"]))

# -- 3. through the compiler --------------------------------------------------
run = compile_graph(bench.graph, partition=2, optimize="full")
r = run(feeds)
assert r.cycles == want.cycles
assert np.asarray(r.outputs[bench.out_arc]).tobytes() == \
    np.asarray(want.outputs[bench.out_arc]).tobytes()
print(f"compile_graph(partition=2, optimize='full') -> backend={run.engine.backend}, "
      f"P={run.partition.P}, still bit-identical")

# -- 4. sharded serving -------------------------------------------------------
srv = DataflowServer(bench.graph, slots=2, partition=2)
reqs = [library.random_feeds("vector_sum", bench, 4,
                             np.random.default_rng(i)) for i in range(4)]
uids = {srv.submit(f): i for i, f in enumerate(reqs)}
results = {uids[r.uid]: r for r in srv.drain()}
ref = DataflowEngine(bench.graph)
for i, f in enumerate(reqs):
    w_ = ref.run(f)
    have = results[i]
    assert have.status == "ok" and have.engine.cycles == w_.cycles
    assert np.asarray(have.engine.outputs[bench.out_arc]).tobytes() == \
        np.asarray(w_.outputs[bench.out_arc]).tobytes()
print(f"server completed {len(results)} sharded requests, "
      "each bit-identical to a solo run")
