"""Static firing schedules: compile the interpreter away (DESIGN.md §13).

1. Probe schedulability: a control-free FIR fabric schedules, the
   value-dependent GCD loop names its blockers and falls back.
2. Inspect the locked plan: prologue + steady-state period + epilogue,
   and the period's output cadence vs the 0.5 tok/cycle handshake bound.
3. Run scheduled vs dynamic vs reference and check bit-identity in
   every field, including the §12 profile.
4. Serve a scheduled fabric through the resumable slot API.

Run: PYTHONPATH=src python examples/schedule.py
"""
import numpy as np

from repro.core import library, schedule
from repro.core.engine import DataflowEngine, pack_feeds, run_reference
from repro.serve.dataflow_server import DataflowServer

# -- 1. schedulability probe --------------------------------------------------
fir = library.BENCHES["fir"]()
gcd = library.BENCHES["gcd"]()
print("fir blockers:", schedule.schedule_blockers(fir.graph) or "(none)")
print("gcd blockers:", schedule.schedule_blockers(gcd.graph))

eng = DataflowEngine(fir.graph, schedule="auto", profile=True)
dyn = DataflowEngine(fir.graph, profile=True)
gcd_eng = DataflowEngine(gcd.graph, schedule="auto")
print(f"fir scheduled={eng._sched_on}, gcd scheduled={gcd_eng._sched_on} "
      "(auto falls back to the dynamic engine)")

# -- 2. the locked plan -------------------------------------------------------
k = 16
rng = np.random.default_rng(0)
feeds = library.random_feeds("fir", fir, k, rng)
ctx = eng._sched_ctx()
_, flens = pack_feeds(eng.p["input_arcs"], feeds, eng.token_shape,
                      ctx.np_dtype)
plan = ctx.plan_for(tuple(int(x) for x in flens))
plan.ensure(eng.max_cycles)
pc, pt = plan.steady()
print(f"plan: {plan.total} cycles as {len(plan.segments)} segments; "
      f"steady period = {pt} tokens / {pc} cycles "
      f"({pt / pc:.3f} tok/cyc vs 0.5 handshake bound)")

# -- 3. bit-identity: scheduled vs dynamic vs reference -----------------------
ref = run_reference(fir.graph, feeds, profile=True)
got = eng.run(feeds)
base = dyn.run(feeds)
assert got.cycles == ref.cycles == base.cycles
assert got.fired == ref.fired == base.fired
assert np.array_equal(got.node_fires, ref.node_fires)
for arc in got.outputs:
    assert np.asarray(got.outputs[arc]).tobytes() == \
        np.asarray(ref.outputs[arc]).tobytes()
out = np.asarray(got.outputs[fir.out_arc])
assert np.array_equal(out, np.asarray(base.outputs[fir.out_arc]))
print(f"scheduled run bit-identical: {got.cycles} cycles, "
      f"{got.fired} firings, out[{fir.out_arc}]={int(out)}")

# -- 4. serving a scheduled fabric --------------------------------------------
srv = DataflowServer(fir.graph, slots=2, schedule="auto")
assert srv.engine._sched_on
reqs = [library.random_feeds("fir", fir, 4, np.random.default_rng(i))
        for i in range(4)]
uids = {srv.submit(f): i for i, f in enumerate(reqs)}
results = {uids[r.uid]: r for r in srv.drain()}
solo = DataflowEngine(fir.graph)
for i, f in enumerate(reqs):
    want = solo.run(f)
    have = results[i]
    assert have.status == "ok"
    assert have.engine.cycles == want.cycles
    assert np.asarray(have.engine.outputs[fir.out_arc]).tobytes() == \
        np.asarray(want.outputs[fir.out_arc]).tobytes()
print(f"server completed {len(results)} scheduled requests, "
      "each bit-identical to a solo dynamic run")
