"""Quickstart: the paper's static dataflow fabric end to end.

1. Parse a Listing-1 assembler program (Fibonacci) into a Graph.
2. Execute it on the cycle-accurate token engine.
3. Compile it to native XLA and compare.
4. Stream vectors through a DAG fabric (dot product) showing pipelining.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import asm, library
from repro.core.compile import compile_cyclic, compile_dag_stream
from repro.core.engine import DataflowEngine

# -- 1. assembler -> graph ---------------------------------------------------
g = asm.parse(library.FIBONACCI_ASM, name="fibonacci")
print("fabric:", g.summary())
print(asm.emit(g))

# -- 2. cycle-accurate engine ------------------------------------------------
bench = library.fibonacci_graph()
n = 12
eng = DataflowEngine(g)
res = eng.run(bench.make_feeds(n))
print(f"fib({n}) fabric result = {int(res.outputs['fibo'])} "
      f"(python ref {int(bench.reference(n))}) in {res.cycles} cycles, "
      f"{res.fired} firings")

# -- 3. compiled backend (identical semantics, fused by XLA) ------------------
run = compile_cyclic(g)
res2 = run(bench.make_feeds(n))
assert int(res2.outputs["fibo"]) == int(res.outputs["fibo"])
assert res2.cycles == res.cycles
print("compiled backend matches cycle-for-cycle")

# -- 4. streaming a DAG fabric ------------------------------------------------
dot = library.dot_product_graph(32)
k = 16
rng = np.random.default_rng(0)
a = rng.integers(0, 9, (k, 32))
b = rng.integers(0, 9, (k, 32))
eng = DataflowEngine(dot.graph)
lat = eng.run(dot.make_feeds(a[:1], b[:1])).cycles
thr = eng.run(dot.make_feeds(a, b)).cycles
print(f"dot-product fabric: latency {lat} cycles; {k} tokens in {thr} "
      f"cycles -> {(thr - lat) / (k - 1):.1f} cycles/token (pipelined)")
fn = compile_dag_stream(dot.graph)
out = fn({kk: np.asarray(v, np.int32) for kk, v in
          dot.make_feeds(a, b).items()})
assert np.array_equal(np.asarray(out["dot"]), dot.reference(a, b))
print("compiled stream backend matches numpy reference")

# -- 5. block-fused Pallas engine + batched streams ---------------------------
# K engine cycles per device dispatch (arc registers stay VMEM-resident,
# environment feed/drain runs in-kernel), and B independent request
# streams ride one fabric concurrently.
peng = DataflowEngine(g, backend="pallas", block_cycles=16)
res3 = peng.run(bench.make_feeds(n))
assert int(res3.outputs["fibo"]) == int(res.outputs["fibo"])
assert res3.cycles == res.cycles
print(f"pallas block engine matches in {res3.dispatches} dispatches "
      f"(vs {res.cycles} per-cycle)")
batch = peng.run_batch([bench.make_feeds(i) for i in (3, 7, 12)])
print("batched fib(3,7,12) =",
      [int(r.outputs["fibo"]) for r in batch],
      f"in {batch[0].dispatches} dispatches total")
