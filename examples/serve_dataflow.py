"""Continuous-batching dataflow serving, end to end.

A mixed-length workload (many short requests + a few long ones) on one
block-fused fabric, served two ways:

1. wave batching (`DataflowEngine.run_batch`, PR 1): every group of B
   requests starts together and waits for its slowest member;
2. the continuous-batching `DataflowServer`: per-slot quiescence
   detection, mid-flight refill from the queue, free slots clock-gated
   out of the fabric — short requests stream through while long ones
   keep their slots.

Results are bit-identical either way (and to solo runs); what changes
is requests/s and queue wait.

Run: PYTHONPATH=src python examples/serve_dataflow.py
"""
import time

import numpy as np

from repro.core import library
from repro.serve.dataflow_server import DataflowServer, cached_engine

SLOTS, K = 4, 16
bench = library.fibonacci_graph()

# deterministic mixed-length trace: fib(40) "long" jobs every 4th
# request, fib(1..3) "short" jobs in between
lens = [40 if i % 4 == 0 else 1 + i % 3 for i in range(12)]
feeds = [bench.make_feeds(n) for n in lens]
print("workload: fib(n) for n =", lens)

eng = cached_engine(bench.graph, backend="xla", block_cycles=K)

# -- wave batching -----------------------------------------------------------
t0 = time.perf_counter()
wave = []
for i in range(0, len(feeds), SLOTS):
    wave.extend(eng.run_batch(feeds[i:i + SLOTS]))
wave_s = time.perf_counter() - t0
print(f"\nwave batching:       {len(feeds) / wave_s:7.1f} req/s "
      f"(each wave waits for its slowest member)")

# -- continuous batching -----------------------------------------------------
srv = DataflowServer(bench.graph, slots=SLOTS, block_cycles=K, engine=eng)
t0 = time.perf_counter()
for f in feeds:
    srv.submit(f)
results = sorted(srv.drain(), key=lambda r: r.uid)
cont_s = time.perf_counter() - t0
print(f"continuous batching: {len(feeds) / cont_s:7.1f} req/s "
      f"({srv.block} block dispatches, {srv.admission_rounds} admission "
      f"rounds)")

print("\nuid  fib(n)      cycles  slot  wait(blocks)  residency(blocks)")
for r, w, n in zip(results, wave, lens):
    m = r.metrics
    assert int(np.asarray(r.engine.outputs["fibo"])) == \
        int(np.asarray(w.outputs["fibo"]))          # bit-identical to waves
    assert int(np.asarray(r.engine.outputs["fibo"])) == \
        int(bench.reference(n))
    print(f"{r.uid:3d}  {int(np.asarray(r.engine.outputs['fibo'])):10d}"
          f"  {r.engine.cycles:6d}  {m.slot:4d}  {m.queue_wait_blocks:12d}"
          f"  {m.residency_blocks:17d}")
print("\nshort requests finished in 1-2 blocks without waiting for the "
      "fib(40) jobs\nriding the neighbouring slots — no wave barrier.")
