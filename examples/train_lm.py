"""End-to-end training driver with fault tolerance.

Trains a small LM (internlm2-family) on the synthetic pipeline, injects a
failure mid-run, restarts from the latest checkpoint, and verifies the
loss curve continues — the restart is byte-exact with an uninterrupted
run (see tests/test_substrate.py).

Defaults are CPU-sized (~10M params, 300 steps).  ``--preset 100m`` is
the real-hardware configuration (d=768, 12 layers ~ 110M params).

Run: PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses
import shutil

import jax

from repro.configs.base import get_arch
from repro.data.pipeline import SyntheticLM
from repro.optim import adamw
from repro.train import loop as train_loop


def preset_cfg(preset: str):
    base = get_arch("internlm2-1.8b")
    if preset == "100m":
        return dataclasses.replace(
            base, n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
            d_ff=3072, vocab=32000, loss_chunk=256, attn_q_block=256,
            attn_kv_block=256)
    return dataclasses.replace(
        base, n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
        d_ff=1024, vocab=8192, loss_chunk=64, attn_q_block=64,
        attn_kv_block=64, compute_dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--preset", choices=["tiny", "100m"], default="tiny")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step to demo restart")
    args = ap.parse_args()

    cfg = preset_cfg(args.preset)
    src = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch, seed=0)
    opt = adamw.OptConfig(lr=1e-3, warmup_steps=20,
                          total_steps=args.steps)
    shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    lp = train_loop.LoopConfig(
        total_steps=args.steps, ckpt_every=max(5, args.steps // 6),
        ckpt_dir=args.ckpt_dir,
        log_every=10, fail_at_step=args.fail_at or args.steps // 2)

    print(f"== run 1 (will fail at step {lp.fail_at_step}) ==")
    try:
        train_loop.run(cfg, lp, opt, src, key=jax.random.key(0))
    except train_loop.SimulatedFailure as e:
        print(f"!! {e} — restarting from latest checkpoint")

    print("== run 2 (restart) ==")
    lp2 = dataclasses.replace(lp, fail_at_step=None)
    out = train_loop.run(cfg, lp2, opt, src, key=jax.random.key(0))
    print(f"resumed from step {out['start_step']}; "
          f"final loss {out['losses'][-1]:.4f}; "
          f"stragglers flagged: {out['straggler_events']}")
    first = sum(out["losses"][:5]) / 5 if out["losses"] else float("nan")
    last = sum(out["losses"][-5:]) / 5
    print(f"loss {first:.3f} -> {last:.3f}")


if __name__ == "__main__":
    main()
