"""Observability end to end: counters, trace, metrics (DESIGN.md §12).

The serve_hardened.py workload — two tenants, deadlines, cycle
budgets, a seeded FaultPlan that kills the primary backend, wedges a
slot, and poisons one request — but fully instrumented: fabric
profiling on, every lifecycle edge traced on the block clock, metrics
registered.  Writes

  obs_trace.json    Chrome trace-event JSON.  Open it in
                    https://ui.perfetto.dev (or chrome://tracing):
                    slot tracks show residency spans, tenant tracks
                    show queued->finished request spans, and every
                    fault injection is an instant on the server track.
                    1 block renders as 1 ms.
  obs_metrics.json  MetricsRegistry snapshot (queue depth, per-tenant
                    admission, retries, degradations, latency
                    histograms).

Profiling perturbs nothing: results with profile=True are bit-
identical and ride the same device dispatches (property-tested in
tests/test_obs.py).

Run: PYTHONPATH=src python examples/observability.py
"""
import numpy as np

from repro.core import library
from repro.obs import MetricsRegistry, TraceRecorder, validate_chrome
from repro.serve.dataflow_server import DataflowServer
from repro.serve.faults import FaultPlan
from repro.serve.types import Request

bench = library.vector_sum_graph(8)
rng = np.random.default_rng(0)

plan = FaultPlan(seed=7, persistent_backends={"xla"},
                 persistent_from_block=7, wedge_uids={4}, poison_uids={5})

tr, mr = TraceRecorder(), MetricsRegistry()
srv = DataflowServer(bench.graph, slots=2, block_cycles=4, backend="xla",
                     max_queue=8, policy="reject",
                     wedge_timeout_blocks=4, max_retries=2, faults=plan,
                     profile=True, trace=tr, metrics=mr)

for uid in range(1, 7):
    srv.submit(Request(
        uid=uid,
        feeds=library.random_feeds("vector_sum", bench, 1 + uid % 4, rng),
        tenant=("alice", "bob")[uid % 2],
        deadline_blocks=40 if uid == 3 else None,
        max_cycles=3 if uid == 6 else None))

results = sorted(srv.drain(), key=lambda r: r.uid)
assert len(results) == 6, "every request must be answered"

# -- fabric counters: where did the cycles go, per request? -----------------
print("uid  status     backend    fires  stall_in  stall_out")
for r in results:
    p = r.engine.profile if r.engine is not None else None
    if p is None:                       # dropped/expired before running
        print(f"{r.uid:3d}  {r.status:9s}  -")
        continue
    p.check()                           # §12 partition invariant
    print(f"{r.uid:3d}  {r.status:9s}  {r.metrics.backend or '-':9s}"
          f"  {p.fired:5d}  {int(p.stall_in.sum()):8d}"
          f"  {int(p.stall_out.sum()):9d}")

# -- the trace: every lifecycle edge on the deterministic block clock -------
kinds = sorted({e.kind for e in tr.events})
print(f"\ntrace: {len(tr.events)} events, kinds: {', '.join(kinds)}")
tr.save("obs_trace.json")               # block clock: diffable across runs
info = validate_chrome(tr.to_chrome())  # monotone clocks, balanced spans,
print(f"obs_trace.json: {info['events']} chrome events, "
      f"{info['uids']} requests, {info['tracks']} tracks -- "
      f"load it in ui.perfetto.dev")

# -- metrics snapshot -------------------------------------------------------
mr.save("obs_metrics.json")
snap = mr.snapshot()
print("obs_metrics.json counters:")
for k, v in snap["counters"].items():
    print(f"  {k} = {v}")
