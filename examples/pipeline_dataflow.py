"""Pipeline parallelism scheduled by the paper's dataflow engine.

Runs a 4-stage pipeline over 8 host devices, comparing the paper-faithful
one-token-per-arc schedule (2M+S-2 steps) against the double-buffered
dense wavefront (M+S-1 steps) — the paper's Fig. 1(b) vs Fig. 1(c).

Run: PYTHONPATH=src python examples/pipeline_dataflow.py
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.core.pipeline import (dataflow_schedule, dense_schedule,
                                 make_stage_fn, pipeline_apply)
from repro.models import transformer as tfm


def main():
    cfg = dataclasses.replace(get_arch("internlm2-1.8b").reduced(),
                              n_layers=8, remat=False)
    S, M, mb, seq = 4, 12, 2, 32
    params = tfm.init_params(cfg, jax.random.key(0))
    mesh = jax.make_mesh((S,), ("pp",))
    x = jax.random.normal(jax.random.key(1),
                          (M, mb, seq, cfg.d_model)) * 0.1
    stage_fn = make_stage_fn(cfg, cfg.n_layers // S)

    for name, sched in [("paper (1 token/arc)", dataflow_schedule(S, M)),
                        ("double-buffered", dense_schedule(S, M))]:
        fn = jax.jit(lambda lp, x: pipeline_apply(mesh, stage_fn, lp, x,
                                                  sched))
        y = fn(params["layers"], x)       # compile+run
        jax.block_until_ready(y)
        t0 = time.perf_counter()
        jax.block_until_ready(fn(params["layers"], x))
        dt = time.perf_counter() - t0
        print(f"{name:24s}: {sched.shape[0]:3d} schedule steps, "
              f"{dt * 1e3:7.1f} ms/iter")
        # correctness vs sequential execution
        def ref(x1):
            pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32),
                                   (mb, seq))
            def body(x, lp):
                x, _ = tfm._dense_body(cfg, lp, x, pos)
                return x, None
            out, _ = jax.lax.scan(body, x1, params["layers"])
            return out
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(jax.vmap(ref)(x)),
                                   rtol=2e-4, atol=2e-4)
    print("both schedules match the sequential reference; the dense "
          "schedule needs", dense_schedule(S, M).shape[0], "steps vs",
          dataflow_schedule(S, M).shape[0],
          "for the paper's handshake cadence")


if __name__ == "__main__":
    main()
