"""Batched serving: prefill + KV-cache decode over request waves.

Run: PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.models import transformer as tfm
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = get_arch("internlm2-1.8b").reduced()
    params = tfm.init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, batch_size=4, max_len=128)

    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        (int(l),)).astype(np.int32),
                    max_new_tokens=12)
            for i, l in enumerate(rng.integers(4, 40, (10,)))]
    t0 = time.perf_counter()
    results = eng.run(reqs)
    dt = time.perf_counter() - t0
    total = sum(len(r.tokens) for r in results)
    for r in results[:5]:
        print(f"req {r.uid}: prompt_len={r.prompt_len} -> "
              f"{r.tokens.tolist()}")
    print(f"{len(reqs)} requests, {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s, wave-batched)")


if __name__ == "__main__":
    main()
