"""Loop programs as cyclic dataflow fabrics (DESIGN.md §10).

A ``lax.while_loop`` with a data-dependent trip count becomes the
paper's cyclic loop schema — NDMERGE entry per carry, predicate cone,
BRANCH-steered back edges — compiled through the single ``compile()``
entry point, bit-identical on every executor, and served by the
continuous-batching DataflowServer one initiation per request.

Run: PYTHONPATH=src python examples/frontend_loop.py
"""
import math

import numpy as np
import jax.numpy as jnp
from jax import lax

from repro.core import asm
from repro.core.compile import GraphTraits, compile
from repro.front import trace
from repro.serve.dataflow_server import DataflowServer


# -- 1. an iterative algorithm, written as everyday jax ----------------------
def gcd(a, b):
    """Subtractive Euclid: the trip count depends on the data."""
    def body(c):
        x, y = c
        return (jnp.where(x > y, x - y, x),
                jnp.where(x > y, y, y - x))
    return lax.while_loop(lambda c: c[0] != c[1], body, (a, b))[0]


prog = trace(gcd, np.int32, np.int32, name="gcd")
print(prog.summary())                    # a CYCLIC fabric
print(GraphTraits.probe(prog))           # what the executor must provide
print(asm.emit(prog)[:400], "...\n")     # Listing-1 assembler round-trips

# -- 2. one compile() entry point, every executor ----------------------------
for backend in ("reference", "xla", "pallas", "unrolled"):
    run = compile(prog, backend=backend, block_cycles=8)
    res = run(prog.make_feeds([360], [84]))
    got = np.asarray(res.outputs[prog.out_arc]).item()
    print(f"{backend:9s} gcd(360, 84) = {got}  "
          f"(cycles={res.cycles}, fired={res.fired})")
    assert got == math.gcd(360, 84) == 12, (backend, got)

# -- 3. serve it: one loop initiation per request ----------------------------
srv = DataflowServer.for_fn(gcd, np.int32, np.int32, name="gcd",
                            slots=4, block_cycles=8, backend="xla")
cases = [(12, 18), (100, 64), (7, 7), (81, 27), (360, 84), (1, 99)]
uids = [srv.submit_args(a, b) for a, b in cases]
results = {r.uid: r for r in srv.drain()}
for uid, (a, b) in zip(uids, cases):
    r = results[uid]
    print(f"gcd({a:3d},{b:3d}) = "
          f"{np.asarray(r.engine.outputs[prog.out_arc]).item():3d}  "
          f"slot={r.metrics.slot} residency={r.metrics.residency_cycles}cyc "
          f"tokens={r.metrics.tokens_out} truncated={r.metrics.truncated}")
    assert np.asarray(r.engine.outputs[prog.out_arc]).item() == math.gcd(a, b)
print("served", len(cases), "loop initiations, all exact")
